# REVEL reproduction — top-level developer workflow.
#
#   make artifacts    AOT-lower the JAX kernels to artifacts/*.hlo.txt
#                     (needs python + jax; enables the PJRT golden tests)
#   make build        release build of the library, CLI, and benches
#   make test         tier-1 gate: cargo build --release && cargo test -q
#   make sweep        full parallel evaluation sweep -> BENCH_sweep.json
#   make bench-smoke  1-rep perf_hotpath (what CI archives)
#   make ci           everything CI runs, in order

CARGO ?= cargo
PYTHON ?= python

.PHONY: artifacts build test sweep bench-smoke fmt clippy ci clean

artifacts:
	cd python && $(PYTHON) -m compile.aot --outdir ../artifacts

build:
	$(CARGO) build --release --workspace

test: build
	$(CARGO) test -q

sweep: build
	$(CARGO) run --release --bin revel -- sweep --out BENCH_sweep.json

bench-smoke:
	REVEL_BENCH_REPS=1 $(CARGO) bench --bench perf_hotpath

fmt:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

ci: build test fmt clippy bench-smoke
	cd python && $(PYTHON) -m pytest tests -q

clean:
	$(CARGO) clean
	rm -f BENCH_sweep.json BENCH_hotpath.json
