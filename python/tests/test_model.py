"""L2 correctness: model graphs vs numpy oracles + AOT lowering sanity."""

import numpy as np
import pytest

# Auto-skip (not error) when the JAX/PJRT toolchain is absent — offline
# CI runners only have the rust toolchain.
jax = pytest.importorskip("jax", reason="JAX toolchain not installed")
import jax.numpy as jnp

from compile import model, aot
from compile.kernels import ref


@pytest.mark.parametrize("n", [12, 16, 24, 32])
def test_qr_reconstructs_and_is_orthogonal(n):
    a = np.asarray(ref.make_spd(n)) * 0.1 + np.eye(n, dtype=np.float32)
    q, r = model.qr(jnp.asarray(a))
    q, r = np.asarray(q), np.asarray(r)
    np.testing.assert_allclose(q @ r, a, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(q.T @ q, np.eye(n), rtol=1e-3, atol=1e-3)
    # r upper-triangular
    assert np.abs(np.tril(r, -1)).max() < 1e-3


@pytest.mark.parametrize("n", [8, 12, 16, 24])
def test_svd_values_vs_numpy(n):
    g = np.random.default_rng(n)
    a = g.standard_normal((n, n)).astype(np.float32)
    got = np.asarray(model.svd(jnp.asarray(a))[0])
    want = np.linalg.svd(a.astype(np.float64), compute_uv=False)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [64, 128])
def test_fft_vs_numpy(n):
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    re, im = model.fft(jnp.asarray(x))
    want = np.fft.fft(x)
    np.testing.assert_allclose(re, want.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(im, want.imag, rtol=1e-3, atol=1e-3)


def test_pipeline_5g_consistent():
    """End-to-end pipeline graph = composition of its stage oracles."""
    g = np.random.default_rng(5)
    h = g.standard_normal((24, 16)).astype(np.float32)
    y_time = g.standard_normal(64).astype(np.float32)
    w = g.standard_normal((16, 16)).astype(np.float32)
    l, z, s = model.pipeline_5g(jnp.asarray(h), jnp.asarray(y_time), jnp.asarray(w))

    re, im = ref.fft(jnp.asarray(y_time))
    y = np.asarray(re)[:24] + 0.125 * np.asarray(im)[:24]
    a = h.T @ h + 0.1 * np.eye(16, dtype=np.float32)
    l_want = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(l, l_want, rtol=2e-3, atol=2e-3)
    z_want = np.linalg.solve(l_want, h.T.astype(np.float64) @ y)
    np.testing.assert_allclose(z, z_want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(s, w @ np.asarray(z), rtol=2e-3, atol=2e-3)


def test_registry_covers_table5():
    reg = model.registry()
    for n in (12, 16, 24, 32):
        for k in ("cholesky", "solver", "qr", "svd"):
            assert f"{k}_n{n}" in reg
    for m in (12, 24, 48):
        assert f"gemm_m{m}" in reg
    assert "fft_n1024" in reg and "pipeline_n16" in reg


def test_aot_lowering_produces_parseable_hlo_text():
    """Smoke: one small entry lowers to non-trivial HLO text with ENTRY."""
    reg = model.registry()
    fn, specs = reg["solver_n12"]
    text = aot.lower_entry(fn, specs)
    assert "ENTRY" in text and "f32[12,12]" in text
    assert len(text) > 500
