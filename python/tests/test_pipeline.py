"""L2 end-to-end: the composed 5G pipeline graph vs a numpy re-derivation,
plus lowering sanity for the pipeline artifact (the graph the rust
coordinator's golden checks exercise)."""

import numpy as np
import pytest

# Auto-skip (not error) when the JAX toolchain or hypothesis is absent —
# offline CI runners only have the rust toolchain.
pytest.importorskip("jax", reason="JAX toolchain not installed")
pytest.importorskip("hypothesis", reason="hypothesis not installed")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def _numpy_pipeline(h, y_time, w):
    """Independent numpy mirror of model.pipeline_5g."""
    spec = np.fft.fft(y_time.astype(np.float64))
    y = spec.real[: h.shape[0]] + 0.125 * spec.imag[: h.shape[0]]
    a = h.T @ h + 0.1 * np.eye(h.shape[1])
    l = np.linalg.cholesky(a)
    rhs = h.T @ y
    z = np.linalg.solve(l, rhs)
    s = w @ z.reshape(-1, 1)
    return l, z, s.reshape(-1)


def _inputs(seed, rows=24, n=16, nfft=64):
    g = np.random.default_rng(seed)
    h = g.standard_normal((rows, n)).astype(np.float32) * 0.3
    y = g.standard_normal(nfft).astype(np.float32)
    w = g.standard_normal((n, n)).astype(np.float32) * 0.2
    return h, y, w


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pipeline_matches_numpy(seed):
    h, y, w = _inputs(seed)
    l, z, s = model.pipeline_5g(jnp.asarray(h), jnp.asarray(y), jnp.asarray(w))
    lw, zw, sw = _numpy_pipeline(h.astype(np.float64), y, w.astype(np.float64))
    np.testing.assert_allclose(np.asarray(l), lw, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(z), zw, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s), sw, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pipeline_stable_over_random_channels(seed):
    h, y, w = _inputs(seed)
    l, z, s = model.pipeline_5g(jnp.asarray(h), jnp.asarray(y), jnp.asarray(w))
    # The regularized Gram matrix keeps everything finite and the
    # Cholesky factor positive on the diagonal.
    assert np.isfinite(np.asarray(z)).all()
    assert np.isfinite(np.asarray(s)).all()
    assert (np.diag(np.asarray(l)) > 0).all()


def test_pipeline_lowers_to_single_hlo_module():
    entries = model.registry()
    fn, args = entries["pipeline_n16"]
    text = aot.lower_entry(fn, args)
    assert "HloModule" in text
    # One fused module, no Python-visible custom calls that the 0.5.1
    # PJRT client cannot compile.
    assert "custom-call" not in text.lower() or "Sharding" in text
