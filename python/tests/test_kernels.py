"""L1 correctness: Pallas kernels vs. pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/values; every comparison is assert_allclose against
the reference.  This is the CORE correctness signal for the compile path —
the same kernels lower into the HLO artifacts the rust coordinator runs.
"""

import numpy as np
import pytest

# Auto-skip (not error) when the JAX/Pallas toolchain or hypothesis is
# absent — offline CI runners only have the rust toolchain.
jax = pytest.importorskip("jax", reason="JAX toolchain not installed")
hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed"
)
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import cholesky_update as k_chol
from compile.kernels import gemm as k_gemm
from compile.kernels import fir as k_fir
from compile.kernels import solver_row as k_solver

SIZES = [4, 8, 12, 16, 24, 32]


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Cholesky step + full factorization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
def test_cholesky_step_matches_ref(n):
    a = ref.make_spd(n)
    for k in [0, 1, n // 2, n - 1]:
        got = k_chol.cholesky_step(a, jnp.int32(k))
        want = ref.cholesky_step(a, jnp.int32(k))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", SIZES)
def test_cholesky_full_matches_numpy(n):
    a = np.asarray(ref.make_spd(n), dtype=np.float64)
    want = np.linalg.cholesky(a)
    got = k_chol.cholesky(jnp.asarray(a, dtype=jnp.float32))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 20), seed=st.integers(0, 10_000))
def test_cholesky_hypothesis(n, seed):
    g = rng(seed)
    m = g.standard_normal((n, n)).astype(np.float32)
    a = m @ m.T + n * np.eye(n, dtype=np.float32)
    want = np.linalg.cholesky(a.astype(np.float64))
    got = k_chol.cholesky(jnp.asarray(a))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# Solver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
def test_solver_matches_ref(n):
    a = ref.make_spd(n)
    l = jnp.tril(a) + jnp.eye(n) * n
    b = jnp.sin(jnp.arange(n, dtype=jnp.float32))
    got = k_solver.solver(l, b)
    want = ref.solver(l, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 24), seed=st.integers(0, 10_000))
def test_solver_hypothesis_vs_numpy(n, seed):
    g = rng(seed)
    l = np.tril(g.standard_normal((n, n))).astype(np.float32)
    np.fill_diagonal(l, np.abs(np.diag(l)) + 1.0)
    b = g.standard_normal(n).astype(np.float32)
    got = np.asarray(k_solver.solver(jnp.asarray(l), jnp.asarray(b)))
    want = np.linalg.solve(l.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [12, 24, 48])
def test_gemm_paper_sizes(m):
    g = rng(m)
    a = g.standard_normal((m, 16)).astype(np.float32)
    b = g.standard_normal((16, 64)).astype(np.float32)
    got = k_gemm.gemm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 24),
    n=st.integers(1, 40),
    seed=st.integers(0, 10_000),
)
def test_gemm_hypothesis_shapes(m, k, n, seed):
    g = rng(seed)
    a = g.standard_normal((m, k)).astype(np.float32)
    b = g.standard_normal((k, n)).astype(np.float32)
    got = k_gemm.gemm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, a @ b, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# FIR
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [4, 5, 16, 32])
def test_fir_matches_ref(m):
    n_out = 64
    x = jnp.cos(jnp.arange(n_out + m - 1, dtype=jnp.float32) * 0.1)
    h = ref.centro_taps(m)
    got = k_fir.fir(x, h, m)
    want = ref.fir(x, h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 32), n_out=st.integers(1, 96), seed=st.integers(0, 10_000))
def test_fir_hypothesis(m, n_out, seed):
    g = rng(seed)
    x = g.standard_normal(n_out + m - 1).astype(np.float32)
    h = np.asarray(ref.centro_taps(m, key=float(seed % 7)))
    got = k_fir.fir(jnp.asarray(x), jnp.asarray(h), m)
    want = np.correlate(x, h, mode="valid")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_centro_taps_are_centro_symmetric():
    for m in range(2, 33):
        h = np.asarray(ref.centro_taps(m))
        np.testing.assert_allclose(h, h[::-1], rtol=0, atol=0)
