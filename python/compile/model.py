"""L2: JAX compute graphs for every REVEL workload (paper Table 5).

These are the functions AOT-lowered by aot.py into artifacts/*.hlo.txt and
executed from the rust runtime as golden numerical models.  The FGOP
kernels (Cholesky, Solver) and the vectorizable hot loops (GEMM, FIR) call
the L1 Pallas kernels, so the Pallas code lowers into the very same HLO the
rust coordinator runs.  QR / SVD / FFT are pure-jnp (ref.py) — their hot
regions are matrix products XLA already fuses well, and keeping them
custom-call-free is required for the 0.5.1 PJRT client.

Workload sizes follow paper Table 5:
  SVD/QR/Cholesky/Solver/FIR: n in {12, 16, 24, 32}
  FFT: n in {64, 128, 1024};  GEMM: (m, 16, 64) for m in {12, 24, 48}.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import cholesky_update as k_chol
from .kernels import gemm as k_gemm
from .kernels import fir as k_fir
from .kernels import solver_row as k_solver

# ---------------------------------------------------------------------------
# Individual workloads (all return tuples — AOT lowers with return_tuple).
# ---------------------------------------------------------------------------


def cholesky(a):
    """Cholesky factor L of SPD a, built from n Pallas step-kernels."""
    return (k_chol.cholesky(a),)


def solver(l, b):
    """Forward substitution L x = b via the Pallas solver kernel."""
    return (k_solver.solver(l, b),)


def qr(a):
    q, r = ref.qr(a)
    return (q, r)


def svd(a):
    return (ref.svd_values(a),)


def gemm(a, b):
    return (k_gemm.gemm(a, b),)


def fir(x, h, m: int):
    return (k_fir.fir(x, h, m),)


def fft(re):
    return ref.fft(re)


# ---------------------------------------------------------------------------
# Composed 5G receiver pipeline slice (paper Fig 4): the end-to-end graph
# the coordinator example drives.  One subframe:
#   1. FFT the received time-domain signal (per-antenna).
#   2. Channel estimation: A = H^H H + sigma I, L = chol(A)   (Cholesky)
#   3. Equalization: solve L z = H^T y                         (Solver)
#   4. Beamforming: s = W @ z_pad                              (GEMM)
# Real-valued stand-in for the complex baseband math — same dataflow and
# FLOP structure, which is what the reproduction measures.
# ---------------------------------------------------------------------------


def pipeline_5g(h, y_time, w):
    n = h.shape[1]
    y_re, y_im = ref.fft(y_time)
    y = y_re[: h.shape[0]] + 0.125 * y_im[: h.shape[0]]
    a = h.T @ h + 0.1 * jnp.eye(n, dtype=jnp.float32)
    l = k_chol.cholesky(a)
    rhs = h.T @ y
    z = k_solver.solver(l, rhs)
    s = k_gemm.gemm(w, z.reshape(n, 1))
    return (l, z, s.reshape(-1))


# ---------------------------------------------------------------------------
# AOT registry: name -> (fn, example-input ShapeDtypeStructs).
# Rust's runtime/artifacts.rs mirrors this table.
# ---------------------------------------------------------------------------


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def registry():
    entries = {}
    for n in (12, 16, 24, 32):
        entries[f"cholesky_n{n}"] = (cholesky, (f32(n, n),))
        entries[f"solver_n{n}"] = (solver, (f32(n, n), f32(n)))
        entries[f"qr_n{n}"] = (qr, (f32(n, n),))
        entries[f"svd_n{n}"] = (svd, (f32(n, n),))
    for m in (12, 24, 48):
        entries[f"gemm_m{m}"] = (gemm, (f32(m, 16), f32(16, 64)))
    for m in (16, 32):
        entries[f"fir_m{m}"] = (
            lambda x, h, m=m: fir(x, h, m),
            (f32(64 + m - 1), f32(m)),
        )
    for n in (64, 128, 1024):
        entries[f"fft_n{n}"] = (fft, (f32(n),))
    entries["pipeline_n16"] = (
        pipeline_5g,
        (f32(24, 16), f32(64), f32(16, 16)),
    )
    return entries
