"""AOT lowering: every registry entry -> artifacts/<name>.hlo.txt.

HLO *text* is the interchange format (NOT lowered.compiler_ir().serialize()
nor jax.export): jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids, which the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot [--outdir ../artifacts] [names...]
Writes a manifest.json describing shapes, and a .stamp for make.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("names", nargs="*", help="subset of registry names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    reg = model.registry()
    names = args.names or sorted(reg)
    manifest = {}
    for name in names:
        fn, specs = reg[name]
        text = lower_entry(fn, specs)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"  aot: {name} -> {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    with open(os.path.join(args.outdir, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"aot: wrote {len(names)} artifacts to {args.outdir}", file=sys.stderr)


if __name__ == "__main__":
    main()
