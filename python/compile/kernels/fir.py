"""L1 Pallas kernel: centro-symmetric FIR filter (paper Table 5 "FIR").

On REVEL, FIR uses a 1D *inductive* access phase ("I" capability in
Table 5): the sliding window over x is expressed as a stream whose start
address advances with the outer induction variable.  On TPU the window
walk becomes `m` statically-unrolled shifted loads from the same VMEM
block (x fits comfortably in VMEM at these sizes), each feeding a
VPU-wide multiply-accumulate.  The taps are centro-symmetric
(h[j] == h[m-1-j]); the kernel exploits this the same way the DSPLIB
centro-FIR does, by adding the two mirrored windows before multiplying —
halving the multiplies, the paper's Table 4 ASIC model counts the same
(n-m+1)/4 per-cycle throughput.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fir_kernel(x_ref, h_ref, o_ref, *, m: int, n_out: int):
    x = x_ref[...]
    h = h_ref[...]
    acc = jnp.zeros((n_out,), dtype=jnp.float32)
    half = m // 2
    # Centro-symmetric pairing: h[j] * (x[i+j] + x[i+m-1-j]).
    for j in range(half):
        wa = jax.lax.dynamic_slice_in_dim(x, j, n_out)
        wb = jax.lax.dynamic_slice_in_dim(x, m - 1 - j, n_out)
        acc = acc + h[j] * (wa + wb)
    if m % 2 == 1:
        wc = jax.lax.dynamic_slice_in_dim(x, half, n_out)
        acc = acc + h[half] * wc
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("m",))
def fir(x: jnp.ndarray, h: jnp.ndarray, m: int | None = None):
    """y[i] = sum_j h[j] x[i+j] for centro-symmetric h (len(h) == m)."""
    m = m if m is not None else h.shape[0]
    n_out = x.shape[0] - m + 1
    return pl.pallas_call(
        functools.partial(_fir_kernel, m=m, n_out=n_out),
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.float32),
        interpret=True,
    )(x, h)
