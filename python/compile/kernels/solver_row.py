"""L1 Pallas kernel: triangular solve by forward substitution (paper Fig 2).

The solver is the paper's instructive FGOP example: a *divide* dataflow
(point region) produces x[j] = b[j] / L[j][j], which the *vector* region
consumes with an inductive production:consumption rate (each x[j] is
reused n-1-j times — the stream "stretch" s_c = -1 of Fig 9/11).

TPU adaptation: the loop-carried chain stays a `fori_loop` inside a single
kernel invocation (it is inherently sequential), while the vector region's
masked AXPY `b -= x[j] * L[:, j]` is a full-width VPU op with an
iota-vs-j mask instead of an inductive trip count — again implicit
masking in place of REVEL's shrinking streams.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _solver_kernel(l_ref, b_ref, o_ref):
    n = l_ref.shape[0]
    l = l_ref[...]
    b0 = b_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def body(j, carry):
        b, x = carry
        bj = jax.lax.dynamic_index_in_dim(b, j, keepdims=False)
        ljj = jax.lax.dynamic_index_in_dim(
            jax.lax.dynamic_index_in_dim(l, j, axis=0, keepdims=False),
            j,
            keepdims=False,
        )
        xj = bj / ljj  # point region (divide dataflow)
        colj = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=1)[:, 0]
        # Vector region: masked AXPY over the remaining rows.
        b = jnp.where(rows > j, b - xj * colj, b)
        x = jnp.where(rows == j, xj, x)
        return (b, x)

    _, x = jax.lax.fori_loop(0, n, body, (b0, jnp.zeros_like(b0)))
    o_ref[...] = x


@jax.jit
def solver(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve L x = b (L lower-triangular) with the Pallas kernel."""
    n = l.shape[0]
    return pl.pallas_call(
        _solver_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(l, b)
