"""L1 Pallas kernel: one right-looking Cholesky step (paper Fig 5).

REVEL decomposes Cholesky into three dataflows: a *point* region
(sqrt + reciprocal), a *vector* region (pivot-column scale) and a *matrix*
region (rank-1 trailing update).  The matrix region is the critical
dataflow (paper Feature 5) and its iteration domain is triangular and
inductive — it shrinks by one row/column every outer step.

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of REVEL's
inductive streams + implicit vector masking, the kernel operates on a
fixed n×n VMEM block and *masks* the live triangular sub-domain with
`broadcasted_iota` comparisons against the step index `k`.  The mask is
generated inside the kernel — the caller never materializes ragged
iterations — which is exactly the role implicit vector masking plays in
REVEL's stream control unit.  The rank-1 update is expressed as an outer
product feeding an elementwise subtract, the MXU/VPU-friendly form of the
critical dataflow; the sqrt/div point region is the scalar prologue (the
"temporal fabric" analogue).

VMEM footprint: 3 n×n f32 blocks (in, out, outer-product temp); for the
paper's n ≤ 32 this is ≤ 12 KiB — far under the ~16 MiB VMEM budget, so a
single-block (grid-free) kernel is the right shape.  Estimated MXU story
is in DESIGN.md §6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cholesky_step_kernel(k_ref, a_ref, o_ref):
    n = a_ref.shape[0]
    k = k_ref[0]
    a = a_ref[...]

    # Point region (non-critical; scalar sqrt + reciprocal).
    akk = jax.lax.dynamic_index_in_dim(
        jax.lax.dynamic_index_in_dim(a, k, axis=0, keepdims=False),
        k,
        axis=0,
        keepdims=False,
    )
    d = jnp.sqrt(akk)
    inva = 1.0 / d

    # Vector region: scale the pivot column below the diagonal.
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    rowvec = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)
    col = jnp.where(
        rowvec > k,
        jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1) * inva,
        0.0,
    )  # (n, 1)

    # Matrix region (critical): masked rank-1 trailing update.
    live = (rows > k) & (cols > k)
    upd = a - col @ col.T  # outer product -> MXU-shaped contraction
    out = jnp.where(live, upd, a)

    # Write back the scaled pivot column and the diagonal sqrt.
    colmask = cols == k
    out = jnp.where(colmask & (rows > k), col, out)
    out = jnp.where(colmask & (rows == k), d, out)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=())
def cholesky_step(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """One Cholesky outer-loop step via the Pallas kernel (interpret mode)."""
    n = a.shape[0]
    k_arr = jnp.asarray(k, dtype=jnp.int32).reshape((1,))
    return pl.pallas_call(
        _cholesky_step_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(k_arr, a)


def cholesky(a: jnp.ndarray) -> jnp.ndarray:
    """Full Cholesky factor via n sequential kernel steps (ordered dep.)."""
    n = a.shape[0]
    out = jax.lax.fori_loop(
        0, n, lambda k, m: cholesky_step(m, jnp.int32(k)), a
    )
    return jnp.tril(out)
