"""L1 Pallas kernel: tiled GEMM (beamforming stage, paper Table 5).

GEMM is one of the paper's non-FGOP kernels — its iteration domain is
rectangular, so on REVEL it uses plain RR streams with stream-reuse
(Table 5 row "GEMM": Acc=RR, Reuse=Y).  The TPU analogue of stream-reuse
is VMEM block residency across grid steps: the A tile is revisited for
every N tile (index_map ignores j) and the B tile for every M tile, so
each HBM word is fetched O(1) times per tile-row instead of O(tiles).

The MXU wants the contraction as `jnp.dot(..., preferred_element_type=
jnp.float32)` on (bm, K) x (K, bn) blocks.  The paper's matrices are small
(m in {12,24,48}, K=16, N=64) so K is kept whole per tile and the caller
pads M/N up to tile multiples (the padding rows are sliced off after the
call — the same role REVEL's implicit vector masking plays for
non-vector-width-divisible iterations).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(a: jnp.ndarray, b: jnp.ndarray, bm: int = 8, bn: int = 32):
    """C = A @ B with (bm, bn) output tiles; pads M and N as needed."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    a_p = jnp.pad(a, ((0, mp - m), (0, 0)))
    b_p = jnp.pad(b, ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _gemm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]
