"""Pure-jnp correctness oracles for the Pallas kernels and JAX models.

Everything here is written with plain jnp ops (no jnp.linalg custom-calls):
the AOT path must produce HLO that the rust PJRT CPU client (xla_extension
0.5.1) can execute, and jaxlib's lapack custom-calls are not registered
there.  These functions double as the L2 reference implementations that the
REVEL simulator's functional outputs are validated against.

The region structure of each kernel mirrors the paper's Fig 5/6/9
decomposition (point / vector / matrix regions), which is what the REVEL
dataflow programs in rust/src/workloads/ implement.
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Cholesky (paper Fig 5): point region (sqrt/div), vector region (column
# scale), matrix region (rank-1 trailing update).
# ---------------------------------------------------------------------------


def cholesky_step(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """One outer-loop iteration of right-looking Cholesky, full-matrix masked.

    Rows/cols <= k are left untouched except column k, which receives the
    scaled pivot column.  This is the oracle for kernels/cholesky_update.py.
    """
    n = a.shape[0]
    i = jnp.arange(n)
    d = jnp.sqrt(a[k, k])  # point region
    inva = 1.0 / d
    col = jnp.where(i > k, a[:, k] * inva, 0.0)  # vector region
    below = i > k
    mask = below[:, None] & below[None, :]  # matrix region domain
    upd = a - jnp.outer(col, col)
    out = jnp.where(mask, upd, a)
    out = out.at[:, k].set(jnp.where(below, col, out[:, k]))
    out = out.at[k, k].set(d)
    return out


def cholesky(a: jnp.ndarray) -> jnp.ndarray:
    """Full Cholesky factor L (lower-triangular), a must be SPD."""
    n = a.shape[0]
    out = jax.lax.fori_loop(0, n, lambda k, m: cholesky_step(m, k), a)
    return jnp.tril(out)


# ---------------------------------------------------------------------------
# Triangular solver (paper Fig 2/9): forward substitution L x = b.
# ---------------------------------------------------------------------------


def solver(l: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    n = l.shape[0]

    def body(j, x):
        # x holds zeros beyond j-1, so the full-row dot is exact.
        xj = (b[j] - jnp.dot(l[j, :], x)) / l[j, j]
        return x.at[j].set(xj)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


# ---------------------------------------------------------------------------
# Householder QR (paper Fig 6): householder region (point/vector) + trailing
# matrix region.
# ---------------------------------------------------------------------------


def qr(a: jnp.ndarray):
    """Householder QR; returns (q, r) with q orthogonal, r upper-triangular."""
    n = a.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)

    def body(k, qr_pair):
        q, r = qr_pair
        i = jnp.arange(n)
        sel = i >= k
        x = jnp.where(sel, r[:, k], 0.0)
        normx = jnp.sqrt(jnp.sum(x * x))
        xk = x[k]
        sign = jnp.where(xk >= 0.0, 1.0, -1.0)
        alpha = -sign * normx
        v = x - alpha * (i == k).astype(a.dtype)
        vnorm2 = jnp.sum(v * v)
        # Degenerate column (already zero below the diagonal): skip.
        safe = vnorm2 > 1e-30
        invv = jnp.where(safe, 2.0 / jnp.where(safe, vnorm2, 1.0), 0.0)
        r = r - invv * jnp.outer(v, v @ r)
        q = q - invv * jnp.outer(q @ v, v)
        return (q, r)

    q, r = jax.lax.fori_loop(0, n, body, (eye, a))
    return q, r


# ---------------------------------------------------------------------------
# One-sided Jacobi SVD: returns singular values (sorted descending).
# The paper's SVD uses a bidiagonalization pipeline; the evaluation only
# needs singular values for numerical checking, and one-sided Jacobi keeps
# the HLO free of custom calls.
# ---------------------------------------------------------------------------


def _jacobi_pairs(n: int) -> jnp.ndarray:
    return jnp.array(
        [(p, q) for p in range(n - 1) for q in range(p + 1, n)],
        dtype=jnp.int32,
    )


def svd_values(a: jnp.ndarray, sweeps: int = 12) -> jnp.ndarray:
    n = a.shape[0]
    pairs = _jacobi_pairs(n)
    npairs = pairs.shape[0]

    def rotate(i, m):
        p = pairs[i % npairs, 0]
        q = pairs[i % npairs, 1]
        cp = m[:, p]
        cq = m[:, q]
        app = jnp.dot(cp, cp)
        aqq = jnp.dot(cq, cq)
        apq = jnp.dot(cp, cq)
        # Classic one-sided Jacobi rotation.
        small = jnp.abs(apq) <= 1e-12 * jnp.sqrt(app * aqq) + 1e-30
        tau = (aqq - app) / (2.0 * jnp.where(small, 1.0, apq))
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t = jnp.where(small, 0.0, t)
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = c * t
        newp = c * cp - s * cq
        newq = s * cp + c * cq
        m = m.at[:, p].set(newp)
        m = m.at[:, q].set(newq)
        return m

    m = jax.lax.fori_loop(0, sweeps * npairs, rotate, a)
    vals = jnp.sqrt(jnp.sum(m * m, axis=0))
    return jnp.sort(vals)[::-1]


# ---------------------------------------------------------------------------
# GEMM / FIR / FFT (non-FGOP kernels, paper Table 5)
# ---------------------------------------------------------------------------


def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b)


def fir(x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Centro-symmetric FIR: y[i] = sum_j h[j] * x[i + j].

    x has length n_out + len(h) - 1 (correlation form, matching the DSPLIB
    convention for FIR filters).
    """
    m = h.shape[0]
    n_out = x.shape[0] - m + 1
    idx = jnp.arange(n_out)[:, None] + jnp.arange(m)[None, :]
    return jnp.sum(x[idx] * h[None, :], axis=1)


def centro_taps(m: int, key: float = 0.0) -> jnp.ndarray:
    """Generate centro-symmetric taps h[j] == h[m-1-j]."""
    half = (m + 1) // 2
    base = jnp.sin(jnp.arange(half, dtype=jnp.float32) * 0.7 + 0.3 + key)
    full = jnp.concatenate([base, base[: m - half][::-1]])
    return full


def fft(re: jnp.ndarray):
    """Complex FFT of a real signal; returns (re, im) f32 arrays."""
    z = jnp.fft.fft(re.astype(jnp.complex64))
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def make_spd(n: int, seed: float = 0.0) -> jnp.ndarray:
    """Deterministic well-conditioned SPD test matrix."""
    i = jnp.arange(n, dtype=jnp.float32)
    m = jnp.sin(jnp.outer(i + 1.0, i + 2.0) * 0.05 + seed) * 0.9
    return m @ m.T + n * jnp.eye(n, dtype=jnp.float32)
