"""Pytest bootstrap for the compile package.

Makes `python -m pytest python/tests -q` work from the repository root
(and from anywhere else) by putting this directory — the parent of the
`compile` package — on sys.path before test collection.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
