//! Regenerates the paper's fig1 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig1());
    eprintln!("[bench fig1_utilization] completed in {:.2?}", t.elapsed());
}
