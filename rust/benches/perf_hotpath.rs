//! Performance bench for the simulator's hot path: simulated lane-cycles
//! per wall-clock second over a representative workload mix (the §Perf
//! target in EXPERIMENTS.md). Run before/after optimizations.
//!
//! The mix dispatches through the parallel sweep harness (memoization
//! disabled — this measures simulation, not cache lookups). Two
//! artifacts come out:
//!   BENCH_sweep.json    per-point results of the last rep, with
//!                       per-point host wall time aggregated (mean/min)
//!                       across all reps — `revel sweep-diff` reports
//!                       wall deltas from it informationally.
//!   BENCH_hotpath.json  the wall-time trajectory artifact: reps,
//!                       per-rep wall seconds, and per-point wall
//!                       ns (mean/min over reps) — CI archives it next
//!                       to BENCH_sweep.json so the simulator's real
//!                       speed is tracked PR over PR.
//! Knobs:
//!   REVEL_BENCH_REPS          repetitions of the mix (default 5; CI: 1)
//!   REVEL_WORKERS             worker threads (default: all cores)
//!   REVEL_BENCH_OUT           sweep artifact path (BENCH_sweep.json)
//!   REVEL_BENCH_HOTPATH_OUT   hotpath artifact path (BENCH_hotpath.json)

use std::sync::Arc;

use revel::harness::{self, json::Json, Options, SweepOutcome, SweepPoint};
use revel::workloads::{Features, Goal};

fn mix() -> Vec<SweepPoint> {
    [
        ("cholesky", 32, Goal::Latency),
        ("lu", 24, Goal::Latency),
        ("solver", 32, Goal::Latency),
        ("qr", 24, Goal::Latency),
        ("fft", 1024, Goal::Latency),
        ("gemm", 48, Goal::Throughput),
        ("svd", 12, Goal::Latency),
    ]
    .into_iter()
    .map(|(k, n, goal)| SweepPoint::new(k, n, Features::ALL, goal))
    .collect()
}

fn main() {
    let reps: usize = std::env::var("REVEL_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&r| r > 0)
        .unwrap_or(5);
    let out_path = std::env::var("REVEL_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sweep.json".to_string());
    let hot_path = std::env::var("REVEL_BENCH_HOTPATH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let workers = harness::pool::default_workers();
    let opts = Options { workers: Some(workers), use_cache: false };

    let mut total_cycles = 0u64;
    let mut total_lane_cycles = 0u64;
    let mut per_rep: Vec<Vec<Arc<SweepOutcome>>> = Vec::new();
    let mut rep_walls_s: Vec<f64> = Vec::new();
    let t = std::time::Instant::now();
    for _ in 0..reps {
        let t_rep = std::time::Instant::now();
        let outcomes = harness::run_all_opts(&mix(), &opts).expect("mix verifies");
        rep_walls_s.push(t_rep.elapsed().as_secs_f64());
        for o in &outcomes {
            total_cycles += o.cycles;
            total_lane_cycles += o.stats.lane_cycles.iter().sum::<u64>();
        }
        per_rep.push(outcomes);
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "perf_hotpath: {total_cycles} machine-cycles, {total_lane_cycles} lane-cycles in {dt:.2}s \
         ({reps} reps, {workers} workers)"
    );
    println!(
        "  {:.2}M machine-cycles/s | {:.2}M lane-cycles/s",
        total_cycles as f64 / dt / 1e6,
        total_lane_cycles as f64 / dt / 1e6
    );

    // Aggregate each point's host wall time across reps (mean/min) onto
    // the last rep's outcomes — simulated results are identical every
    // rep; only the wall measurements differ.
    let last = per_rep.last().expect("reps >= 1");
    let merged: Vec<Arc<SweepOutcome>> = last
        .iter()
        .enumerate()
        .map(|(i, o)| {
            let walls: Vec<f64> =
                per_rep.iter().map(|r| r[i].wall_ns_mean).collect();
            let mut out = o.as_ref().clone();
            out.wall_ns_mean = walls.iter().sum::<f64>() / walls.len() as f64;
            out.wall_ns_min =
                walls.iter().copied().fold(f64::INFINITY, f64::min);
            Arc::new(out)
        })
        .collect();

    // The sweep artifact pairs one rep's results with that rep's wall
    // time (the totals above span all reps and would skew throughput
    // math); per-point walls carry the cross-rep aggregate.
    let last_rep_s = *rep_walls_s.last().expect("reps >= 1");
    harness::write_artifact(&out_path, &merged, last_rep_s, workers)
        .expect("write BENCH_sweep.json");
    println!("  wrote {out_path}");

    let hotpath = Json::obj(vec![
        ("schema", Json::Str("revel-bench-hotpath".into())),
        ("version", Json::Num(1.0)),
        ("reps", Json::Num(reps as f64)),
        ("workers", Json::Num(workers as f64)),
        ("wall_s_total", Json::Num(dt)),
        (
            "rep_wall_s",
            Json::Arr(rep_walls_s.iter().map(|&w| Json::Num(w)).collect()),
        ),
        ("machine_cycles", Json::Num(total_cycles as f64)),
        ("lane_cycles", Json::Num(total_lane_cycles as f64)),
        (
            "machine_cycles_per_s",
            Json::Num(total_cycles as f64 / dt.max(1e-12)),
        ),
        (
            "lane_cycles_per_s",
            Json::Num(total_lane_cycles as f64 / dt.max(1e-12)),
        ),
        (
            "points",
            Json::Arr(
                merged
                    .iter()
                    .map(|o| {
                        Json::obj(vec![
                            ("kernel", Json::Str(o.point.kernel.clone())),
                            ("n", Json::Num(o.point.n as f64)),
                            (
                                "goal",
                                Json::Str(
                                    format!("{:?}", o.point.goal).to_lowercase(),
                                ),
                            ),
                            ("cycles", Json::Num(o.cycles as f64)),
                            ("wall_ns_mean", Json::Num(o.wall_ns_mean)),
                            ("wall_ns_min", Json::Num(o.wall_ns_min)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(&hot_path, hotpath.pretty()).expect("write BENCH_hotpath.json");
    println!("  wrote {hot_path}");
}
