//! Performance bench for the simulator's hot path: simulated lane-cycles
//! per wall-clock second over a representative workload mix (the §Perf
//! target in EXPERIMENTS.md). Run before/after optimizations.
use revel::workloads::{prepare, Features, Goal};

fn main() {
    let mut total_cycles = 0u64;
    let mut total_lane_cycles = 0u64;
    let t = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        for (k, n, goal) in [
            ("cholesky", 32, Goal::Latency),
            ("solver", 32, Goal::Latency),
            ("qr", 24, Goal::Latency),
            ("fft", 1024, Goal::Latency),
            ("gemm", 48, Goal::Throughput),
            ("svd", 12, Goal::Latency),
        ] {
            let r = prepare(k, n, Features::ALL, goal)
                .unwrap()
                .execute()
                .unwrap();
            total_cycles += r.cycles;
            total_lane_cycles += r.stats.lane_cycles.iter().sum::<u64>();
        }
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "perf_hotpath: {total_cycles} machine-cycles, {total_lane_cycles} lane-cycles in {dt:.2}s"
    );
    println!(
        "  {:.2}M machine-cycles/s | {:.2}M lane-cycles/s",
        total_cycles as f64 / dt / 1e6,
        total_lane_cycles as f64 / dt / 1e6
    );
}
