//! Performance bench for the simulator's hot path: simulated lane-cycles
//! per wall-clock second over a representative workload mix (the §Perf
//! target in EXPERIMENTS.md). Run before/after optimizations.
//!
//! The mix dispatches through the parallel sweep harness (memoization
//! disabled — this measures simulation, not cache lookups) and emits
//! the per-point results as `BENCH_sweep.json` so CI can archive the
//! perf trajectory. Knobs:
//!   REVEL_BENCH_REPS   repetitions of the mix (default 5; CI smoke: 1)
//!   REVEL_WORKERS      worker threads (default: available parallelism)
//!   REVEL_BENCH_OUT    artifact path (default BENCH_sweep.json)

use revel::harness::{self, Options, SweepPoint};
use revel::workloads::{Features, Goal};

fn mix() -> Vec<SweepPoint> {
    [
        ("cholesky", 32, Goal::Latency),
        ("lu", 24, Goal::Latency),
        ("solver", 32, Goal::Latency),
        ("qr", 24, Goal::Latency),
        ("fft", 1024, Goal::Latency),
        ("gemm", 48, Goal::Throughput),
        ("svd", 12, Goal::Latency),
    ]
    .into_iter()
    .map(|(k, n, goal)| SweepPoint::new(k, n, Features::ALL, goal))
    .collect()
}

fn main() {
    let reps: usize = std::env::var("REVEL_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&r| r > 0)
        .unwrap_or(5);
    let out_path = std::env::var("REVEL_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sweep.json".to_string());
    let workers = harness::pool::default_workers();
    let opts = Options { workers: Some(workers), use_cache: false };

    let mut total_cycles = 0u64;
    let mut total_lane_cycles = 0u64;
    let mut last = Vec::new();
    let mut last_rep_s = 0.0;
    let t = std::time::Instant::now();
    for _ in 0..reps {
        let t_rep = std::time::Instant::now();
        let outcomes = harness::run_all_opts(&mix(), &opts).expect("mix verifies");
        last_rep_s = t_rep.elapsed().as_secs_f64();
        for o in &outcomes {
            total_cycles += o.cycles;
            total_lane_cycles += o.stats.lane_cycles.iter().sum::<u64>();
        }
        last = outcomes;
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "perf_hotpath: {total_cycles} machine-cycles, {total_lane_cycles} lane-cycles in {dt:.2}s \
         ({reps} reps, {workers} workers)"
    );
    println!(
        "  {:.2}M machine-cycles/s | {:.2}M lane-cycles/s",
        total_cycles as f64 / dt / 1e6,
        total_lane_cycles as f64 / dt / 1e6
    );
    // The artifact pairs one rep's results with that rep's wall time
    // (the totals above span all reps and would skew throughput math).
    harness::write_artifact(&out_path, &last, last_rep_s, workers)
        .expect("write BENCH_sweep.json");
    println!("  wrote {out_path}");
}
