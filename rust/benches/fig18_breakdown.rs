//! Regenerates the paper's fig18 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig18());
    eprintln!("[bench fig18_breakdown] completed in {:.2?}", t.elapsed());
}
