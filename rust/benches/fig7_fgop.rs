//! Regenerates the paper's fig7 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig7());
    eprintln!("[bench fig7_fgop] completed in {:.2?}", t.elapsed());
}
