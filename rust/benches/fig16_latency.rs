//! Regenerates the paper's fig16 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig16());
    eprintln!("[bench fig16_latency] completed in {:.2?}", t.elapsed());
}
