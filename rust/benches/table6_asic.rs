//! Regenerates the paper's table6 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::table6());
    eprintln!("[bench table6_asic] completed in {:.2?}", t.elapsed());
}
