//! Regenerates the paper's fig19 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig19());
    eprintln!("[bench fig19_mechanisms] completed in {:.2?}", t.elapsed());
}
