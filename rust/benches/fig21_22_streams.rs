//! Regenerates the paper's fig21_22 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig21_22());
    eprintln!("[bench fig21_22_streams] completed in {:.2?}", t.elapsed());
}
