//! Regenerates the paper's fig17 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig17());
    eprintln!("[bench fig17_throughput] completed in {:.2?}", t.elapsed());
}
