//! Regenerates the paper's fig8 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig8());
    eprintln!("[bench fig8_taskpar] completed in {:.2?}", t.elapsed());
}
