//! Regenerates the paper's fig20 (see rust/src/report.rs).
fn main() {
    let t = std::time::Instant::now();
    println!("{}", revel::report::fig20());
    eprintln!("[bench fig20_temporal] completed in {:.2?}", t.elapsed());
}
