//! Stream address/value patterns (paper §4, Figures 10–12).
//!
//! A 2D pattern is a loop nest `for j in 0..n_j { for i in 0..len(j) }`
//! where `len(j) = n_i + j * s_ji` (the *stretch*). `s_ji == 0` is the
//! classic **rectangular** (RR) stream every prior stream ISA supports;
//! `s_ji != 0` is REVEL's **inductive** (RI) stream. `s_ji` is fixed-point
//! (f64 here) because vectorizing an inductive loop divides the stretch by
//! the vector width (paper Feature 4).

/// A 2D affine memory/value pattern with inductive inner trip count.
#[derive(Clone, Debug, PartialEq)]
pub struct Pattern2D {
    /// Base word address (or element index for value streams).
    pub start: i64,
    /// Inner-dimension stride (words per i step).
    pub c_i: i64,
    /// Outer-dimension stride (words per j step).
    pub c_j: i64,
    /// Initial inner trip count.
    pub n_i: f64,
    /// Outer trip count.
    pub n_j: i64,
    /// Stretch: d(len)/d(j). 0 => rectangular.
    pub s_ji: f64,
}

impl Pattern2D {
    /// 1D contiguous pattern of `n` words from `start`.
    pub fn lin(start: i64, n: i64) -> Self {
        Self { start, c_i: 1, c_j: 0, n_i: n as f64, n_j: 1, s_ji: 0.0 }
    }

    /// 1D strided pattern.
    pub fn strided(start: i64, c_i: i64, n: i64) -> Self {
        Self { start, c_i, c_j: 0, n_i: n as f64, n_j: 1, s_ji: 0.0 }
    }

    /// 2D rectangular pattern.
    pub fn rect(start: i64, c_i: i64, n_i: i64, c_j: i64, n_j: i64) -> Self {
        Self { start, c_i, c_j, n_i: n_i as f64, n_j, s_ji: 0.0 }
    }

    /// 2D inductive pattern with stretch.
    pub fn inductive(
        start: i64,
        c_i: i64,
        n_i: f64,
        c_j: i64,
        n_j: i64,
        s_ji: f64,
    ) -> Self {
        Self { start, c_i, c_j, n_i, n_j, s_ji }
    }

    pub fn is_inductive(&self) -> bool {
        self.s_ji != 0.0
    }

    /// Inner trip count at outer iteration j (clamped at 0, rounded to
    /// nearest — the hardware keeps a fixed-point length register).
    pub fn len_at(&self, j: i64) -> i64 {
        let l = self.n_i + self.s_ji * j as f64;
        l.round().max(0.0) as i64
    }

    /// Total number of elements the stream will produce.
    pub fn total_len(&self) -> i64 {
        (0..self.n_j).map(|j| self.len_at(j)).sum()
    }

    /// Number of port *instances* a width-`w` delivery produces: rows are
    /// chunked at width w, partial rows padded (never merged).
    pub fn instances(&self, w: usize) -> i64 {
        let w = w.max(1) as i64;
        (0..self.n_j).map(|j| (self.len_at(j) + w - 1) / w).sum()
    }

    /// Word address of element (j, i).
    pub fn addr(&self, j: i64, i: i64) -> i64 {
        self.start + self.c_j * j + self.c_i * i
    }

    /// Iterate all (addr, flags) in stream order.
    pub fn iter(&self) -> PatternIter<'_> {
        PatternIter { pat: self, j: 0, i: 0, cur_len: self.len_at(0) }
    }

    /// Inclusive address bounds of the whole pattern, or None if empty.
    /// Used by the lane's memory-ordering interlock (the command queue
    /// "maintains data ordering" — paper §6.1).
    pub fn bounds(&self) -> Option<(i64, i64)> {
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for j in 0..self.n_j {
            let len = self.len_at(j);
            if len == 0 {
                continue;
            }
            let a = self.addr(j, 0);
            let b = self.addr(j, len - 1);
            lo = lo.min(a.min(b));
            hi = hi.max(a.max(b));
        }
        (lo <= hi).then_some((lo, hi))
    }

    /// Number of *control commands* this pattern would cost on an ISA with
    /// only the given capability (paper Fig 11 / Fig 22 accounting).
    pub fn commands_needed(&self, cap: Capability) -> i64 {
        match cap {
            Capability::V(w) => {
                // One vector instruction covers w contiguous elements.
                (0..self.n_j)
                    .map(|j| (self.len_at(j) as f64 / w as f64).ceil() as i64)
                    .sum::<i64>()
                    .max(1)
            }
            Capability::R => self.n_j.max(1),
            Capability::RR | Capability::RRR => {
                if self.is_inductive() {
                    self.n_j.max(1) // must decompose into 1D commands
                } else {
                    1
                }
            }
            Capability::RI | Capability::RII => 1,
        }
    }
}

/// Decompose a 2D (possibly inductive) pattern into per-row 1D patterns
/// — what a rectangular-only (RR-capable or weaker) ISA must issue
/// (paper Fig 11). Used by the `inductive: false` ablation lowering.
pub fn decompose_rows(pat: &Pattern2D) -> Vec<Pattern2D> {
    (0..pat.n_j)
        .filter_map(|j| {
            let len = pat.len_at(j);
            (len > 0).then(|| Pattern2D::strided(pat.addr(j, 0), pat.c_i, len))
        })
        .collect()
}

/// Element position flags the stream control unit tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElemFlags {
    pub j: i64,
    pub i: i64,
    pub first_of_row: bool,
    pub last_of_row: bool,
    pub last: bool,
}

pub struct PatternIter<'a> {
    pat: &'a Pattern2D,
    j: i64,
    i: i64,
    cur_len: i64,
}

impl Iterator for PatternIter<'_> {
    type Item = (i64, ElemFlags);

    fn next(&mut self) -> Option<Self::Item> {
        // Skip empty rows.
        while self.j < self.pat.n_j && self.cur_len == 0 {
            self.j += 1;
            self.i = 0;
            self.cur_len = self.pat.len_at(self.j);
        }
        if self.j >= self.pat.n_j {
            return None;
        }
        let addr = self.pat.addr(self.j, self.i);
        let last_of_row = self.i == self.cur_len - 1;
        let flags = ElemFlags {
            j: self.j,
            i: self.i,
            first_of_row: self.i == 0,
            last_of_row,
            last: false, // fixed up below
        };
        self.i += 1;
        if self.i >= self.cur_len {
            self.j += 1;
            self.i = 0;
            self.cur_len = if self.j < self.pat.n_j { self.pat.len_at(self.j) } else { 0 };
        }
        // `last` = no more elements remain.
        let mut done = self.j >= self.pat.n_j;
        if !done && self.cur_len == 0 {
            // peek: all remaining rows empty?
            done = (self.j..self.pat.n_j).all(|j| self.pat.len_at(j) == 0);
        }
        Some((addr, ElemFlags { last: done, ..flags }))
    }
}

/// Stream address-generation capability classes (paper Fig 21/22).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Capability {
    /// Plain vector instructions of width w.
    V(usize),
    /// 1D streams.
    R,
    /// 2D rectangular streams.
    RR,
    /// 2D with inductive inner dimension (REVEL).
    RI,
    /// 3D rectangular.
    RRR,
    /// 3D with inductive dimensions.
    RII,
}

impl std::fmt::Display for Capability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Capability::V(w) => write!(f, "V{w}"),
            Capability::R => write!(f, "R"),
            Capability::RR => write!(f, "RR"),
            Capability::RI => write!(f, "RI"),
            Capability::RRR => write!(f, "RRR"),
            Capability::RII => write!(f, "RII"),
        }
    }
}

/// Constant-value pattern for the `Const` command (paper Table 1):
/// per outer iteration j, emit `val1` len1(j) times then `val2` len2(j)
/// times, with independent stretches. Used for inductive control flow
/// inside dataflow graphs (e.g. accumulator-emit gating).
#[derive(Clone, Debug, PartialEq)]
pub struct ConstPattern {
    pub val1: f64,
    pub n1: f64,
    pub s1: f64,
    pub val2: f64,
    pub n2: f64,
    pub s2: f64,
    pub n_j: i64,
}

impl ConstPattern {
    /// Uniform stream of one value, n times.
    pub fn scalar(val: f64, n: i64) -> Self {
        Self { val1: val, n1: n as f64, s1: 0.0, val2: 0.0, n2: 0.0, s2: 0.0, n_j: 1 }
    }

    /// Per row j: one `val1` then (len(j)-1) `val2`s — the "first element
    /// of each row" gate used by Cholesky's loop-carried dependence.
    pub fn first_of_row(val1: f64, val2: f64, n_i: f64, n_j: i64, s: f64) -> Self {
        Self { val1, n1: 1.0, s1: 0.0, val2, n2: n_i - 1.0, s2: s, n_j }
    }

    /// Per row j: (len(j)-1) `val2`s then one `val1` — "last element of
    /// each row" gate (accumulator emit).
    pub fn last_of_row(val1: f64, val2: f64, n_i: f64, n_j: i64, s: f64) -> Self {
        Self { val1: val2, n1: n_i - 1.0, s1: s, val2: val1, n2: 1.0, s2: 0.0, n_j }
    }

    pub fn len1_at(&self, j: i64) -> i64 {
        (self.n1 + self.s1 * j as f64).round().max(0.0) as i64
    }

    pub fn len2_at(&self, j: i64) -> i64 {
        (self.n2 + self.s2 * j as f64).round().max(0.0) as i64
    }

    pub fn total_len(&self) -> i64 {
        (0..self.n_j).map(|j| self.len1_at(j) + self.len2_at(j)).sum()
    }

    /// Port instances at width `w` (rows chunked, never merged).
    pub fn instances(&self, w: usize) -> i64 {
        let w = w.max(1) as i64;
        (0..self.n_j)
            .map(|j| {
                let len = self.len1_at(j) + self.len2_at(j);
                (len + w - 1) / w
            })
            .sum()
    }

    /// Materialize all values (used by the stream control unit and tests).
    pub fn values(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for j in 0..self.n_j {
            for _ in 0..self.len1_at(j) {
                out.push(self.val1);
            }
            for _ in 0..self.len2_at(j) {
                out.push(self.val2);
            }
        }
        out
    }
}

/// Data-reuse configuration on an input port (paper Feature 2): arriving
/// element t is presented `r_t` times before being popped, with
/// `r_0 = n_r` and `r_{t+1} = r_t + s_r`. Fractional values accumulate
/// (vectorized consumers divide the rate by the vector width).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Reuse {
    pub n_r: f64,
    pub s_r: f64,
}

impl Reuse {
    pub fn uniform(n: f64) -> Self {
        Self { n_r: n, s_r: 0.0 }
    }

    /// Presentation count of the t-th element (>= 1 while stream live).
    pub fn count_at(&self, t: i64) -> i64 {
        (self.n_r + self.s_r * t as f64).round().max(1.0) as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_pattern_covers_matrix_row_major() {
        let p = Pattern2D::rect(0, 1, 4, 8, 3);
        let addrs: Vec<i64> = p.iter().map(|(a, _)| a).collect();
        assert_eq!(addrs, vec![0, 1, 2, 3, 8, 9, 10, 11, 16, 17, 18, 19]);
        assert_eq!(p.total_len(), 12);
        assert!(!p.is_inductive());
    }

    #[test]
    fn inductive_pattern_shrinks_like_cholesky_trailing() {
        // Triangular: row j covers len 4-j starting at diagonal offset.
        let p = Pattern2D::inductive(0, 1, 4.0, 5, 4, -1.0);
        let rows: Vec<i64> = (0..4).map(|j| p.len_at(j)).collect();
        assert_eq!(rows, vec![4, 3, 2, 1]);
        assert_eq!(p.total_len(), 10);
        let addrs: Vec<i64> = p.iter().map(|(a, _)| a).collect();
        assert_eq!(addrs, vec![0, 1, 2, 3, 5, 6, 7, 10, 11, 15]);
    }

    #[test]
    fn pattern_flags_mark_row_boundaries_and_last() {
        let p = Pattern2D::inductive(0, 1, 2.0, 10, 3, -1.0); // lens 2,1,0
        let v: Vec<(i64, ElemFlags)> = p.iter().collect();
        assert_eq!(v.len(), 3);
        assert!(v[0].1.first_of_row && !v[0].1.last_of_row);
        assert!(v[1].1.last_of_row && !v[1].1.last);
        assert!(v[2].1.first_of_row && v[2].1.last_of_row && v[2].1.last);
    }

    #[test]
    fn empty_rows_are_skipped() {
        let p = Pattern2D::inductive(0, 1, 1.0, 1, 3, -1.0); // lens 1,0,0
        let v: Vec<i64> = p.iter().map(|(a, _)| a).collect();
        assert_eq!(v, vec![0]);
        assert_eq!(p.total_len(), 1);
    }

    #[test]
    fn commands_needed_matches_fig11_accounting() {
        // Solver-like triangular read: n=8 outer iters, shrinking rows.
        let p = Pattern2D::inductive(0, 1, 8.0, 9, 8, -1.0);
        assert_eq!(p.commands_needed(Capability::RI), 1);
        assert_eq!(p.commands_needed(Capability::RR), 8); // decompose rows
        assert_eq!(p.commands_needed(Capability::R), 8);
        // Vector width 4 over rows 8,7,..,1 = ceil each / 4.
        let v: i64 = (1..=8).map(|l: i64| (l as f64 / 4.0).ceil() as i64).sum();
        assert_eq!(p.commands_needed(Capability::V(4)), v);
        // Rectangular pattern is one RR command.
        let r = Pattern2D::rect(0, 1, 8, 8, 8);
        assert_eq!(r.commands_needed(Capability::RR), 1);
    }

    #[test]
    fn const_pattern_gates() {
        let g = ConstPattern::first_of_row(1.0, 0.0, 3.0, 3, -1.0);
        // rows: len 3 -> 1,0,0 ; len 2 -> 1,0 ; len 1 -> 1
        assert_eq!(g.values(), vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        let e = ConstPattern::last_of_row(1.0, 0.0, 3.0, 2, 0.0);
        assert_eq!(e.values(), vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        assert_eq!(ConstPattern::scalar(7.0, 3).values(), vec![7.0; 3]);
    }

    #[test]
    fn reuse_counts_stretch() {
        // Solver: x_j reused n-1-j times, n=8: 7,6,5,...
        let r = Reuse { n_r: 7.0, s_r: -1.0 };
        assert_eq!(r.count_at(0), 7);
        assert_eq!(r.count_at(3), 4);
        assert_eq!(r.count_at(20), 1); // clamped
    }

    #[test]
    fn fractional_stretch_rounds_like_fixed_point() {
        // Vectorized by 4: stretch -1/4.
        let p = Pattern2D::inductive(0, 1, 2.0, 0, 8, -0.25);
        let lens: Vec<i64> = (0..8).map(|j| p.len_at(j)).collect();
        assert_eq!(lens, vec![2, 2, 2, 1, 1, 1, 1, 0]);
    }
}
