//! REVEL ISA (paper §5): stream patterns, data reuse, and the
//! vector-stream command set the control core executes.

pub mod command;
pub mod pattern;

pub use command::{
    program_stats, Cmd, LaneMask, Program, ProgramStats, VsCommand, XferDst,
    NUM_LANES,
};
pub use pattern::{
    decompose_rows, Capability, ConstPattern, ElemFlags, Pattern2D, Reuse,
};
