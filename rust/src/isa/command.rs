//! REVEL's vector-stream control commands (paper Table 1).
//!
//! A single Von Neumann control program coordinates all lanes: every
//! command carries a **lane bitmask** selecting the lanes it is broadcast
//! to, and a **lane stride** that offsets addresses by the lane index —
//! the "vector-stream control" paradigm that amortizes control both in
//! space (across lanes) and in time (across stream iterations).

use std::sync::Arc;

use super::pattern::{ConstPattern, Pattern2D, Reuse};
use crate::compiler::Configured;

/// Number of lanes in a REVEL unit (paper Table 3).
pub const NUM_LANES: usize = 8;

/// Bitmask over lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneMask(pub u8);

impl LaneMask {
    pub const ALL: LaneMask = LaneMask(0xFF);

    pub fn one(lane: usize) -> Self {
        LaneMask(1 << lane)
    }

    pub fn first_n(n: usize) -> Self {
        LaneMask(if n >= 8 { 0xFF } else { (1u8 << n) - 1 })
    }

    pub fn contains(&self, lane: usize) -> bool {
        self.0 & (1 << lane) != 0
    }

    pub fn lanes(&self) -> impl Iterator<Item = usize> + '_ {
        (0..NUM_LANES).filter(move |&l| self.contains(l))
    }

    pub fn count(&self) -> usize {
        self.0.count_ones() as usize
    }
}

/// Destination of an XFER stream relative to the source lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XferDst {
    /// Same lane (dataflow-to-dataflow forwarding).
    Local,
    /// Neighbor lane at +offset (mod NUM_LANES).
    Lane(i8),
    /// Replicate each element to the given set of lanes' input ports
    /// (bus serializes: one destination per cycle). Used by the
    /// latency-optimized factorizations to broadcast pivot columns.
    Bcast(LaneMask),
}

/// One vector-stream command body (paper Table 1).
#[derive(Clone, Debug)]
pub enum Cmd {
    /// Broadcast a fabric + port configuration (pre-compiled placement)
    /// to the lane.
    Configure(Arc<Configured>),
    /// Local scratchpad -> input port stream.
    LocalLd {
        pat: Pattern2D,
        port: usize,
        /// Port-side data reuse (paper Feature 2); None = destructive read.
        reuse: Option<Reuse>,
        /// Implicit vector masking of partial vectors (paper Feature 4).
        /// When false, non-width-divisible remainders are delivered as
        /// scalar (width-1) instances — the non-FGOP baseline behaviour.
        masked: bool,
        /// In-place RMW partner of an rmw store over the same range:
        /// `Some(lag)` skips the conservative issue-level interlock and
        /// applies element-level ordering instead (issue the store
        /// command *before* this load). `lag` is the outer-row distance
        /// of the cross-iteration RAW: row j of the load reads what the
        /// store produced in row j-lag (solver: 1). `Some(0)` = the pair
        /// touches disjoint addresses row-by-row (in-place trailing
        /// updates): no load-side wait. See `Cmd::LocalSt::rmw`.
        rmw: Option<u8>,
    },
    /// Output port -> local scratchpad stream. `rmw` marks the store as
    /// the in-place read-modify-write partner of a concurrently active
    /// load over the same range: the lane's memory-ordering logic then
    /// applies element-level ordering (store trails the load) instead of
    /// blocking the store until the load completes (paper §6.1: the
    /// command queue "is responsible for maintaining data ordering").
    LocalSt { pat: Pattern2D, port: usize, rmw: bool },
    /// Constant pattern -> input port (inductive control flow).
    ConstSt { pat: ConstPattern, port: usize },
    /// Output port -> input port stream (fine-grain ordered dependence),
    /// same lane or remote (paper XFER unit).
    Xfer {
        src_port: usize,
        dst_port: usize,
        dst: XferDst,
        /// Number of elements to transfer.
        n: i64,
        /// Reuse applied at the destination port.
        reuse: Option<Reuse>,
    },
    /// Shared scratchpad -> local scratchpad (words copied in pattern
    /// order, packed contiguously at `local_addr`).
    SharedLd { pat: Pattern2D, shared_addr: i64, local_addr: i64 },
    /// Local scratchpad -> shared scratchpad.
    SharedSt { pat: Pattern2D, local_addr: i64, shared_addr: i64 },
    /// Scratchpad barrier: later commands for this lane wait until all
    /// earlier streams complete (paper Barrier_Ld/St; used for double
    /// buffering and for the no-fine-grain-dependence ablation).
    Barrier,
    /// Control core blocks until all masked lanes are idle.
    Wait,
}

/// A command plus its lane bitmask and per-lane address stride.
#[derive(Clone, Debug)]
pub struct VsCommand {
    pub cmd: Cmd,
    pub lanes: LaneMask,
    /// Address offset added per lane index (paper: "a lane's index can be
    /// used to offset the address of a command").
    pub lane_stride: i64,
}

impl VsCommand {
    pub fn new(cmd: Cmd, lanes: LaneMask) -> Self {
        Self { cmd, lanes, lane_stride: 0 }
    }

    pub fn with_stride(cmd: Cmd, lanes: LaneMask, lane_stride: i64) -> Self {
        Self { cmd, lanes, lane_stride }
    }

    /// Cycles the (single-issue, 5-stage RISCV-like) control core spends
    /// computing this command's parameters and enqueueing the broadcast.
    /// Calibrated to a handful of scalar instructions per command — the
    /// quantity Fig 11 counts and Fig 22 reports per-iteration.
    pub fn ctrl_cost(&self) -> u64 {
        match &self.cmd {
            Cmd::Configure(_) => 6,
            Cmd::LocalLd { pat, .. } => 3 + pat_params(pat),
            Cmd::LocalSt { pat, .. } => 3 + pat_params(pat),
            Cmd::ConstSt { .. } => 3,
            Cmd::Xfer { .. } => 3,
            Cmd::SharedLd { pat, .. } | Cmd::SharedSt { pat, .. } => 3 + pat_params(pat),
            Cmd::Barrier => 1,
            Cmd::Wait => 1,
        }
    }
}

fn pat_params(p: &Pattern2D) -> u64 {
    let mut c = 0;
    if p.n_j > 1 {
        c += 2; // c_j, n_j
    }
    if p.s_ji != 0.0 {
        c += 1; // stretch register
    }
    c
}

/// A full control program (what the control core executes).
pub type Program = Vec<VsCommand>;

/// Static control statistics of a program (Fig 11-style accounting).
pub struct ProgramStats {
    pub commands: usize,
    pub ctrl_cycles: u64,
    pub stream_elems: i64,
}

pub fn program_stats(prog: &Program) -> ProgramStats {
    let mut stream_elems = 0i64;
    let mut ctrl_cycles = 0u64;
    for c in prog {
        ctrl_cycles += c.ctrl_cost();
        let e = match &c.cmd {
            Cmd::LocalLd { pat, .. } | Cmd::LocalSt { pat, .. } => pat.total_len(),
            Cmd::ConstSt { pat, .. } => pat.total_len(),
            Cmd::Xfer { n, .. } => *n,
            Cmd::SharedLd { pat, .. } | Cmd::SharedSt { pat, .. } => pat.total_len(),
            _ => 0,
        };
        stream_elems += e * c.lanes.count() as i64;
    }
    ProgramStats { commands: prog.len(), ctrl_cycles, stream_elems }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_mask_ops() {
        let m = LaneMask::first_n(3);
        assert_eq!(m.count(), 3);
        assert!(m.contains(0) && m.contains(2) && !m.contains(3));
        assert_eq!(LaneMask::one(7).lanes().collect::<Vec<_>>(), vec![7]);
        assert_eq!(LaneMask::ALL.count(), 8);
    }

    #[test]
    fn ctrl_cost_rewards_inductive_encoding() {
        // One inductive command vs n rectangular rows: Fig 11's 8 vs 3+5n.
        let n = 16i64;
        let ind = VsCommand::new(
            Cmd::LocalLd {
                pat: Pattern2D::inductive(0, 1, n as f64, n + 1, n, -1.0),
                port: 0,
                reuse: None,
                masked: true,
                rmw: None,
            },
            LaneMask::one(0),
        );
        let per_row_cost: u64 = (0..n)
            .map(|j| {
                VsCommand::new(
                    Cmd::LocalLd {
                        pat: Pattern2D::lin(j * (n + 1), n - j),
                        port: 0,
                        reuse: None,
                        masked: true,
                        rmw: None,
                    },
                    LaneMask::one(0),
                )
                .ctrl_cost()
            })
            .sum();
        assert!(ind.ctrl_cost() as i64 * 4 < per_row_cost as i64);
    }

    #[test]
    fn program_stats_counts_elements_per_lane() {
        let prog: Program = vec![
            VsCommand::new(
                Cmd::LocalLd {
                    pat: Pattern2D::lin(0, 10),
                    port: 0,
                    reuse: None,
                    masked: true,
                    rmw: None,
                },
                LaneMask::first_n(2),
            ),
            VsCommand::new(Cmd::Wait, LaneMask::ALL),
        ];
        let s = program_stats(&prog);
        assert_eq!(s.commands, 2);
        assert_eq!(s.stream_elems, 20);
        assert!(s.ctrl_cycles >= 4);
    }
}
