//! Sharded co-simulation: conservative parallel DES over per-cell
//! [`super::cosim::CosimSession`]s.
//!
//! A metro-scale serve run holds N cells, each a full co-simulated
//! cluster on its own calendar. Cells are partitioned into `shards`
//! by the **fixed** mapping `cell -> cell % shards`, and each shard
//! advances its cells on a worker-pool thread
//! ([`crate::harness::pool::scope`]) between conservative
//! synchronization horizons:
//!
//! ```text
//!   round k:   barrier ── every shard drains its cells' calendars
//!              up to horizon h_k (strictly-before, FIFO intact) ── barrier
//!   round k+1: h_{k+1} = earliest pending event + window
//! ```
//!
//! **Why any horizon is safe.** Classic conservative (CMB-style)
//! parallel simulation may only process an event once no other shard
//! can still send one earlier; the distance other shards must respect
//! is the *lookahead*. Here the cheapest cross-cluster interaction is
//! one inter-stage handoff on a shared interconnect, so the lookahead
//! bound is `min` [`crate::model::handoff_s`] over the mix's stage
//! chains ([`ShardPlan::lookahead_s`]), and [`ShardPlan`] asserts the
//! window respects that floor. Today's cells exchange **no** events —
//! each is an independent traffic domain — so every horizon is
//! trivially conservative and the window only trades barrier overhead
//! against merge granularity; the lookahead floor is what becomes
//! load-bearing the day cross-cell coupling (inter-cell handover,
//! fronthaul sharing) lands.
//!
//! **Why results are bit-deterministic under any shard→thread
//! mapping.** Each session is deterministic in (cell config, seed) and
//! touches no shared mutable state; shards only decide *where* a cell
//! advances, never *what* it observes. The runner returns runs in cell
//! order, and the serve layer merges them in that same fixed order —
//! so artifacts are byte-identical across `shards` ∈ {1, 2, 8, …},
//! pinned by `tests/cosim_equivalence.rs` and the CI serve-smoke diff.

use crate::harness::pool;
use crate::model;

use super::cosim::{CosimClass, CosimRun, CosimSession};

/// How a multi-cell co-simulation is driven: shard count plus the
/// horizon window, with the conservative lookahead floor it respects.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardPlan {
    /// Worker shards (clamped to the cell count by the runner).
    pub shards: usize,
    /// Virtual seconds per synchronization window.
    pub horizon_s: f64,
    /// Conservative-DES lookahead bound: the cheapest inter-stage
    /// handoff in the mix. `horizon_s >= lookahead_s` always.
    pub lookahead_s: f64,
}

impl ShardPlan {
    /// Minimum virtual seconds before any cross-cluster interaction
    /// could take effect: the cheapest handoff a multi-stage chain in
    /// `mix` puts on a shared interconnect, floored at one bus cycle
    /// when the mix has no handoffs at all.
    pub fn lookahead_s(mix: &[Option<CosimClass>]) -> f64 {
        let one_cycle = model::cycles_to_us(1) * 1e-6;
        mix.iter()
            .flatten()
            .flat_map(|c| c.stages.windows(2))
            .map(|w| model::handoff_s(&w[1].kernel, w[1].n))
            .fold(one_cycle, f64::min)
            .max(one_cycle)
    }

    /// Plan for `shards` workers over a metro whose union job mix is
    /// `mix`: the window is one longest-job's demand — coarse enough
    /// that a run takes a handful of windows, well above the lookahead
    /// floor (asserted).
    pub fn for_mix(shards: usize, mix: &[Option<CosimClass>]) -> ShardPlan {
        let lookahead_s = Self::lookahead_s(mix);
        let horizon_s = mix
            .iter()
            .flatten()
            .map(CosimClass::demand_s)
            .fold(0.0f64, f64::max)
            .max(lookahead_s);
        assert!(
            horizon_s >= lookahead_s,
            "horizon {horizon_s} violates the conservative lookahead {lookahead_s}"
        );
        ShardPlan { shards: shards.max(1), horizon_s, lookahead_s }
    }
}

/// Drive every cell session to completion under `plan` and return the
/// per-cell runs **in cell order** (index-aligned with `sessions`).
/// Bit-identical for any `plan.shards` and any window: sessions never
/// interact, and within a cell events replay in single-timeline order.
pub fn run_sharded(sessions: Vec<CosimSession<'_>>, plan: &ShardPlan) -> Vec<CosimRun> {
    struct Slot<'a> {
        cell: usize,
        session: CosimSession<'a>,
        drained: bool,
    }
    let n = sessions.len();
    let shards = plan.shards.max(1).min(n.max(1));
    let window =
        if plan.horizon_s.is_finite() && plan.horizon_s > 0.0 { plan.horizon_s } else { f64::INFINITY };
    // Fixed cell→shard mapping: round-robin by cell index. Results do
    // not depend on it (cells are independent); only wall time does.
    let mut groups: Vec<Vec<Slot<'_>>> = (0..shards).map(|_| Vec::new()).collect();
    for (cell, session) in sessions.into_iter().enumerate() {
        groups[cell % shards].push(Slot { cell, session, drained: false });
    }
    loop {
        // Next horizon: one window past the earliest pending event, so
        // every round retires at least one event and the loop is
        // guaranteed to terminate (no event is ever scheduled in its
        // creator's past).
        let earliest = groups
            .iter()
            .flat_map(|g| g.iter())
            .filter(|s| !s.drained)
            .filter_map(|s| s.session.next_time())
            .fold(f64::INFINITY, f64::min);
        if !earliest.is_finite() {
            break;
        }
        let horizon = earliest + window;
        if shards == 1 {
            // One shard is the single-timeline engine, on this thread.
            for slot in groups[0].iter_mut().filter(|s| !s.drained) {
                slot.drained = slot.session.advance_to(horizon);
            }
        } else {
            pool::scope(shards, |s| {
                for group in groups.iter_mut() {
                    s.spawn(move || {
                        for slot in group.iter_mut().filter(|s| !s.drained) {
                            slot.drained = slot.session.advance_to(horizon);
                        }
                    });
                }
            });
        }
    }
    let mut out: Vec<Option<CosimRun>> = (0..n).map(|_| None).collect();
    for slot in groups.into_iter().flatten() {
        out[slot.cell] = Some(slot.session.finish());
    }
    out.into_iter().map(|r| r.expect("every cell ran")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::{Arrival, ClusterConfig, Workload};
    use crate::coordinator::cosim::{self, CosimConfig, StageTask};
    use crate::harness;
    use crate::workloads::{Features, Goal};

    fn est_s(kernel: &str, n: usize) -> f64 {
        model::cycles_to_us(harness::cycles(kernel, n, Features::ALL, Goal::Latency).unwrap())
            * 1e-6
    }

    fn mix() -> Vec<Option<CosimClass>> {
        let two = CosimClass {
            stages: vec![
                StageTask { kernel: "solver".into(), n: 8, est_s: est_s("solver", 8) },
                StageTask { kernel: "gemm".into(), n: 12, est_s: est_s("gemm", 12) },
            ],
        };
        let one = CosimClass {
            stages: vec![StageTask {
                kernel: "solver".into(),
                n: 12,
                est_s: est_s("solver", 12),
            }],
        };
        vec![Some(two), Some(one)]
    }

    #[test]
    fn plan_respects_the_lookahead_floor() {
        let mix = mix();
        let plan = ShardPlan::for_mix(4, &mix);
        assert!(plan.lookahead_s > 0.0);
        assert!(plan.horizon_s >= plan.lookahead_s);
        // The lookahead is the cheapest handoff in the mix: gemm n=12.
        assert_eq!(plan.lookahead_s, model::handoff_s("gemm", 12));
        // A mix with no handoffs floors at one bus cycle.
        let single = vec![mix[1].clone()];
        assert_eq!(
            ShardPlan::lookahead_s(&single),
            model::cycles_to_us(1) * 1e-6
        );
    }

    #[test]
    fn sharded_runs_are_bit_identical_for_any_shard_count() {
        let mix = mix();
        let cfg = CosimConfig {
            cluster: ClusterConfig { units: 2, queue_cap: 8, admit_cap: 64 },
            deadline_s: None,
        };
        let traces: Vec<Vec<Arrival>> = (0..5)
            .map(|cell| {
                (0..6)
                    .map(|i| Arrival {
                        id: i as u64,
                        class: (i + cell) % 2,
                        t_s: 0.0,
                    })
                    .collect()
            })
            .collect();
        // The single-timeline oracle: each cell run to completion alone.
        let solo: Vec<CosimRun> = traces
            .iter()
            .map(|t| cosim::run(&cfg, &mix, Workload::Open(t), || 0))
            .collect();
        for shards in [1usize, 2, 3, 8] {
            let plan = ShardPlan::for_mix(shards, &mix);
            let sessions: Vec<CosimSession<'_>> = traces
                .iter()
                .map(|t| CosimSession::new(&cfg, &mix, Workload::Open(t), || 0))
                .collect();
            let runs = run_sharded(sessions, &plan);
            assert_eq!(runs, solo, "shards={shards} must be bit-identical");
        }
    }
}
