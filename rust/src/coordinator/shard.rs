//! Sharded co-simulation: conservative parallel DES over per-cell
//! [`super::cosim::CosimSession`]s, with cross-cell coupling.
//!
//! A metro-scale serve run holds N cells, each a full co-simulated
//! cluster on its own calendar. Cells are partitioned into `shards`
//! contiguous groups, and each shard advances its cells on a
//! worker-pool thread ([`crate::harness::pool::scope`]) between
//! conservative synchronization horizons:
//!
//! ```text
//!   round k:   barrier ── every shard drains its cells' calendars
//!              up to horizon h_k (strictly-before, FIFO intact)
//!              ── barrier ── exchange cross-cell messages, in cell
//!              order ── repeat with h_{k+1} = earliest pending + W
//! ```
//!
//! **Why the window bound is the fronthaul latency.** Classic
//! conservative (Chandy–Misra–Bryant) parallel simulation may only
//! process an event once no other shard can still send one earlier;
//! the distance other shards must respect is the *lookahead*. With
//! cross-cell coupling ([`super::cosim::Coupling`]) the cheapest
//! inter-cell interaction is one fronthaul traversal of latency `F`:
//! a message emitted while round `k` processes events in
//! `[earliest_k, h_k)` is stamped `t_send + F >= earliest_k + F`, so
//! with window `W = h_k - earliest_k <= F` every delivery lands at or
//! after `h_k` — in the receiver's strict future, because
//! `pop_before` is strictly-before. [`ShardPlan::for_metro`] sets
//! `W = F` exactly (the largest safe window); `F` itself is floored
//! at the mix's cheapest [`crate::model::handoff_s`] (a fronthaul
//! cannot beat the on-die interconnect), which is the
//! [`ShardPlan::lookahead_s`] bound. An uncoupled metro exchanges no
//! events, so any window is safe and [`ShardPlan::for_mix`] picks a
//! coarse one (one longest-job demand) purely for barrier economy.
//!
//! **Why results are bit-deterministic under any shard→thread
//! mapping.** Horizons are computed globally (the minimum pending
//! event over *all* cells), sessions share no state while a round
//! runs, and cross-cell messages are exchanged only at barriers, in
//! canonical order — source cell order, emit order within a source —
//! for every shard count including one. Shards only decide *where* a
//! cell advances, never *what* it observes. The runner returns runs
//! in cell order and the serve layer merges them in that same fixed
//! order, so artifacts are byte-identical across `shards` ∈ {1, 2,
//! 8, …}, pinned by `tests/cosim_equivalence.rs`, `tests/coupling.rs`
//! (which also proves the bound is *load-bearing* via
//! [`ShardPlan::with_unchecked_horizon`]), and the CI serve-smoke
//! diffs.

use crate::harness::pool;
use crate::model;
use crate::runtime::RtError;

use super::cosim::{CosimClass, CosimRun, CosimSession, Outbound};

/// How a multi-cell co-simulation is driven: shard count plus the
/// horizon window, with the conservative lookahead floor it respects.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardPlan {
    /// Worker shards (clamped to the cell count by the runner).
    pub shards: usize,
    /// Virtual seconds per synchronization window. For a coupled
    /// metro this must not exceed `lookahead_s` (it is set equal);
    /// uncoupled metros may use any window.
    pub horizon_s: f64,
    /// Conservative-DES lookahead bound: the fronthaul latency for a
    /// coupled metro, else the cheapest inter-stage handoff in the
    /// mix. Always finite and positive.
    pub lookahead_s: f64,
}

impl ShardPlan {
    /// Minimum virtual seconds before any cross-cluster interaction
    /// could take effect: the cheapest handoff a multi-stage chain in
    /// `mix` puts on a shared interconnect. **Floor contract:** the
    /// result is always finite and at least one bus cycle
    /// (`model::cycles_to_us(1) * 1e-6`) — an empty mix, an all-`None`
    /// (fully degraded) mix, or a mix of single-stage chains has no
    /// handoffs at all, and the floor keeps the plan finite instead of
    /// panicking or degenerating to a zero/∞ window.
    pub fn lookahead_s(mix: &[Option<CosimClass>]) -> f64 {
        let one_cycle = model::cycles_to_us(1) * 1e-6;
        mix.iter()
            .flatten()
            .flat_map(|c| c.stages.windows(2))
            .map(|w| model::handoff_s(&w[1].kernel, w[1].n))
            .fold(one_cycle, f64::min)
            .max(one_cycle)
    }

    /// Plan for `shards` workers over an **uncoupled** metro whose
    /// union job mix is `mix`: cells exchange no events, so the window
    /// is one longest-job's demand — coarse enough that a run takes a
    /// handful of windows, and clamped back to the (finite, positive)
    /// lookahead floor if the mix's demand estimates are degenerate
    /// (empty, all-`None`, or non-finite `est_s`).
    pub fn for_mix(shards: usize, mix: &[Option<CosimClass>]) -> ShardPlan {
        let lookahead_s = Self::lookahead_s(mix);
        let demand = mix
            .iter()
            .flatten()
            .map(CosimClass::demand_s)
            .fold(0.0f64, f64::max);
        let horizon_s =
            if demand.is_finite() { demand.max(lookahead_s) } else { lookahead_s };
        assert!(
            horizon_s >= lookahead_s,
            "horizon {horizon_s} violates the conservative lookahead {lookahead_s}"
        );
        ShardPlan { shards: shards.max(1), horizon_s, lookahead_s }
    }

    /// Plan for a **coupled** metro: cells exchange fronthaul messages
    /// of latency `fronthaul_s`, so the conservative window is exactly
    /// that latency — the largest window that still delivers every
    /// message in its receiver's future (see the module docs for the
    /// bound). A sub-floor fronthaul (notably `--fronthaul-us 0`, the
    /// "co-located cells" degenerate case) is floored at the mix's
    /// [`ShardPlan::lookahead_s`] — one bus cycle at minimum — because
    /// a fronthaul cannot beat the on-die interconnect and a zero
    /// window would retire one event per round forever. Negative or
    /// non-finite latencies are caller bugs and panic. `None` means
    /// uncoupled and delegates to [`ShardPlan::for_mix`].
    pub fn for_metro(
        shards: usize,
        mix: &[Option<CosimClass>],
        fronthaul_s: Option<f64>,
    ) -> ShardPlan {
        let Some(f) = fronthaul_s else { return Self::for_mix(shards, mix) };
        assert!(
            f.is_finite() && f >= 0.0,
            "fronthaul {f} must be a finite, non-negative latency"
        );
        let la = f.max(Self::lookahead_s(mix));
        let plan = ShardPlan { shards: shards.max(1), horizon_s: la, lookahead_s: la };
        debug_assert!(
            plan.horizon_s <= plan.lookahead_s,
            "window {} violates the conservative lookahead {}",
            plan.horizon_s,
            plan.lookahead_s
        );
        plan
    }

    /// **Test-only escape hatch**: replace the window with one that
    /// may violate the conservative lookahead bound, bypassing every
    /// safety assertion. A coupled metro driven with `horizon_s >
    /// lookahead_s` delivers fronthaul messages into receivers' pasts
    /// — counted as `causality_violations` and processed late — and
    /// its reports diverge from a correctly-windowed run. The canary
    /// suite (`tests/coupling.rs`) uses exactly this to prove the
    /// bound is load-bearing rather than vacuous. Never use outside
    /// tests.
    pub fn with_unchecked_horizon(mut self, horizon_s: f64) -> ShardPlan {
        self.horizon_s = horizon_s;
        self
    }
}

/// Drive every cell session to completion under `plan` and return the
/// per-cell runs **in cell order** (index-aligned with `sessions`).
///
/// Rounds alternate compute and exchange: all sessions advance to the
/// global horizon (in parallel across shards), then — at the barrier —
/// every outbox is drained in cell order and delivered. Re-offered
/// arrivals (`dst: None`) are routed here, to the cell with the least
/// [`CosimSession::backlog_s`] at the horizon (ties to the lowest
/// index), so the routing decision is made on horizon-consistent
/// metro state identically for every shard count.
///
/// Bit-identical for any `plan.shards`: the exchange happens at the
/// same virtual times with the same canonical ordering whether one
/// thread advances every cell or eight threads advance one each.
///
/// A panicking cell does not abort the process: advancement runs
/// under [`pool::try_scope`], so the error names the dead shard's
/// cell range (`cells a..b`) and its panic payload — what a
/// fault-injection test needs to say *which* cell died.
pub fn run_sharded(
    sessions: Vec<CosimSession<'_>>,
    plan: &ShardPlan,
) -> Result<Vec<CosimRun>, RtError> {
    let mut sessions = sessions;
    let n = sessions.len();
    let shards = plan.shards.max(1).min(n.max(1));
    let window = if plan.horizon_s.is_finite() && plan.horizon_s > 0.0 {
        plan.horizon_s
    } else {
        f64::INFINITY
    };
    // Fixed cell→shard mapping: contiguous chunks of the cell vector
    // (cells [0, c), [c, 2c), …). Results do not depend on it — only
    // where a cell advances does — and chunked borrows let the barrier
    // code below address every session by cell index between rounds.
    let chunk = n.max(1).div_ceil(shards);
    loop {
        // Next horizon: one window past the earliest pending event
        // metro-wide, so every round retires at least one event and
        // the loop terminates (no event is ever scheduled in its
        // creator's past, and cross-cell messages always land at or
        // after the horizon that produced them).
        let earliest = sessions
            .iter()
            .filter_map(|s| s.next_time())
            .fold(f64::INFINITY, f64::min);
        if !earliest.is_finite() {
            break;
        }
        let horizon = earliest + window;
        if shards == 1 {
            // One shard is the single-timeline engine, on this thread.
            // Catch panics here too so the error shape matches the
            // multi-shard path (one labeled RtError, not an abort).
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for session in sessions.iter_mut() {
                    session.advance_to(horizon);
                }
            }));
            if let Err(p) = r {
                return Err(RtError(format!(
                    "worker panic: cells 0..{n}: {}",
                    pool::panic_message(p.as_ref())
                )));
            }
        } else {
            pool::try_scope(shards, |s| {
                for (g, group) in sessions.chunks_mut(chunk).enumerate() {
                    let start = g * chunk;
                    let end = start + group.len();
                    s.spawn(format!("shard {g} (cells {start}..{end})"), move || {
                        for session in group.iter_mut() {
                            session.advance_to(horizon);
                        }
                    });
                }
            })?;
        }
        // Horizon barrier: exchange cross-cell messages in canonical
        // order — source cell order, emit order within a source. The
        // delivery schedule is therefore a pure function of the
        // virtual timeline, independent of the shard→thread mapping.
        let mut msgs: Vec<(usize, Outbound)> = Vec::new();
        for (cell, session) in sessions.iter_mut().enumerate() {
            for out in session.drain_outbox() {
                msgs.push((cell, out));
            }
        }
        for (src, out) in msgs {
            let dst = out.dst.unwrap_or_else(|| {
                // Least-backlogged peer at the horizon; ties break to
                // the lowest cell index.
                let mut best: Option<(f64, usize)> = None;
                for (c, session) in sessions.iter().enumerate() {
                    if c == src {
                        continue;
                    }
                    let b = session.backlog_s(horizon);
                    match best {
                        Some((bb, _)) if b >= bb => {}
                        _ => best = Some((b, c)),
                    }
                }
                best.map_or(src, |(_, c)| c)
            });
            sessions[dst].deliver(out);
        }
    }
    Ok(sessions.into_iter().map(|s| s.finish()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::{Arrival, ClusterConfig, Workload};
    use crate::coordinator::cosim::{self, CosimConfig, StageTask};
    use crate::harness;
    use crate::workloads::{Features, Goal};

    fn est_s(kernel: &str, n: usize) -> f64 {
        model::cycles_to_us(harness::cycles(kernel, n, Features::ALL, Goal::Latency).unwrap())
            * 1e-6
    }

    fn mix() -> Vec<Option<CosimClass>> {
        let two = CosimClass {
            stages: vec![
                StageTask { kernel: "solver".into(), n: 8, est_s: est_s("solver", 8) },
                StageTask { kernel: "gemm".into(), n: 12, est_s: est_s("gemm", 12) },
            ],
        };
        let one = CosimClass {
            stages: vec![StageTask {
                kernel: "solver".into(),
                n: 12,
                est_s: est_s("solver", 12),
            }],
        };
        vec![Some(two), Some(one)]
    }

    #[test]
    fn plan_respects_the_lookahead_floor() {
        let mix = mix();
        let plan = ShardPlan::for_mix(4, &mix);
        assert!(plan.lookahead_s > 0.0);
        assert!(plan.horizon_s >= plan.lookahead_s);
        // The lookahead is the cheapest handoff in the mix: gemm n=12.
        assert_eq!(plan.lookahead_s, model::handoff_s("gemm", 12));
        // A mix with no handoffs floors at one bus cycle.
        let single = vec![mix[1].clone()];
        assert_eq!(
            ShardPlan::lookahead_s(&single),
            model::cycles_to_us(1) * 1e-6
        );
    }

    #[test]
    fn degenerate_mixes_floor_at_one_finite_bus_cycle() {
        let one_cycle = model::cycles_to_us(1) * 1e-6;
        // Empty and fully-degraded (all-None) mixes: no chains at all.
        for mix in [Vec::new(), vec![None, None]] {
            assert_eq!(ShardPlan::lookahead_s(&mix), one_cycle);
            let plan = ShardPlan::for_mix(3, &mix);
            assert_eq!(plan.horizon_s, one_cycle, "window floors at one bus cycle");
            assert_eq!(plan.lookahead_s, one_cycle);
            assert!(plan.horizon_s.is_finite() && plan.horizon_s > 0.0);
        }
        // Non-finite demand estimates clamp back to the floor instead
        // of poisoning the window (∞ would disable coupling safety).
        let bad = vec![Some(CosimClass {
            stages: vec![StageTask {
                kernel: "solver".into(),
                n: 8,
                est_s: f64::INFINITY,
            }],
        })];
        let plan = ShardPlan::for_mix(2, &bad);
        assert!(plan.horizon_s.is_finite());
        assert_eq!(plan.horizon_s, one_cycle);
    }

    #[test]
    fn metro_plan_windows_exactly_the_fronthaul() {
        let mix = mix();
        let floor = ShardPlan::lookahead_s(&mix);
        let f = floor.max(50e-6);
        let plan = ShardPlan::for_metro(4, &mix, Some(f));
        assert_eq!(plan.horizon_s, f, "coupled window == fronthaul latency");
        assert_eq!(plan.lookahead_s, f);
        // Uncoupled delegates to the coarse for_mix window.
        assert_eq!(ShardPlan::for_metro(4, &mix, None), ShardPlan::for_mix(4, &mix));
        // The canary hook really does bypass the bound.
        let canary = plan.with_unchecked_horizon(f * 64.0);
        assert_eq!(canary.horizon_s, f * 64.0);
        assert_eq!(canary.lookahead_s, f);
    }

    #[test]
    fn zero_fronthaul_falls_back_to_the_lookahead_floor() {
        // The "co-located cells" degenerate case: --fronthaul-us 0
        // used to trip the f >= floor assertion; now it floors at the
        // mix's lookahead instead of panicking or windowing at zero.
        let mix = mix();
        let floor = ShardPlan::lookahead_s(&mix);
        let plan = ShardPlan::for_metro(4, &mix, Some(0.0));
        assert_eq!(plan.horizon_s, floor);
        assert_eq!(plan.lookahead_s, floor);
        assert!(plan.horizon_s.is_finite() && plan.horizon_s > 0.0);
        // Any sub-floor latency gets the same clamp.
        let plan = ShardPlan::for_metro(4, &mix, Some(floor / 2.0));
        assert_eq!(plan.horizon_s, floor);
        // A fully-degraded mix floors at one finite bus cycle.
        let degraded: Vec<Option<CosimClass>> = vec![None, None];
        let plan = ShardPlan::for_metro(2, &degraded, Some(0.0));
        assert_eq!(plan.horizon_s, model::cycles_to_us(1) * 1e-6);
    }

    #[test]
    fn sharded_runs_are_bit_identical_for_any_shard_count() {
        let mix = mix();
        let cfg = CosimConfig {
            cluster: ClusterConfig { units: 2, queue_cap: 8, admit_cap: 64 },
            deadline_s: None,
        };
        let traces: Vec<Vec<Arrival>> = (0..5)
            .map(|cell| {
                (0..6)
                    .map(|i| Arrival {
                        id: i as u64,
                        class: (i + cell) % 2,
                        t_s: 0.0,
                    })
                    .collect()
            })
            .collect();
        // The single-timeline oracle: each cell run to completion alone.
        let solo: Vec<CosimRun> = traces
            .iter()
            .map(|t| cosim::run(&cfg, &mix, Workload::Open(t), || 0))
            .collect();
        for shards in [1usize, 2, 3, 8] {
            let plan = ShardPlan::for_mix(shards, &mix);
            let sessions: Vec<CosimSession<'_>> = traces
                .iter()
                .map(|t| CosimSession::new(&cfg, &mix, Workload::Open(t), || 0))
                .collect();
            let runs = run_sharded(sessions, &plan).unwrap();
            assert_eq!(runs, solo, "shards={shards} must be bit-identical");
        }
    }
}
