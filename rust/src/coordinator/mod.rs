//! 5G-baseband pipeline coordinator (paper §2, Fig 4): a multi-threaded
//! serving layer that routes subframe jobs through the receiver chain
//!
//!   FFT (OFDM demod) -> Cholesky (channel estimation) ->
//!   Solver (equalization) -> GEMM (beamforming)
//!
//! across a pool of simulated REVEL units — the L3 "deployment" story:
//! request routing, batching, backpressure, latency accounting. Each
//! worker owns one REVEL unit; jobs carry real data and every stage's
//! simulated output is verified, so the pipeline doubles as an
//! end-to-end correctness test of the whole stack. `golden_check`
//! additionally cross-checks stage results against the AOT-compiled JAX
//! artifacts through PJRT (the L2/L1 layers).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::model;
use crate::util::stats::percentile;
use crate::util::Rng;
use crate::workloads::{self, Features, Goal};

/// One subframe job flowing through the receiver pipeline.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    /// Synthetic arrival time (seconds since trace start).
    pub arrival_s: f64,
}

/// Per-job result: simulated cycles per stage + wall-clock timings.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub stage_cycles: [u64; 4],
    /// End-to-end simulated latency (us at 1.25 GHz).
    pub sim_latency_us: f64,
    /// Wall-clock queueing delay (s).
    pub queue_delay_s: f64,
    pub worker: usize,
}

pub const STAGES: [(&str, usize); 4] =
    [("fft", 64), ("cholesky", 16), ("solver", 16), ("gemm", 12)];

/// Run one job through all four stages on a fresh simulated unit.
fn run_job(job: &Job, worker: usize) -> JobResult {
    let mut stage_cycles = [0u64; 4];
    for (si, (kernel, n)) in STAGES.iter().enumerate() {
        let r = workloads::prepare(kernel, *n, Features::ALL, Goal::Latency)
            .expect("prepare")
            .execute()
            .expect("stage must verify");
        stage_cycles[si] = r.cycles;
    }
    let total: u64 = stage_cycles.iter().sum();
    JobResult {
        id: job.id,
        stage_cycles,
        sim_latency_us: model::cycles_to_us(total),
        queue_delay_s: 0.0,
        worker,
    }
}

/// Bounded job queue with backpressure (producers block when full).
struct Queue {
    q: Mutex<(VecDeque<(Job, Instant)>, bool)>,
    cv: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Self {
        Self { q: Mutex::new((VecDeque::new(), false)), cv: Condvar::new(), cap }
    }

    fn push(&self, job: Job) {
        let mut g = self.q.lock().unwrap();
        while g.0.len() >= self.cap {
            g = self.cv.wait(g).unwrap();
        }
        g.0.push_back((job, Instant::now()));
        self.cv.notify_all();
    }

    fn close(&self) {
        self.q.lock().unwrap().1 = true;
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<(Job, Instant)> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(x) = g.0.pop_front() {
                self.cv.notify_all();
                return Some(x);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Pipeline run summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub jobs: usize,
    pub wall_s: f64,
    pub jobs_per_s: f64,
    pub sim_latency_p50_us: f64,
    pub sim_latency_p99_us: f64,
    pub queue_delay_p99_s: f64,
    pub per_worker: Vec<usize>,
}

/// Serve `n_jobs` Poisson arrivals (rate `lambda` jobs/s wall-clock,
/// 0 = open the floodgates) across `workers` simulated REVEL units.
pub fn serve(n_jobs: usize, workers: usize, lambda: f64, seed: u64) -> Summary {
    let queue = Arc::new(Queue::new(2 * workers.max(1)));
    let results: Arc<Mutex<Vec<JobResult>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..workers {
            let queue = queue.clone();
            let results = results.clone();
            s.spawn(move || {
                while let Some((job, enq)) = queue.pop() {
                    let mut r = run_job(&job, w);
                    r.queue_delay_s = enq.elapsed().as_secs_f64();
                    results.lock().unwrap().push(r);
                }
            });
        }
        // Producer: synthetic arrival trace.
        let mut rng = Rng::new(seed);
        for id in 0..n_jobs {
            if lambda > 0.0 {
                let gap = rng.exp(lambda);
                std::thread::sleep(std::time::Duration::from_secs_f64(gap));
            }
            queue.push(Job { id: id as u64, arrival_s: t0.elapsed().as_secs_f64() });
        }
        queue.close();
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let rs = results.lock().unwrap();
    let lat: Vec<f64> = rs.iter().map(|r| r.sim_latency_us).collect();
    let qd: Vec<f64> = rs.iter().map(|r| r.queue_delay_s).collect();
    let mut per_worker = vec![0usize; workers];
    for r in rs.iter() {
        per_worker[r.worker] += 1;
    }
    Summary {
        jobs: rs.len(),
        wall_s,
        jobs_per_s: rs.len() as f64 / wall_s,
        sim_latency_p50_us: percentile(&lat, 50.0),
        sim_latency_p99_us: percentile(&lat, 99.0),
        queue_delay_p99_s: percentile(&qd, 99.0),
        per_worker,
    }
}

/// Cross-check the pipeline stages against the AOT JAX artifacts via
/// PJRT (the L2/L1 golden model). Returns Err if the artifacts are
/// missing or the binary was built without the `pjrt` feature.
pub fn golden_check() -> crate::runtime::Result<()> {
    use crate::runtime::{Engine, RtError};
    use crate::util::linalg::Mat;
    let ensure = |cond: bool, msg: String| -> crate::runtime::Result<()> {
        if cond {
            Ok(())
        } else {
            Err(RtError(msg))
        }
    };
    let eng = Engine::discover()?;

    // Cholesky 16: simulate and compare against the lowered JAX kernel.
    let inst = workloads::cholesky::instance(16, 0);
    let exe = eng.load("cholesky_n16")?;
    let a32: Vec<f32> = (0..16 * 16)
        .map(|i| inst.a[(i / 16, i % 16)] as f32)
        .collect();
    let out = exe.run_f32(&[a32])?;
    let mut max_err = 0.0f32;
    for i in 0..16 {
        for j in 0..=i {
            let want = inst.l_ref[(i, j)] as f32;
            max_err = max_err.max((out[0][i * 16 + j] - want).abs());
        }
    }
    ensure(max_err < 1e-3, format!("cholesky golden mismatch: {max_err}"))?;

    // Solver 16.
    let sinst = workloads::solver::instance(16, 0);
    let exe = eng.load("solver_n16")?;
    let l32: Vec<f32> = (0..16 * 16)
        .map(|i| sinst.l[(i / 16, i % 16)] as f32)
        .collect();
    let b32: Vec<f32> = sinst.b.iter().map(|&x| x as f32).collect();
    let out = exe.run_f32(&[l32, b32])?;
    for (j, want) in sinst.x_ref.iter().enumerate() {
        ensure(
            (out[0][j] - *want as f32).abs() < 1e-3,
            format!("solver golden mismatch at {j}"),
        )?;
    }

    // GEMM 12.
    let ginst = workloads::gemm::instance(12, 0);
    let exe = eng.load("gemm_m12")?;
    let flat = |m: &Mat| -> Vec<f32> { m.data.iter().map(|&x| x as f32).collect() };
    let out = exe.run_f32(&[flat(&ginst.a), flat(&ginst.b)])?;
    for (i, want) in ginst.c_ref.data.iter().enumerate() {
        ensure(
            (out[0][i] - *want as f32).abs() < 1e-3,
            format!("gemm golden mismatch at {i}"),
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_serves_jobs_and_balances() {
        let s = serve(6, 3, 0.0, 7);
        assert_eq!(s.jobs, 6);
        assert!(s.sim_latency_p50_us > 0.0);
        // All workers should see work under an open-loop flood.
        assert!(s.per_worker.iter().filter(|&&c| c > 0).count() >= 2);
    }

    #[test]
    fn stage_cycles_reported() {
        let r = run_job(&Job { id: 0, arrival_s: 0.0 }, 0);
        assert!(r.stage_cycles.iter().all(|&c| c > 0));
        assert!(r.sim_latency_us > 0.0);
    }
}
