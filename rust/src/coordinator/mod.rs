//! 5G-baseband serving subsystem (paper §2, Fig 4): a cluster of
//! simulated REVEL units serving subframe jobs through the receiver
//! chain
//!
//! ```text
//!   FFT (OFDM demod) -> Cholesky (channel estimation) ->
//!   Solver (equalization) -> GEMM (beamforming)
//! ```
//!
//! — the L3 "deployment" story on top of the reproduction: request
//! routing, stage-level batching, admission control with backpressure,
//! and latency/SLO accounting.
//!
//! The subsystem splits in eight:
//! * [`calendar`] — the shared wake-time calendar both engines
//!   schedule on (one deterministic virtual timeline per cell).
//! * [`cluster`] — the **replay** engine: N units with per-unit
//!   bounded run queues, a least-loaded dispatcher with idle-time work
//!   stealing, and a cluster-wide admission queue that sheds load when
//!   full. Service times are memoized simulated stage cycles at the
//!   REVEL clock; a job occupies its unit for all four stages.
//! * [`cosim`] — the **co-simulation** engine: every unit advances a
//!   live [`crate::sim::Machine`] on the shared calendar, subframes
//!   are stage-pipelined (the unit frees between stages; inter-stage
//!   handoffs serialize on a shared interconnect), and admission can
//!   shed by predicted SLO-deadline miss. Replay is kept as the
//!   optimistic oracle; `tests/cosim_equivalence.rs` pins the two
//!   engines against each other.
//! * [`shard`] — the conservative parallel driver for multi-cell
//!   co-simulation: per-cell [`cosim::CosimSession`]s advance on
//!   worker-pool threads between synchronization horizons bounded by
//!   the fronthaul lookahead (floored at
//!   [`crate::model::handoff_s`]), exchanging cross-cell messages
//!   (subframe handover, shed re-routing — [`cosim::Coupling`]) at
//!   the barriers; results are bit-identical for every shard count.
//! * [`arrival`] — typed per-cell arrival processes: Poisson, bursty
//!   MMPP, diurnal, recorded-trace replay, and closed client loops.
//! * [`faults`] — the seed-deterministic fault-injection plane: typed
//!   [`faults::FaultPlan`] scenarios (unit crash/recover schedules,
//!   degraded units, fronthaul drop/delay windows, identity-keyed
//!   transient stage faults) plus the recovery policy (bounded retries
//!   with exponential virtual-time backoff) both engines honor.
//!   Faults are shard- and rerun-invariant by construction.
//! * [`slo`] — the latency accountant (p50/p95/p99/mean/max digests
//!   end-to-end, queueing, and per stage).
//! * [`serve`](mod@serve) — the typed [`serve::ClusterSpec`] /
//!   [`serve::CellSpec`] metro API: per-cell trace synthesis (seeded
//!   via [`crate::util::Rng`] and [`serve::cell_seed`]), the batched
//!   stage pre-simulation through the [`crate::harness`] memo cache,
//!   engine selection (`--engine replay|cosim`), cross-cell coupling
//!   knobs (`--handover-frac`, `--fronthaul-us`, `--reroute`), fault
//!   injection (`--faults`), and the `BENCH_serve.json` artifact
//!   (schema v5: multi-cell + coupling + fault counters).
//!
//! Every stage kernel is functionally simulated and verified, so the
//! pipeline doubles as an end-to-end correctness test of the whole
//! stack; [`golden_check`] additionally cross-checks stage results
//! against the AOT-compiled JAX artifacts through PJRT (the L2/L1
//! layers).

pub mod arrival;
pub mod calendar;
pub mod cluster;
pub mod cosim;
pub mod faults;
pub mod serve;
pub mod shard;
pub mod slo;

pub use arrival::ArrivalProcess;
pub use calendar::Calendar;
pub use cluster::{Arrival, ClusterConfig, ClusterRun, Completion, UnitStats, Workload};
pub use cosim::{
    run_dag, run_dag_faulted, CosimClass, CosimConfig, CosimRun, CosimSession,
    Coupling, DagConfig, DagRun, DagUnitStat, Migrant, Msg, Outbound, StageTask,
};
pub use faults::{DagFaultPlan, FaultPlan};
pub use serve::{
    cell_seed, read_artifact, serve, strong_scaling, write_artifact, Batching,
    CellReport, CellSpec, ClassReport, ClusterSpec, EngineKind, HostOnly, JobRecord,
    ScalingRow, ServeReport, StageWall, UnitReport,
};
pub use shard::ShardPlan;
pub use slo::{Pctls, SloAccountant, SloDigest};

use crate::runtime::{Result, RtError};
use crate::workloads::{self, Features, Goal};

/// One stage of the receiver chain: which kernel at which size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageSpec {
    pub kernel: &'static str,
    pub n: usize,
}

/// Pipeline-stage kernel names, in chain order (paper Fig 4). These
/// are *positional slot labels* (also the `stage_us` keys in
/// `BENCH_serve.json`): per-stage accounting aggregates by pipeline
/// position, so the `"cholesky"` slot covers whatever channel
/// estimator a class runs — Cholesky or LU (see [`STAGE_CHOICES`]).
pub const STAGE_NAMES: [&str; 4] = ["fft", "cholesky", "solver", "gemm"];

/// Kernels a job class may run at each pipeline position: the channel
/// estimator is Cholesky for Hermitian covariance estimates or LU for
/// the non-Hermitian (asymmetric-pilot) configurations.
pub const STAGE_CHOICES: [&[&str]; 4] =
    [&["fft"], &["cholesky", "lu"], &["solver"], &["gemm"]];

/// What each pipeline position does in the receiver.
pub const STAGE_ROLES: [&str; 4] =
    ["OFDM demod", "channel est", "equalize", "beamform"];

/// A subframe class: the receiver chain sized for one antenna/user
/// configuration, plus its share of the traffic mix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobClass {
    pub name: &'static str,
    /// Stage sizes in [`STAGE_NAMES`] order.
    pub stages: [StageSpec; 4],
    /// Relative arrival weight in the synthetic trace.
    pub weight: f64,
}

impl JobClass {
    /// The co-simulation view of this class: its stage chain with the
    /// profiled per-stage estimates (`cycles`, in [`STAGE_NAMES`]
    /// order — the same memoized counts the replay engine consumes).
    /// This is the *single* lowering from the static class table to
    /// [`cosim::CosimClass`]; serve's per-cell tables and the union
    /// mix both go through it, so the two engines can never disagree
    /// on a class's chain shape (pinned by the
    /// `cosim_class_agrees_with_the_stage_tables` test).
    pub fn cosim_class(&self, cycles: &[u64; 4]) -> cosim::CosimClass {
        cosim::CosimClass {
            stages: self
                .stages
                .iter()
                .zip(cycles.iter())
                .map(|(s, &cy)| cosim::StageTask {
                    kernel: s.kernel.to_string(),
                    n: s.n,
                    est_s: crate::model::cycles_to_us(cy) * 1e-6,
                })
                .collect(),
        }
    }
}

/// The default traffic mix: PUSCH-like subframe classes of increasing
/// MIMO order (all sizes are paper Table 5 sizes, so the stage
/// simulations are shared with the evaluation figures), plus an
/// LU-estimated 4x4 class for the non-Hermitian channel configurations.
pub const CLASSES: [JobClass; 4] = [
    JobClass {
        name: "pusch-2x2",
        stages: [
            StageSpec { kernel: "fft", n: 64 },
            StageSpec { kernel: "cholesky", n: 12 },
            StageSpec { kernel: "solver", n: 12 },
            StageSpec { kernel: "gemm", n: 12 },
        ],
        weight: 0.50,
    },
    JobClass {
        name: "pusch-4x4",
        stages: [
            StageSpec { kernel: "fft", n: 64 },
            StageSpec { kernel: "cholesky", n: 16 },
            StageSpec { kernel: "solver", n: 16 },
            StageSpec { kernel: "gemm", n: 12 },
        ],
        weight: 0.35,
    },
    JobClass {
        name: "pusch-8x8",
        stages: [
            StageSpec { kernel: "fft", n: 128 },
            StageSpec { kernel: "cholesky", n: 32 },
            StageSpec { kernel: "solver", n: 32 },
            StageSpec { kernel: "gemm", n: 24 },
        ],
        weight: 0.15,
    },
    JobClass {
        name: "pusch-4x4-lu",
        stages: [
            StageSpec { kernel: "fft", n: 64 },
            StageSpec { kernel: "lu", n: 16 },
            StageSpec { kernel: "solver", n: 16 },
            StageSpec { kernel: "gemm", n: 12 },
        ],
        weight: 0.10,
    },
];

/// Run one subframe of `class` through all four stages on a fresh
/// simulated unit, returning the per-stage cycle counts.
///
/// Stage failures propagate as [`RtError`] — a failing stage degrades
/// this one job instead of poisoning the serving thread (the cluster
/// path reaches the same property via [`serve::serve`]'s per-class
/// degradation).
pub fn run_job(class: &JobClass) -> Result<[u64; 4]> {
    let mut cycles = [0u64; 4];
    for (slot, stage) in cycles.iter_mut().zip(class.stages.iter()) {
        let out = workloads::prepare(stage.kernel, stage.n, Features::ALL, Goal::Latency)
            .and_then(|p| p.execute())
            .map_err(|e| {
                RtError(format!("stage {} n={} failed: {e}", stage.kernel, stage.n))
            })?;
        *slot = out.cycles;
    }
    Ok(cycles)
}

/// Cross-check the pipeline stages against the AOT JAX artifacts via
/// PJRT (the L2/L1 golden model). Returns Err if the artifacts are
/// missing or the binary was built without the `pjrt` feature.
pub fn golden_check() -> crate::runtime::Result<()> {
    use crate::runtime::Engine;
    use crate::util::linalg::Mat;
    let ensure = |cond: bool, msg: String| -> crate::runtime::Result<()> {
        if cond {
            Ok(())
        } else {
            Err(RtError(msg))
        }
    };
    let eng = Engine::discover()?;

    // Cholesky 16: simulate and compare against the lowered JAX kernel.
    let inst = workloads::cholesky::instance(16, 0);
    let exe = eng.load("cholesky_n16")?;
    let a32: Vec<f32> = (0..16 * 16)
        .map(|i| inst.a[(i / 16, i % 16)] as f32)
        .collect();
    let out = exe.run_f32(&[a32])?;
    let mut max_err = 0.0f32;
    for i in 0..16 {
        for j in 0..=i {
            let want = inst.l_ref[(i, j)] as f32;
            max_err = max_err.max((out[0][i * 16 + j] - want).abs());
        }
    }
    ensure(max_err < 1e-3, format!("cholesky golden mismatch: {max_err}"))?;

    // Solver 16.
    let sinst = workloads::solver::instance(16, 0);
    let exe = eng.load("solver_n16")?;
    let l32: Vec<f32> = (0..16 * 16)
        .map(|i| sinst.l[(i / 16, i % 16)] as f32)
        .collect();
    let b32: Vec<f32> = sinst.b.iter().map(|&x| x as f32).collect();
    let out = exe.run_f32(&[l32, b32])?;
    for (j, want) in sinst.x_ref.iter().enumerate() {
        ensure(
            (out[0][j] - *want as f32).abs() < 1e-3,
            format!("solver golden mismatch at {j}"),
        )?;
    }

    // GEMM 12.
    let ginst = workloads::gemm::instance(12, 0);
    let exe = eng.load("gemm_m12")?;
    let flat = |m: &Mat| -> Vec<f32> { m.data.iter().map(|&x| x as f32).collect() };
    let out = exe.run_f32(&[flat(&ginst.a), flat(&ginst.b)])?;
    for (i, want) in ginst.c_ref.data.iter().enumerate() {
        ensure(
            (out[0][i] - *want as f32).abs() < 1e-3,
            format!("gemm golden mismatch at {i}"),
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mix_is_well_formed() {
        assert!(!CLASSES.is_empty());
        for c in &CLASSES {
            assert!(c.weight > 0.0, "{}", c.name);
            for (s, choices) in c.stages.iter().zip(STAGE_CHOICES) {
                assert!(
                    choices.contains(&s.kernel),
                    "{}: {} is not a valid kernel for this pipeline position",
                    c.name,
                    s.kernel
                );
                assert!(
                    workloads::sizes(s.kernel).contains(&s.n),
                    "{}: {} n={} is a paper Table 5 size",
                    c.name,
                    s.kernel,
                    s.n
                );
            }
        }
    }

    #[test]
    fn cosim_class_agrees_with_the_stage_tables() {
        // The single lowering from the static class table to the
        // cosim chain: position-for-position the same kernels the
        // STAGE_CHOICES table allows, the same sizes, and estimates
        // that are exactly the profiled cycles at the REVEL clock.
        let cycles = [11u64, 22, 33, 44];
        for c in &CLASSES {
            let cc = c.cosim_class(&cycles);
            assert_eq!(cc.stages.len(), STAGE_NAMES.len(), "{}", c.name);
            for ((task, spec), choices) in
                cc.stages.iter().zip(c.stages.iter()).zip(STAGE_CHOICES)
            {
                assert_eq!(task.kernel, spec.kernel, "{}", c.name);
                assert_eq!(task.n, spec.n, "{}", c.name);
                assert!(
                    choices.contains(&task.kernel.as_str()),
                    "{}: {} escaped its pipeline position",
                    c.name,
                    task.kernel
                );
            }
            for (task, &cy) in cc.stages.iter().zip(cycles.iter()) {
                assert_eq!(task.est_s, crate::model::cycles_to_us(cy) * 1e-6);
            }
        }
    }

    #[test]
    fn run_job_reports_stage_cycles() {
        let cycles = run_job(&CLASSES[0]).expect("smallest class simulates cleanly");
        assert!(cycles.iter().all(|&c| c > 0));
    }
}
