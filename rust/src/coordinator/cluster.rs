//! The serving cluster: N simulated REVEL units with per-unit bounded
//! run queues, a least-loaded dispatcher with idle-time work stealing,
//! and cluster-wide admission control with load shedding.
//!
//! The engine is a sequential discrete-event simulation over *virtual*
//! time — this is the **replay** engine behind one cell of a
//! [`super::serve::ClusterSpec`] metro. Per-job service times are the
//! simulated stage cycle counts at the REVEL clock (supplied by the
//! caller, who obtains them from one batched [`crate::harness`] pass),
//! so a run is bit-exactly deterministic for a fixed trace: every tie —
//! same event timestamp, equal unit load — breaks on insertion order or
//! the lowest unit index. Host parallelism lives in the harness worker
//! pool that pre-simulates the distinct stage kernels and, for
//! multi-cell co-simulation, in the [`super::shard`] driver that
//! advances whole cells on pool threads; within a cell the dispatcher
//! itself never races.
//!
//! Dispatch policy, in order:
//! 1. an idle unit runs an arriving job immediately (idle units always
//!    have empty queues — they drain or steal before idling);
//! 2. otherwise the job queues at the eligible unit with the least
//!    backlog (in-service remainder + queued service seconds), bounded
//!    by [`ClusterConfig::queue_cap`];
//! 3. with every run queue full, the job waits in the cluster-wide
//!    admission queue, bounded by [`ClusterConfig::admit_cap`];
//! 4. beyond that, open-loop arrivals are shed (`dropped`) —
//!    backpressure instead of unbounded memory growth.
//!
//! A unit that finishes its run queue steals the newest job from the
//! most-backlogged peer before going idle.

use std::collections::VecDeque;

use super::calendar::Calendar;

/// Cluster sizing and admission policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterConfig {
    /// Simulated REVEL units serving in parallel.
    pub units: usize,
    /// Per-unit run-queue bound (jobs waiting at one unit, excluding
    /// the one in service).
    pub queue_cap: usize,
    /// Cluster-wide admission queue bound; open-loop arrivals beyond
    /// it are shed.
    pub admit_cap: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { units: 4, queue_cap: 8, admit_cap: 1024 }
    }
}

/// One subframe arrival offered to the dispatcher.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    pub id: u64,
    /// Index into the caller's class/service tables.
    pub class: usize,
    /// Arrival time (virtual seconds since trace start).
    pub t_s: f64,
}

/// A served job, fully timed (virtual seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub class: usize,
    pub unit: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    /// Taken from another unit's run queue by an idle unit.
    pub stolen: bool,
}

/// Per-unit serving counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UnitStats {
    pub jobs: usize,
    pub busy_s: f64,
    /// Jobs this unit stole from a peer's queue.
    pub stolen: usize,
}

/// Outcome of one cluster run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterRun {
    /// Served jobs, in service-start order.
    pub completions: Vec<Completion>,
    /// Arrivals shed by admission control (every queue full).
    pub dropped: usize,
    /// Arrivals whose class has no service profile (a degraded stage);
    /// the job fails, the cluster keeps serving.
    pub failed: usize,
    pub units: Vec<UnitStats>,
    /// Virtual seconds from the first arrival to the last pipeline
    /// exit (0 when nothing completes).
    pub makespan_s: f64,
    /// High-water mark of the admission queue.
    pub peak_admit_queue: usize,
}

/// How jobs are offered to the cluster.
pub enum Workload<'a> {
    /// Open loop: a pre-generated arrival trace. The trace — and hence
    /// per-job service demand — is independent of the unit count, so
    /// unit-scaling comparisons run "the same traffic".
    Open(&'a [Arrival]),
    /// Closed loop: `clients` concurrent submitters; each submits its
    /// next subframe the instant the previous one leaves the pipeline,
    /// `jobs` in total. Self-limiting, so nothing is ever shed as long
    /// as `clients` fits the queues.
    Closed { clients: usize, jobs: usize },
}

#[derive(Clone, Copy, Debug)]
enum EvKind {
    Arrive(Arrival),
    /// Unit `usize` finishes its in-service job.
    Free(usize),
}

struct Unit {
    busy: bool,
    /// When the in-service job finishes (valid while `busy`).
    free_at: f64,
    queue: VecDeque<Arrival>,
    /// Total service seconds sitting in `queue`.
    queued_s: f64,
    stats: UnitStats,
}

impl Unit {
    fn new() -> Self {
        Self {
            busy: false,
            free_at: 0.0,
            queue: VecDeque::new(),
            queued_s: 0.0,
            stats: UnitStats::default(),
        }
    }
}

struct Engine<'a> {
    cfg: ClusterConfig,
    /// Per-class stage service seconds; `None` marks a degraded class.
    service: &'a [Option<[f64; 4]>],
    units: Vec<Unit>,
    cal: Calendar<EvKind>,
    admission: VecDeque<Arrival>,
    out: ClusterRun,
}

impl Engine<'_> {
    fn total(&self, class: usize) -> f64 {
        self.service
            .get(class)
            .copied()
            .flatten()
            .map(|s| s.iter().sum())
            .unwrap_or(0.0)
    }

    fn push(&mut self, t_s: f64, kind: EvKind) {
        self.cal.push(t_s, kind);
    }

    /// Backlog a new job would wait behind at unit `u`.
    fn load(&self, u: usize, now: f64) -> f64 {
        let unit = &self.units[u];
        let in_service = if unit.busy { (unit.free_at - now).max(0.0) } else { 0.0 };
        in_service + unit.queued_s
    }

    /// Begin service of `a` on unit `u` at `now` (the unit is idle).
    fn start(&mut self, u: usize, a: Arrival, stolen: bool, now: f64) {
        let svc = self.total(a.class);
        let finish = now + svc;
        {
            let unit = &mut self.units[u];
            unit.busy = true;
            unit.free_at = finish;
            unit.stats.jobs += 1;
            unit.stats.busy_s += svc;
            if stolen {
                unit.stats.stolen += 1;
            }
        }
        self.out.completions.push(Completion {
            id: a.id,
            class: a.class,
            unit: u,
            arrival_s: a.t_s,
            start_s: now,
            finish_s: finish,
            stolen,
        });
        if finish > self.out.makespan_s {
            self.out.makespan_s = finish;
        }
        self.push(finish, EvKind::Free(u));
    }

    /// Least-loaded dispatch; `false` means every eligible queue is
    /// full (the job backs up into the admission queue).
    fn try_assign(&mut self, a: Arrival, now: f64) -> bool {
        let mut best: Option<(f64, usize)> = None;
        for u in 0..self.units.len() {
            let unit = &self.units[u];
            let eligible = !unit.busy || unit.queue.len() < self.cfg.queue_cap;
            if !eligible {
                continue;
            }
            let load = self.load(u, now);
            match best {
                Some((b, _)) if load >= b => {}
                _ => best = Some((load, u)),
            }
        }
        let Some((_, u)) = best else { return false };
        if !self.units[u].busy {
            // Idle units always have empty queues (they drain or steal
            // before idling), so this job runs immediately.
            self.start(u, a, false, now);
        } else {
            let svc = self.total(a.class);
            self.units[u].queued_s += svc;
            self.units[u].queue.push_back(a);
        }
        true
    }

    /// An idle unit with an empty queue takes the *newest* job from
    /// the most-backlogged peer (steal-from-tail keeps the victim's
    /// FIFO head intact).
    fn steal_for(&mut self, u: usize) -> Option<Arrival> {
        let mut best: Option<(f64, usize)> = None;
        for v in 0..self.units.len() {
            if v == u || self.units[v].queue.is_empty() {
                continue;
            }
            let backlog = self.units[v].queued_s;
            match best {
                Some((b, _)) if backlog <= b => {}
                _ => best = Some((backlog, v)),
            }
        }
        let (_, v) = best?;
        let a = self.units[v].queue.pop_back()?;
        let svc = self.total(a.class);
        self.units[v].queued_s -= svc;
        Some(a)
    }

    /// Move admission-queue jobs into freed run-queue slots, in FIFO
    /// order, until assignment backpressures again.
    fn drain_admission(&mut self, now: f64) {
        while let Some(&a) = self.admission.front() {
            if self.try_assign(a, now) {
                self.admission.pop_front();
            } else {
                break;
            }
        }
    }

    fn on_arrive(&mut self, a: Arrival, now: f64) {
        if self.service.get(a.class).copied().flatten().is_none() {
            self.out.failed += 1;
            return;
        }
        if self.try_assign(a, now) {
            return;
        }
        if self.admission.len() < self.cfg.admit_cap {
            self.admission.push_back(a);
            self.out.peak_admit_queue = self.out.peak_admit_queue.max(self.admission.len());
        } else {
            self.out.dropped += 1;
        }
    }

    fn on_free(&mut self, u: usize, now: f64) {
        self.units[u].busy = false;
        let next = if let Some(a) = self.units[u].queue.pop_front() {
            let svc = self.total(a.class);
            self.units[u].queued_s -= svc;
            Some((a, false))
        } else {
            self.steal_for(u).map(|a| (a, true))
        };
        if let Some((a, stolen)) = next {
            self.start(u, a, stolen, now);
        }
        self.drain_admission(now);
    }
}

/// Run a workload through the cluster.
///
/// `class_service` gives each job class's per-stage service seconds;
/// `None` marks a class degraded by a failed stage — its jobs count as
/// `failed` while the rest of the cluster keeps serving. `pick_class`
/// samples a class index per closed-loop submission (ignored for open
/// traces). Deterministic: identical inputs give a bit-identical
/// [`ClusterRun`].
pub fn run(
    cfg: &ClusterConfig,
    class_service: &[Option<[f64; 4]>],
    workload: Workload<'_>,
    mut pick_class: impl FnMut() -> usize,
) -> ClusterRun {
    let cfg = ClusterConfig {
        units: cfg.units.max(1),
        queue_cap: cfg.queue_cap.max(1),
        admit_cap: cfg.admit_cap,
    };
    let mut eng = Engine {
        units: (0..cfg.units).map(|_| Unit::new()).collect(),
        cfg,
        service: class_service,
        cal: Calendar::new(),
        admission: VecDeque::new(),
        out: ClusterRun::default(),
    };
    let (mut remaining, mut next_id, closed) = match workload {
        Workload::Open(trace) => {
            for a in trace {
                eng.push(a.t_s, EvKind::Arrive(*a));
            }
            (0usize, 0u64, false)
        }
        Workload::Closed { clients, jobs } => {
            let c = clients.max(1).min(jobs);
            for id in 0..c {
                let class = pick_class();
                eng.push(0.0, EvKind::Arrive(Arrival { id: id as u64, class, t_s: 0.0 }));
            }
            (jobs - c, c as u64, true)
        }
    };
    // Events pop in time order, so the first Arrive seen is the trace
    // start; makespan is measured from it, not from virtual t=0 (a
    // paced trace's first Poisson gap is not serving time).
    let mut first_arrival: Option<f64> = None;
    while let Some((now, kind)) = eng.cal.pop() {
        let resubmit = match kind {
            EvKind::Arrive(a) => {
                first_arrival.get_or_insert(now);
                // A degraded-class job fails instantly; its closed-loop
                // client resubmits rather than silently dying.
                let dead = eng.service.get(a.class).copied().flatten().is_none();
                eng.on_arrive(a, now);
                closed && dead
            }
            EvKind::Free(u) => {
                eng.on_free(u, now);
                closed
            }
        };
        if resubmit && remaining > 0 {
            let class = pick_class();
            eng.push(now, EvKind::Arrive(Arrival { id: next_id, class, t_s: now }));
            next_id += 1;
            remaining -= 1;
        }
    }
    let mut out = eng.out;
    if let Some(t0) = first_arrival {
        out.makespan_s = (out.makespan_s - t0).max(0.0);
    }
    out.units = eng.units.iter().map(|u| u.stats.clone()).collect();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Service profiles: class i takes `totals[i]` seconds, split
    /// evenly over the four stages.
    fn svc(totals: &[f64]) -> Vec<Option<[f64; 4]>> {
        totals.iter().map(|&t| Some([t / 4.0; 4])).collect()
    }

    fn flood(n: usize, class: usize) -> Vec<Arrival> {
        (0..n).map(|i| Arrival { id: i as u64, class, t_s: 0.0 }).collect()
    }

    #[test]
    fn least_loaded_unit_wins() {
        // class 0 takes 4 s, class 1 takes 1 s.
        let service = svc(&[4.0, 1.0]);
        let cfg = ClusterConfig { units: 2, queue_cap: 4, admit_cap: 16 };
        let tr = vec![
            Arrival { id: 0, class: 0, t_s: 0.0 }, // idle unit 0
            Arrival { id: 1, class: 1, t_s: 0.0 }, // idle unit 1
            Arrival { id: 2, class: 1, t_s: 0.0 }, // both busy; unit 1 backlog is smaller
        ];
        let r = run(&cfg, &service, Workload::Open(&tr), || 0);
        assert_eq!(r.completions.iter().find(|c| c.id == 2).unwrap().unit, 1);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.completions.len(), 3);
    }

    #[test]
    fn backpressure_bounds_accepted_jobs() {
        let service = svc(&[1.0]);
        let cfg = ClusterConfig { units: 1, queue_cap: 1, admit_cap: 2 };
        let r = run(&cfg, &service, Workload::Open(&flood(10, 0)), || 0);
        // 1 in service + 1 queued + 2 admitted; the other 6 shed.
        assert_eq!(r.completions.len(), 4);
        assert_eq!(r.dropped, 6);
        assert_eq!(r.peak_admit_queue, 2);
        assert!((r.makespan_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn idle_units_steal_queued_work() {
        // class 0: 8 s (pins unit 0); class 1: 1 s.
        let service = svc(&[8.0, 1.0]);
        let cfg = ClusterConfig { units: 2, queue_cap: 2, admit_cap: 8 };
        let tr = vec![
            Arrival { id: 0, class: 0, t_s: 0.0 }, // unit 0, busy to t=8
            Arrival { id: 1, class: 1, t_s: 0.0 }, // unit 1, busy to t=1
            Arrival { id: 2, class: 1, t_s: 0.0 }, // queues at unit 1 (lighter)
            Arrival { id: 3, class: 1, t_s: 0.0 }, // queues at unit 1 (cap reached)
            Arrival { id: 4, class: 1, t_s: 0.0 }, // unit 1 full -> queues at unit 0
        ];
        let r = run(&cfg, &service, Workload::Open(&tr), || 0);
        let c4 = r.completions.iter().find(|c| c.id == 4).unwrap();
        assert!(c4.stolen, "unit 1 drains and steals job 4 from unit 0's queue");
        assert_eq!(c4.unit, 1);
        assert_eq!(r.units[1].stolen, 1);
        assert!(r.makespan_s < 8.5, "stealing keeps the light jobs off the pinned unit");
    }

    #[test]
    fn deterministic_replay() {
        let service = svc(&[3.0, 1.0, 0.5]);
        let cfg = ClusterConfig { units: 3, queue_cap: 2, admit_cap: 4 };
        let tr: Vec<Arrival> = (0..40)
            .map(|i| Arrival {
                id: i as u64,
                class: (i * 7 % 3) as usize,
                t_s: (i % 11) as f64 * 0.3,
            })
            .collect();
        let a = run(&cfg, &service, Workload::Open(&tr), || 0);
        let b = run(&cfg, &service, Workload::Open(&tr), || 0);
        assert_eq!(a, b, "bit-identical replay for an identical trace");
        assert!(a.completions.len() + a.dropped == 40);
    }

    #[test]
    fn degraded_class_fails_jobs_without_poisoning() {
        let service = vec![Some([0.25; 4]), None];
        let cfg = ClusterConfig::default();
        let tr: Vec<Arrival> = (0..10)
            .map(|i| Arrival { id: i as u64, class: (i % 2) as usize, t_s: 0.0 })
            .collect();
        let r = run(&cfg, &service, Workload::Open(&tr), || 0);
        assert_eq!(r.failed, 5);
        assert_eq!(r.completions.len(), 5);
        assert!(r.completions.iter().all(|c| c.class == 0));
    }

    #[test]
    fn makespan_measured_from_first_arrival() {
        let service = svc(&[1.0]);
        let cfg = ClusterConfig { units: 1, queue_cap: 2, admit_cap: 4 };
        let tr = vec![
            Arrival { id: 0, class: 0, t_s: 5.0 },
            Arrival { id: 1, class: 0, t_s: 5.5 },
        ];
        let r = run(&cfg, &service, Workload::Open(&tr), || 0);
        // Finishes at t=6 and t=7; the 5 s lead-in is not serving time.
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn closed_loop_serves_all_jobs() {
        let service = svc(&[1.0]);
        let cfg = ClusterConfig { units: 2, queue_cap: 2, admit_cap: 4 };
        let r = run(&cfg, &service, Workload::Closed { clients: 2, jobs: 6 }, || 0);
        assert_eq!(r.completions.len(), 6);
        assert_eq!(r.dropped, 0);
        assert!((r.makespan_s - 3.0).abs() < 1e-12, "2 clients, 1 s each, 6 jobs");
    }
}
