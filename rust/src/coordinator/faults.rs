//! Deterministic fault-injection plane for the serving engines.
//!
//! A [`FaultPlan`] describes *what goes wrong* in a metro run: per-cell
//! unit crash/recover schedules, degraded units that run slower by a
//! cycle multiplier, fronthaul link drop/delay windows, and a transient
//! per-stage failure probability. Recovery policy (bounded retries with
//! exponential virtual-time backoff) rides along in the same plan so one
//! `--faults <spec>` string captures the whole scenario.
//!
//! Everything here is **seed-deterministic and shard-invariant**:
//!
//! - Crash/recover/degrade/link clauses are pure virtual-time windows —
//!   no randomness at all, so they replay identically for any shard
//!   count.
//! - The transient stage-failure draw is *identity-keyed*: a hash of
//!   `(salted seed, cell, job id, stage, attempt)` rather than a stream
//!   RNG, so the verdict for a given stage attempt never depends on the
//!   order events happen to pop within a window. Reruns and re-shards
//!   see the same faults down to the bit.
//!
//! The spec grammar is a `;`-separated clause list (whitespace ignored):
//!
//! ```text
//! crash=CELL.UNIT@DOWN_US..UP_US   unit crashes at DOWN_US, recovers at UP_US
//! crash=CELL.UNIT@DOWN_US          ... and never recovers
//! degrade=CELL.UNIT@MULT           unit runs MULT x slower (MULT >= 1.0)
//! drop=FROM_US..TO_US              fronthaul messages sent in the window drop
//! delay=FROM_US..TO_US@EXTRA_US    ... are delayed by EXTRA_US instead
//! p=PROB                           transient per-stage failure probability
//! retries=N                        bounded re-dispatch attempts (default 3)
//! backoff=US                       base virtual-time backoff (default 50us)
//! ```
//!
//! Example: `crash=0.1@200..900; p=0.02; retries=4; backoff=25`.

use crate::runtime::RtError;

/// Salt folded into the cluster seed for the transient-fault stream,
/// mirroring `HANDOVER_SALT` in `serve` ("FAULTIN" in ASCII).
pub const FAULT_SALT: u64 = 0x4641_554C_5449_4E00;

/// One scheduled unit outage: down at `down_s`, back at `up_s`
/// (`f64::INFINITY` when the unit never recovers).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outage {
    /// Cell index the outage applies to.
    pub cell: usize,
    /// Unit index within the cell.
    pub unit: usize,
    /// Virtual time (seconds) the unit crashes.
    pub down_s: f64,
    /// Virtual time (seconds) the unit recovers; infinite = never.
    pub up_s: f64,
}

/// A permanently degraded unit: every simulated stage on it takes
/// `mult` times longer in virtual time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Degrade {
    /// Cell index.
    pub cell: usize,
    /// Unit index within the cell.
    pub unit: usize,
    /// Cycle-time multiplier, `>= 1.0` (1.0 is a no-op).
    pub mult: f64,
}

/// A fronthaul fault window over message *send* times.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    /// Window start (seconds, inclusive).
    pub from_s: f64,
    /// Window end (seconds, exclusive).
    pub to_s: f64,
    /// `None` = messages sent in the window are dropped (and re-offered
    /// to the origin cell); `Some(extra_s)` = delivery is delayed by
    /// `extra_s` seconds instead.
    pub extra_s: Option<f64>,
}

/// Typed, validated fault scenario threaded through `serve`/`cosim`.
///
/// Defaults (`FaultPlan::default`) describe a fault-free run with the
/// standard recovery policy (3 retries, 50us base backoff), so engines
/// can hold a plan unconditionally.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Scheduled unit crash/recover windows.
    pub outages: Vec<Outage>,
    /// Permanently degraded (slow) units.
    pub degrades: Vec<Degrade>,
    /// Fronthaul drop/delay windows.
    pub links: Vec<LinkFault>,
    /// Transient per-stage failure probability in [0, 1).
    pub stage_fail_p: f64,
    /// Maximum re-dispatch attempts before a job lands in `failed`.
    pub max_retries: u32,
    /// Base virtual-time backoff (seconds); attempt k waits
    /// `backoff_s * 2^(k-1)`.
    pub backoff_s: f64,
    /// The raw spec string, echoed into artifacts for provenance.
    pub spec: String,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            outages: Vec::new(),
            degrades: Vec::new(),
            links: Vec::new(),
            stage_fail_p: 0.0,
            max_retries: 3,
            backoff_s: 50.0e-6,
            spec: String::new(),
        }
    }
}

impl FaultPlan {
    /// Parse a `;`-separated clause spec (see module docs for grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan, RtError> {
        let mut plan = FaultPlan { spec: spec.trim().to_string(), ..FaultPlan::default() };
        for raw in spec.split(';') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| bad(clause, "expected key=value"))?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "crash" => {
                    let (loc, times) = val
                        .split_once('@')
                        .ok_or_else(|| bad(clause, "expected CELL.UNIT@US[..US]"))?;
                    let (cell, unit) = parse_loc(clause, loc)?;
                    let (down_us, up_us) = match times.split_once("..") {
                        Some((d, u)) => (parse_us(clause, d)?, parse_us(clause, u)?),
                        None => (parse_us(clause, times)?, f64::INFINITY),
                    };
                    if up_us <= down_us {
                        return Err(bad(clause, "recover time must be after crash time"));
                    }
                    plan.outages.push(Outage {
                        cell,
                        unit,
                        down_s: down_us * 1e-6,
                        up_s: up_us * 1e-6,
                    });
                }
                "degrade" => {
                    let (loc, m) = val
                        .split_once('@')
                        .ok_or_else(|| bad(clause, "expected CELL.UNIT@MULT"))?;
                    let (cell, unit) = parse_loc(clause, loc)?;
                    let mult: f64 = m
                        .trim()
                        .parse()
                        .map_err(|_| bad(clause, "multiplier must be a number"))?;
                    if !(mult.is_finite() && mult >= 1.0) {
                        return Err(bad(clause, "multiplier must be finite and >= 1.0"));
                    }
                    plan.degrades.push(Degrade { cell, unit, mult });
                }
                "drop" => {
                    let (from_s, to_s) = parse_window(clause, val)?;
                    plan.links.push(LinkFault { from_s, to_s, extra_s: None });
                }
                "delay" => {
                    let (win, extra) = val
                        .split_once('@')
                        .ok_or_else(|| bad(clause, "expected FROM_US..TO_US@EXTRA_US"))?;
                    let (from_s, to_s) = parse_window(clause, win)?;
                    let extra_us = parse_us(clause, extra)?;
                    if extra_us <= 0.0 {
                        return Err(bad(clause, "delay must be positive"));
                    }
                    plan.links.push(LinkFault {
                        from_s,
                        to_s,
                        extra_s: Some(extra_us * 1e-6),
                    });
                }
                "p" => {
                    let p: f64 = val
                        .parse()
                        .map_err(|_| bad(clause, "probability must be a number"))?;
                    if !(p.is_finite() && (0.0..1.0).contains(&p)) {
                        return Err(bad(clause, "probability must be in [0, 1)"));
                    }
                    plan.stage_fail_p = p;
                }
                "retries" => {
                    plan.max_retries = val
                        .parse()
                        .map_err(|_| bad(clause, "retries must be a non-negative integer"))?;
                }
                "backoff" => {
                    let us = parse_us(clause, val)?;
                    if us <= 0.0 {
                        return Err(bad(clause, "backoff must be positive"));
                    }
                    plan.backoff_s = us * 1e-6;
                }
                other => {
                    return Err(RtError(format!(
                        "fault spec: unknown clause key `{other}` in `{clause}` \
                         (expected crash|degrade|drop|delay|p|retries|backoff)"
                    )));
                }
            }
        }
        Ok(plan)
    }

    /// True when the plan injects anything at all (recovery-policy
    /// knobs alone do not make a plan active).
    pub fn is_active(&self) -> bool {
        !self.outages.is_empty()
            || !self.degrades.is_empty()
            || !self.links.is_empty()
            || self.stage_fail_p > 0.0
    }

    /// Outages scheduled for one cell.
    pub fn outages_for(&self, cell: usize) -> impl Iterator<Item = &Outage> {
        self.outages.iter().filter(move |o| o.cell == cell)
    }

    /// Cycle-time multiplier for a unit (1.0 when not degraded).
    pub fn mult_for(&self, cell: usize, unit: usize) -> f64 {
        self.degrades
            .iter()
            .find(|d| d.cell == cell && d.unit == unit)
            .map_or(1.0, |d| d.mult)
    }

    /// Link fault covering a message sent at `t_s`, if any. The first
    /// matching window in spec order wins, so overlapping windows stay
    /// deterministic.
    pub fn link_fault_at(&self, t_s: f64) -> Option<&LinkFault> {
        self.links.iter().find(|l| t_s >= l.from_s && t_s < l.to_s)
    }

    /// Identity-keyed transient-failure verdict for one stage attempt.
    ///
    /// Keyed on `(seed ^ FAULT_SALT, cell, job, stage, attempt)` via a
    /// SplitMix64-style finalizer, so the draw is independent of event
    /// pop order — the property the shard-invariance tests pin.
    pub fn stage_fails(
        &self,
        seed: u64,
        cell: usize,
        job: u64,
        stage: usize,
        attempt: u32,
    ) -> bool {
        if self.stage_fail_p <= 0.0 {
            return false;
        }
        let mut x = seed ^ FAULT_SALT;
        for k in [cell as u64, job, stage as u64, attempt as u64] {
            x = mix64(x ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        // Same u64 -> [0,1) mapping as util::Rng::f64.
        let u = (x >> 11) as f64 / (1u64 << 53) as f64;
        u < self.stage_fail_p
    }

    /// Virtual-time backoff before re-dispatch attempt `attempt`
    /// (1-based): `backoff_s * 2^(attempt-1)`.
    pub fn backoff_for(&self, attempt: u32) -> f64 {
        self.backoff_s * f64::from(1u32 << (attempt.saturating_sub(1)).min(20))
    }
}

/// Fault scenario for the tile-DAG scheduler, in the DAG's native time
/// domain (cycles): each entry kills one unit at a cycle timestamp.
/// Killed units lose their retained spad slots; their in-flight task is
/// re-executed on a survivor, and the factor digest must still match
/// the fault-free run bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DagFaultPlan {
    /// `(unit, crash_cycle)` pairs; a unit listed here never recovers.
    pub crashes: Vec<(usize, u64)>,
}

impl DagFaultPlan {
    /// Parse a `;`-separated list of `crash=UNIT@CYCLE` clauses.
    pub fn parse(spec: &str) -> Result<DagFaultPlan, RtError> {
        let mut plan = DagFaultPlan::default();
        for raw in spec.split(';') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            let body = clause
                .strip_prefix("crash=")
                .ok_or_else(|| bad(clause, "expected crash=UNIT@CYCLE"))?;
            let (u, c) = body
                .split_once('@')
                .ok_or_else(|| bad(clause, "expected crash=UNIT@CYCLE"))?;
            let unit: usize = u
                .trim()
                .parse()
                .map_err(|_| bad(clause, "unit must be an integer"))?;
            let cycle: u64 = c
                .trim()
                .parse()
                .map_err(|_| bad(clause, "cycle must be an integer"))?;
            plan.crashes.push((unit, cycle));
        }
        Ok(plan)
    }

    /// True when at least one crash is scheduled.
    pub fn is_active(&self) -> bool {
        !self.crashes.is_empty()
    }
}

/// SplitMix64 finalizer (also used by `util::Rng` seeding).
fn mix64(z: u64) -> u64 {
    let mut x = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn bad(clause: &str, why: &str) -> RtError {
    RtError(format!("fault spec: `{clause}`: {why}"))
}

fn parse_loc(clause: &str, loc: &str) -> Result<(usize, usize), RtError> {
    let (c, u) = loc
        .split_once('.')
        .ok_or_else(|| bad(clause, "location must be CELL.UNIT"))?;
    let cell = c
        .trim()
        .parse()
        .map_err(|_| bad(clause, "cell must be an integer"))?;
    let unit = u
        .trim()
        .parse()
        .map_err(|_| bad(clause, "unit must be an integer"))?;
    Ok((cell, unit))
}

fn parse_us(clause: &str, s: &str) -> Result<f64, RtError> {
    let v: f64 = s
        .trim()
        .parse()
        .map_err(|_| bad(clause, "time must be a number (microseconds)"))?;
    if !(v.is_finite() && v >= 0.0) {
        return Err(bad(clause, "time must be finite and non-negative"));
    }
    Ok(v)
}

fn parse_window(clause: &str, s: &str) -> Result<(f64, f64), RtError> {
    let (a, b) = s
        .split_once("..")
        .ok_or_else(|| bad(clause, "window must be FROM_US..TO_US"))?;
    let (from_us, to_us) = (parse_us(clause, a)?, parse_us(clause, b)?);
    if to_us <= from_us {
        return Err(bad(clause, "window end must be after its start"));
    }
    Ok((from_us * 1e-6, to_us * 1e-6))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_spec_and_rejects_malformed_clauses() {
        let p = FaultPlan::parse(
            "crash=0.1@200..900; crash=1.0@50; degrade=0.0@2.5; \
             drop=0..100; delay=100..200@25; p=0.02; retries=4; backoff=10",
        )
        .unwrap();
        assert_eq!(p.outages.len(), 2);
        assert_eq!(p.outages[0], Outage { cell: 0, unit: 1, down_s: 200e-6, up_s: 900e-6 });
        assert_eq!(p.outages[1].up_s, f64::INFINITY);
        assert_eq!(p.mult_for(0, 0), 2.5);
        assert_eq!(p.mult_for(0, 1), 1.0);
        assert_eq!(p.links.len(), 2);
        assert_eq!(p.links[1].extra_s, Some(25e-6));
        assert_eq!(p.stage_fail_p, 0.02);
        assert_eq!(p.max_retries, 4);
        assert!((p.backoff_s - 10e-6).abs() < 1e-12);
        assert!(p.is_active());

        for spec in [
            "crash=0@100",          // location missing the unit
            "crash=0.1@900..200",   // recover before crash
            "degrade=0.0@0.5",      // speedup is not a degrade
            "drop=100..50",         // inverted window
            "delay=0..10@0",        // zero delay
            "p=1.5",                // probability out of range
            "p=nan",                // non-finite
            "backoff=-1",           // negative time
            "warp=9",               // unknown key
            "crash",                // no '='
        ] {
            assert!(FaultPlan::parse(spec).is_err(), "spec `{spec}` should fail");
        }

        assert!(!FaultPlan::parse("").unwrap().is_active());
        assert!(!FaultPlan::parse("retries=9; backoff=5").unwrap().is_active());
    }

    #[test]
    fn stage_fail_draws_are_identity_keyed_and_match_the_rate() {
        let p = FaultPlan::parse("p=0.25").unwrap();
        // Same identity -> same verdict, always.
        for job in 0..50 {
            let a = p.stage_fails(7, 0, job, 1, 2);
            let b = p.stage_fails(7, 0, job, 1, 2);
            assert_eq!(a, b);
        }
        // Distinct attempts are independent draws; frequency tracks p.
        let n: u64 = 20_000;
        let hits = (0..n)
            .filter(|&j| p.stage_fails(7, 0, j, 0, 1))
            .count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
        // Seed and salt matter: a different seed flips some verdicts.
        let flips = (0..n)
            .filter(|&j| p.stage_fails(7, 0, j, 0, 1) != p.stage_fails(8, 0, j, 0, 1))
            .count();
        assert!(flips > 0);
        // p=0 never fails regardless of identity.
        let off = FaultPlan::default();
        assert!(!off.stage_fails(7, 0, 0, 0, 1));
    }

    #[test]
    fn backoff_doubles_per_attempt_and_link_windows_resolve_in_order() {
        let p = FaultPlan::parse("backoff=50").unwrap();
        assert!((p.backoff_for(1) - 50e-6).abs() < 1e-12);
        assert!((p.backoff_for(2) - 100e-6).abs() < 1e-12);
        assert!((p.backoff_for(3) - 200e-6).abs() < 1e-12);

        let p = FaultPlan::parse("drop=0..100; delay=50..200@10").unwrap();
        // 60us sits in both windows; the first clause (drop) wins.
        assert_eq!(p.link_fault_at(60e-6).unwrap().extra_s, None);
        assert_eq!(p.link_fault_at(150e-6).unwrap().extra_s, Some(10e-6));
        assert!(p.link_fault_at(250e-6).is_none());
        // Window end is exclusive; start is inclusive.
        assert!(p.link_fault_at(200e-6).is_none());
        assert!(p.link_fault_at(0.0).is_some());
    }

    #[test]
    fn dag_plan_parses_and_rejects_garbage() {
        let p = DagFaultPlan::parse("crash=1@5000; crash=0@9000").unwrap();
        assert_eq!(p.crashes, vec![(1, 5000), (0, 9000)]);
        assert!(p.is_active());
        assert!(!DagFaultPlan::parse("").unwrap().is_active());
        assert!(DagFaultPlan::parse("crash=1").is_err());
        assert!(DagFaultPlan::parse("drop=0..9").is_err());
        assert!(DagFaultPlan::parse("crash=x@1").is_err());
    }
}
