//! Calendar-driven multi-unit co-simulation: the serving cluster's
//! second engine, in which every unit's [`crate::sim::Machine`]
//! advances **live** on one shared virtual timeline instead of
//! replaying service times memoized by the harness.
//!
//! The replay engine ([`super::cluster`]) treats a job as an opaque
//! block of pre-simulated seconds: accurate per unit, but blind to
//! anything that happens *between* units. This engine schedules three
//! kinds of actors on one [`super::calendar::Calendar`]:
//!
//! * **Units** own a live machine per in-flight stage. While other
//!   events are pending, a unit advances its machine in fixed bounded
//!   chunks and yields the timeline back, so machine progress
//!   genuinely interleaves with dispatch, work stealing, and admission
//!   decisions; with the calendar otherwise empty the stage runs out
//!   in one go. Chunking is invisible to results
//!   ([`crate::sim::Machine::advance_until`] is bit-identical to an
//!   unchunked run).
//! * **Stage-pipelined jobs**: a subframe occupies a unit for one
//!   stage at a time. When a stage retires, the unit is freed for
//!   queued work immediately and the subframe's working set crosses
//!   the cluster's **shared interconnect** (one handoff at a time,
//!   [`crate::model::handoff_cycles`]) before its next stage re-enters
//!   dispatch — on whichever unit is then least loaded.
//! * **SLO-aware admission**: with a deadline configured, an arrival
//!   whose predicted completion (calendar lookahead over unit backlogs
//!   plus the class's service + handoff demand) misses the deadline is
//!   shed at admission instead of wasting cluster time.
//! * **Cross-cell coupling** (multi-cell metros only, [`Coupling`]):
//!   a retiring stage may hand its subframe over the fronthaul to the
//!   ring-neighbor cell ([`Migrant`]), and a shed arrival may be
//!   re-offered to the least-backlogged peer before it counts. The
//!   engine only ever *emits* [`Outbound`] messages; the sharded
//!   driver ([`super::shard::run_sharded`]) exchanges them at
//!   conservative horizon barriers, which is what keeps multi-cell
//!   results bit-identical for every shard count.
//!
//! A second, independent engine in this module ([`run_dag`]) schedules
//! a **tile-task DAG** ([`crate::taskgraph`]) instead of a job stream:
//! persistent per-unit machines ([`crate::sim::Machine::reset_retaining_spad`])
//! keep factored tiles resident in scratchpad slots between tasks, a
//! dependence-count dispatcher releases ready tasks onto the same
//! [`super::calendar::Calendar`], and inter-tile working sets are
//! billed on the shared interconnect via [`crate::model::handoff_cycles`].
//!
//! Relationship to replay — pinned by `tests/cosim_equivalence.rs`:
//! for **single-stage jobs** there are no handoffs and stage
//! granularity coincides with job granularity, so this engine
//! reproduces the replay engine's completions, unit stats, and SLO
//! digests **bit-exactly** (the dispatch policies below are the same
//! policies, and live machine cycles equal memoized cycles because the
//! simulator is deterministic in the stage point). For multi-stage
//! jobs, replay is the optimistic bound: it assumes inter-stage
//! handoffs are free and infinitely parallel, so co-simulated
//! latencies are `>=` replayed ones — the delta is exactly the
//! cross-unit contention replay cannot see.

use std::collections::{BTreeMap, VecDeque};

use crate::harness::json::Json;
use crate::model;
use crate::sim::{Machine, SimConfig, LINE_WORDS};
use crate::taskgraph::{exec, DagKernel, Lowerer, TileDag};
use crate::util::linalg::Mat;
use crate::util::Rng;
use crate::vsc::{Region, SpadAlloc};
use crate::workloads::{self, Features, Goal, Prepared};

use super::calendar::Calendar;
use super::cluster::{Arrival, ClusterConfig, Completion, UnitStats, Workload};
use super::faults::{DagFaultPlan, FaultPlan};

/// Machine progress per calendar step while other events are pending,
/// in cycles. Bounds calendar traffic (one event per chunk, not per
/// cycle) while keeping the interleave fine enough that dispatch never
/// waits long on a busy unit's turn. Any chunking yields bit-identical
/// results; the fixed size also keeps simultaneous identical stages in
/// cycle lockstep (see `Engine::on_step`).
const MIN_CHUNK: u64 = 1024;

/// Virtual seconds of `c` simulated cycles — the exact conversion the
/// replay path applies to memoized stage cycles, so a co-simulated
/// stage of `c` cycles lands on the same `f64` timestamp replay would
/// produce.
fn s_of(c: u64) -> f64 {
    model::cycles_to_us(c) * 1e-6
}

/// One pipeline stage of a co-simulated job class.
#[derive(Clone, Debug, PartialEq)]
pub struct StageTask {
    pub kernel: String,
    pub n: usize,
    /// Predicted service seconds. Steers the dispatcher's least-loaded
    /// metric and SLO admission lookahead (a profiled cost model, as a
    /// real scheduler would use) — never the timeline itself, which
    /// comes from the live machines.
    pub est_s: f64,
}

/// A co-simulated job class: an arbitrary-length stage chain (the
/// serving pipeline uses four; the equivalence suite pins single-stage
/// chains against the replay oracle).
#[derive(Clone, Debug, PartialEq)]
pub struct CosimClass {
    pub stages: Vec<StageTask>,
}

impl CosimClass {
    /// Total predicted demand of one job: every stage's estimate plus
    /// every inter-stage handoff on the shared interconnect.
    pub fn demand_s(&self) -> f64 {
        let mut d: f64 = self.stages.iter().map(|s| s.est_s).sum();
        for w in self.stages.windows(2) {
            d += s_of(model::handoff_cycles(&w[1].kernel, w[1].n));
        }
        d
    }
}

/// Co-simulation engine configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct CosimConfig {
    pub cluster: ClusterConfig,
    /// SLO-aware admission: shed arrivals whose predicted completion
    /// lies more than this many virtual seconds after arrival. `None`
    /// falls back to queue-depth-only admission (replay's policy).
    pub deadline_s: Option<f64>,
}

/// Cross-cell coupling of one cell's engine inside a multi-cell
/// metro ([`super::shard::run_sharded`]): subframe handover to the
/// ring neighbor and metro-level re-routing of shed arrivals. The
/// engine never talks to other cells directly — it *emits*
/// [`Outbound`] messages into an outbox that the sharded driver
/// collects and delivers at the next conservative horizon barrier, so
/// every cross-cell event rides the same protocol for every shard
/// count (including one).
#[derive(Clone, Debug, PartialEq)]
pub struct Coupling {
    /// This cell's index in the metro.
    pub cell: usize,
    /// Total cells in the metro (handover targets the ring neighbor
    /// `(cell + 1) % cells`).
    pub cells: usize,
    /// Probability that a retiring non-final stage hands the subframe
    /// over to the neighbor cell instead of the local interconnect.
    /// Drawn from the cell's dedicated handover seed stream, and only
    /// when positive — a zero fraction makes zero draws, so uncoupled
    /// metros stay bit-identical to pre-coupling runs.
    pub handover_frac: f64,
    /// Fronthaul traversal latency in virtual seconds — the conserva-
    /// tive cross-shard lookahead. Must already be floored at the
    /// [`super::shard::ShardPlan::lookahead_s`] bound by the caller;
    /// the horizon window must not exceed it (CMB safety).
    pub fronthaul_s: f64,
    /// Re-offer SLO-shed and admission-overflowed arrivals to the
    /// least-backlogged peer (one hop, terminal) before counting them.
    pub reroute: bool,
}

impl Coupling {
    /// The single-cell / uncoupled configuration: no messages are ever
    /// emitted and no handover randomness is ever drawn.
    pub fn none() -> Self {
        Coupling { cell: 0, cells: 1, handover_frac: 0.0, fronthaul_s: 0.0, reroute: false }
    }

    /// Whether this cell can exchange any cross-cell event at all.
    pub fn active(&self) -> bool {
        self.cells > 1 && (self.reroute || self.handover_frac > 0.0)
    }
}

/// A subframe mid-chain in flight over the fronthaul: everything the
/// receiving cell needs to resume the job at its next stage.
#[derive(Clone, Debug, PartialEq)]
pub struct Migrant {
    pub id: u64,
    pub class: usize,
    pub arrival_s: f64,
    /// Service start of the first stage (in the source cell) — carried
    /// so end-to-end latency stays honest across the handover.
    pub start_s: f64,
    pub stolen: bool,
    /// Next stage index to run in the receiving cell. Class indices
    /// are only meaningful when every cell serves the same job mix —
    /// the serve layer enforces that whenever handover is enabled.
    pub stage: usize,
    /// Live-measured cycles of the stages already completed upstream.
    pub cycles: Vec<u64>,
}

/// One cross-cell event payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Subframe handover: the job's remaining stages run at the
    /// destination cell.
    Migrate(Migrant),
    /// An arrival shed here, re-offered to the least-backlogged peer.
    Shed(Arrival),
}

/// An outbound cross-cell message, parked in the sender's outbox until
/// the sharded driver drains it at a horizon barrier.
#[derive(Clone, Debug, PartialEq)]
pub struct Outbound {
    /// Destination cell. `None` means "least-backlogged peer" — the
    /// driver resolves it at the barrier, so the routing decision uses
    /// horizon-consistent metro state instead of whatever the sender
    /// happened to see mid-window.
    pub dst: Option<usize>,
    /// Delivery time: send time + fronthaul latency. With the window
    /// bounded by the fronthaul this is never in the receiver's past.
    pub t_s: f64,
    pub msg: Msg,
}

/// Outcome of one co-simulated run. `completions` (and the aligned
/// `stage_cycles`) are ordered by service start, exactly like
/// [`super::cluster::ClusterRun::completions`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CosimRun {
    pub completions: Vec<Completion>,
    /// Live-measured simulated cycles of every stage of every
    /// completed job, aligned index-for-index with `completions`. The
    /// equivalence suite pins these against the harness-memoized
    /// per-stage cycles.
    pub stage_cycles: Vec<Vec<u64>>,
    /// Arrivals shed by admission control (every queue full).
    pub dropped: usize,
    /// Arrivals shed by the SLO deadline lookahead.
    pub deadline_shed: usize,
    /// Degraded-class arrivals plus jobs lost to a stage that failed
    /// to prepare, simulate, or verify mid-run.
    pub failed: usize,
    /// Per-unit counters. A subframe occupies one unit *per stage*, so
    /// `jobs`/`stolen` here count stage executions, not whole jobs —
    /// 4x the replay engine's numbers for the 4-stage classes, and
    /// identical for the single-stage classes the equivalence suite
    /// pins. `busy_s` is compute occupancy either way.
    pub units: Vec<UnitStats>,
    /// Virtual seconds from the first arrival to the last pipeline
    /// exit (0 when nothing completes).
    pub makespan_s: f64,
    pub peak_admit_queue: usize,
    /// Inter-stage handoffs granted on the shared interconnect.
    pub handoffs: usize,
    /// Virtual seconds the shared interconnect spent moving data.
    pub bus_busy_s: f64,
    /// Virtual seconds handoffs waited for the interconnect — the
    /// cross-unit contention replay cannot model.
    pub bus_wait_s: f64,
    /// Subframes handed over to a neighbor cell mid-chain (egress).
    pub migrated_out: usize,
    /// Subframes received from a neighbor cell mid-chain (ingress).
    pub migrated_in: usize,
    /// Shed arrivals re-offered to a peer instead of counted here.
    pub rerouted_out: usize,
    /// Re-offered arrivals received from a peer (their outcome —
    /// completion or a now-terminal shed — is accounted in this cell).
    pub rerouted_in: usize,
    /// Cross-cell messages delivered with a timestamp behind this
    /// cell's clock. Always zero when the horizon window respects the
    /// fronthaul lookahead; the canary suite drives it positive with a
    /// deliberately oversized window to prove the bound is
    /// load-bearing.
    pub causality_violations: usize,
    /// Stage re-dispatches scheduled by the fault plane (transient
    /// stage faults, crash kills, and all-units-down arrivals waiting
    /// out an outage). Zero without an active [`FaultPlan`].
    pub retries: usize,
    /// In-flight stages killed by a unit crash.
    pub crash_kills: usize,
    /// Fronthaul messages lost in a link-drop window; each one was
    /// re-offered to this (the origin) cell's own queue.
    pub link_dropped: usize,
    /// Fronthaul messages held back by a link-delay window.
    pub link_delayed: usize,
    /// Mid-run stage failures, rendered (normally empty).
    pub stage_errors: Vec<String>,
}

/// One in-flight job.
struct Job {
    id: u64,
    class: usize,
    arrival_s: f64,
    /// Index of the stage currently running or next to run.
    stage: usize,
    /// Service start of the first stage.
    start_s: f64,
    /// Position in the global service-start order (completions sort on
    /// it, matching replay's push-at-start ordering).
    start_ord: u64,
    /// `start_ord` has been assigned (first local stage started).
    /// Local jobs take their ordinal at stage 0 exactly as before;
    /// migrants enter mid-chain and take a fresh ordinal here.
    ord_set: bool,
    /// This job entered the cell over the fronthaul (migrant or
    /// re-offered arrival). Foreign departures never free a *local*
    /// closed-loop client.
    foreign: bool,
    /// Any stage of this job ran via work stealing.
    stolen: bool,
    /// Fault-plane re-dispatch attempts consumed so far (transient
    /// stage faults and crash kills). Bounded by
    /// [`FaultPlan::max_retries`]; also keys the identity-hashed
    /// transient draw so each attempt gets an independent verdict.
    attempts: u32,
    /// Live-measured cycles of completed stages.
    cycles: Vec<u64>,
}

/// A unit's in-flight stage: a live machine plus the verifier that
/// checks its functional outputs at retirement.
struct Active {
    job: usize,
    machine: Machine,
    verify: Box<dyn Fn(&Machine) -> Result<f64, String> + Send + Sync>,
    start_s: f64,
    /// Exact finish time, once the machine has completed (the
    /// `StageDone` event is scheduled here).
    done: Option<f64>,
}

struct Unit {
    run: Option<Active>,
    /// Ready stages (job indices) queued at this unit.
    queue: VecDeque<usize>,
    /// Predicted service seconds sitting in `queue`.
    queued_s: f64,
    /// Predicted end of the in-service stage (valid while `run` is
    /// `Some`) — the dispatcher's in-service-remainder estimate.
    est_end_s: f64,
    /// Crashed by the fault plane: ineligible for dispatch until the
    /// matching recover event (if any) brings it back.
    down: bool,
    /// Degraded-unit cycle-time multiplier from the fault plan; 1.0
    /// (the exact-identity multiplier) when healthy.
    mult: f64,
    stats: UnitStats,
}

impl Unit {
    fn new() -> Self {
        Self {
            run: None,
            queue: VecDeque::new(),
            queued_s: 0.0,
            est_end_s: 0.0,
            down: false,
            mult: 1.0,
            stats: UnitStats::default(),
        }
    }
}

enum Ev {
    Arrive(Arrival),
    /// Advance unit `usize`'s live machine (up to the calendar's next
    /// pending event).
    Step(usize),
    /// Unit `usize`'s in-flight stage retires at this instant.
    StageDone(usize),
    /// Job `usize`'s inter-stage handoff leaves the shared
    /// interconnect; its next stage enters dispatch.
    BusDone(usize),
    /// A subframe lands from a neighbor cell (fronthaul traversal
    /// done); its next stage enters dispatch here.
    MigrateIn(Migrant),
    /// A shed arrival re-offered by a peer lands here. Terminal: a
    /// second shed counts locally, it is never re-offered again.
    Rerouted(Arrival),
    /// Fault plane: unit `usize` crashes at this instant (in-flight
    /// stage killed, queue drained to peers).
    Crash(usize),
    /// Fault plane: unit `usize` comes back from its outage.
    Recover(usize),
    /// Fault plane: job `usize`'s current stage re-enters dispatch
    /// after its retry backoff.
    Retry(usize),
}

struct Engine<'a> {
    cfg: ClusterConfig,
    deadline_s: Option<f64>,
    /// Per-class stage chains; `None` marks a degraded class (same
    /// contract as replay's service table).
    classes: &'a [Option<CosimClass>],
    units: Vec<Unit>,
    cal: Calendar<Ev>,
    jobs: Vec<Job>,
    /// Cluster-wide admission queue of stage-0 jobs.
    admission: VecDeque<usize>,
    bus_busy: bool,
    /// Pending handoffs: (job, request time).
    bus_fifo: VecDeque<(usize, f64)>,
    next_ord: u64,
    /// Jobs lost after admission (prepare / simulate / verify failure).
    /// The closed-loop driver watches this so a client whose job dies
    /// mid-run still submits its next one (replay has no mid-run
    /// deaths; its clients resubmit on every completion or degraded
    /// arrival, and this keeps the invariant).
    mid_run_deaths: usize,
    /// (start_ord, completion, per-stage cycles); sorted at the end.
    done_jobs: Vec<(u64, Completion, Vec<u64>)>,
    dropped: usize,
    deadline_shed: usize,
    failed: usize,
    makespan_s: f64,
    peak_admit_queue: usize,
    handoffs: usize,
    bus_busy_s: f64,
    bus_wait_s: f64,
    /// Cross-cell role of this cell, plus the dedicated handover seed
    /// stream (separate from trace synthesis so enabling coupling
    /// never perturbs arrival randomness).
    coupling: Coupling,
    hand_rng: Rng,
    /// Cross-cell messages emitted since the last barrier drain.
    outbox: Vec<Outbound>,
    migrated_out: usize,
    migrated_in: usize,
    rerouted_out: usize,
    rerouted_in: usize,
    /// Local jobs that left this cell over the fronthaul — the closed
    /// loop resubmits on egress (the client's slot frees when its job
    /// leaves the cell), mirroring `mid_run_deaths`.
    local_egress: usize,
    /// Latest event timestamp popped from the calendar; deliveries
    /// behind it are causality violations.
    last_t: f64,
    causality_violations: usize,
    /// The armed fault scenario (default = fault-free) plus the seed
    /// its identity-keyed transient draws fold in.
    faults: FaultPlan,
    fault_seed: u64,
    retries: usize,
    crash_kills: usize,
    link_dropped: usize,
    link_delayed: usize,
    stage_errors: Vec<String>,
}

impl Engine<'_> {
    fn class_of(&self, j: usize) -> &CosimClass {
        self.classes[self.jobs[j].class]
            .as_ref()
            .expect("enqueued jobs have a service profile")
    }

    /// Predicted service seconds of job `j`'s current stage.
    fn cur_est(&self, j: usize) -> f64 {
        self.class_of(j).stages[self.jobs[j].stage].est_s
    }

    /// Backlog a new stage would wait behind at unit `u` — the same
    /// metric as replay's, with the in-service remainder read from the
    /// profiled estimate (the live machine's exact remainder is the
    /// future; a dispatcher only ever has a prediction).
    fn load(&self, u: usize, now: f64) -> f64 {
        let unit = &self.units[u];
        let in_service =
            if unit.run.is_some() { (unit.est_end_s - now).max(0.0) } else { 0.0 };
        in_service + unit.queued_s
    }

    /// Least-loaded dispatch of job `j`'s current stage; `false` means
    /// every eligible queue is full. Stage-0 jobs respect the per-unit
    /// queue cap (admission backpressure); later stages of an admitted
    /// job always find a queue — admission gates jobs, not the
    /// pipeline's interior.
    fn try_assign(&mut self, j: usize, now: f64) -> bool {
        // A retried job already passed admission once, so a re-dispatch
        // bypasses the stage-0 queue cap exactly like a mid-job stage.
        let first = self.jobs[j].stage == 0 && self.jobs[j].attempts == 0;
        let mut best: Option<(f64, usize)> = None;
        for u in 0..self.units.len() {
            let unit = &self.units[u];
            if unit.down {
                continue;
            }
            let eligible =
                unit.run.is_none() || !first || unit.queue.len() < self.cfg.queue_cap;
            if !eligible {
                continue;
            }
            let load = self.load(u, now);
            match best {
                Some((b, _)) if load >= b => {}
                _ => best = Some((load, u)),
            }
        }
        let Some((_, u)) = best else { return false };
        if self.units[u].run.is_none() {
            // Idle units always have empty queues (they drain or steal
            // before idling), so this stage starts immediately.
            self.start_stage(u, j, false, now);
        } else {
            let est = self.cur_est(j);
            self.units[u].queued_s += est;
            self.units[u].queue.push_back(j);
        }
        true
    }

    /// An idle unit with an empty queue takes the *newest* ready stage
    /// from the most-backlogged peer (steal-from-tail keeps the
    /// victim's FIFO head intact) — replay's policy at stage
    /// granularity.
    fn steal_for(&mut self, u: usize) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for v in 0..self.units.len() {
            if v == u || self.units[v].queue.is_empty() {
                continue;
            }
            let backlog = self.units[v].queued_s;
            match best {
                Some((b, _)) if backlog <= b => {}
                _ => best = Some((backlog, v)),
            }
        }
        let (_, v) = best?;
        let j = self.units[v].queue.pop_back()?;
        let est = self.cur_est(j);
        self.units[v].queued_s -= est;
        Some(j)
    }

    /// Begin job `j`'s current stage on idle unit `u`: prepare the
    /// stage kernel (program + preloaded data + verifier), install it
    /// on a fresh live machine, and schedule the unit's first calendar
    /// step. A preparation failure degrades this one job and leaves
    /// the unit idle (the caller's dispatch loop moves on).
    fn start_stage(&mut self, u: usize, j: usize, stolen: bool, now: f64) {
        let (kernel, n, est_s) = {
            let st = &self.class_of(j).stages[self.jobs[j].stage];
            (st.kernel.clone(), st.n, st.est_s)
        };
        match workloads::prepare(&kernel, n, Features::ALL, Goal::Latency) {
            Err(e) => {
                self.failed += 1;
                if !self.jobs[j].foreign {
                    self.mid_run_deaths += 1;
                }
                self.stage_errors
                    .push(format!("cosim: {kernel} n={n} failed to prepare: {e}"));
            }
            Ok(prep) => {
                let Prepared { mut machine, prog, verify, .. } = prep;
                machine.begin(prog);
                let job = &mut self.jobs[j];
                if job.stage == 0 {
                    job.start_s = now;
                }
                if !job.ord_set {
                    // First local stage: local jobs hit this at stage 0
                    // (identical ordinals to the uncoupled engine),
                    // migrants at whatever stage they resume at.
                    job.ord_set = true;
                    job.start_ord = self.next_ord;
                    self.next_ord += 1;
                }
                job.stolen |= stolen;
                let unit = &mut self.units[u];
                if stolen {
                    unit.stats.stolen += 1;
                }
                // Stage-pipelined units serve *stages*, so the per-unit
                // jobs/stolen counters count stage executions here
                // (replay counts whole jobs; identical for single-stage
                // classes). See `CosimRun::units`.
                unit.stats.jobs += 1;
                // A degraded unit is predicted (and simulated) `mult`
                // times slower; `mult == 1.0` multiplies exactly.
                unit.est_end_s = now + est_s * unit.mult;
                unit.run = Some(Active { job: j, machine, verify, start_s: now, done: None });
                self.cal.push(now, Ev::Step(u));
            }
        }
    }

    /// Advance unit `u`'s live machine by one bounded chunk (or to
    /// stage completion when the calendar holds nothing else). Units
    /// never interact mid-stage, so any chunking is conservative and
    /// cannot change results — only how finely machine progress
    /// interleaves with the rest of the timeline.
    fn on_step(&mut self, u: usize, now: f64) {
        enum Next {
            Done(f64),
            Again(f64),
            /// (job index, rendered simulator error)
            Fail(usize, String),
        }
        // Calendar lookahead: does anything else want the timeline
        // before this stage could end? If not, the stage runs out in
        // one go; otherwise advance one fixed chunk and yield. Fixed
        // chunks (rather than horizon-shaped ones) matter for
        // determinism across engines: every machine's chunk grid
        // depends only on its own stage, so units that started
        // identical stages at the same instant stay in exact cycle
        // lockstep and retire in the deterministic unit order the
        // replay engine uses — a horizon-shaped limit would hand
        // whichever unit popped second a head start (it sees the first
        // unit's *next* event, one chunk further out). Results never
        // depend on chunking (advance_until is chunk-invisible); only
        // event interleaving granularity does.
        let others_pending = self.cal.peek_time().is_some();
        let mult = self.units[u].mult;
        let next = {
            let Some(active) = self.units[u].run.as_mut() else { return };
            if active.done.is_some() {
                return; // stage already finished; StageDone is pending
            }
            let limit = if others_pending {
                active.machine.now().saturating_add(MIN_CHUNK)
            } else {
                u64::MAX
            };
            // Degraded units stretch simulated cycles by `mult` in
            // virtual time; the healthy multiplier 1.0 is bit-exact.
            match active.machine.advance_until(limit) {
                Err(e) => Next::Fail(active.job, e.to_string()),
                Ok(true) => {
                    let finish = active.start_s + s_of(active.machine.now()) * mult;
                    active.done = Some(finish);
                    Next::Done(finish)
                }
                Ok(false) => {
                    Next::Again(active.start_s + s_of(active.machine.now()) * mult)
                }
            }
        };
        match next {
            Next::Done(finish) => self.cal.push(finish, Ev::StageDone(u)),
            Next::Again(t) => self.cal.push(t, Ev::Step(u)),
            Next::Fail(j, err) => {
                let msg = format!(
                    "cosim: job {} stage {} on unit {u} aborted: {err}",
                    self.jobs[j].id, self.jobs[j].stage
                );
                self.stage_errors.push(msg);
                self.failed += 1;
                if !self.jobs[j].foreign {
                    self.mid_run_deaths += 1;
                }
                self.units[u].run = None;
                self.dispatch_free(u, now);
            }
        }
    }

    /// Retire unit `u`'s finished stage: account its live-measured
    /// cycles, verify its functional outputs, hand the subframe to the
    /// shared interconnect — or over the fronthaul to the neighbor
    /// cell, when the handover draw fires — or complete it after its
    /// last stage, and put the freed unit back to work. Returns
    /// whether a *locally submitted* job completed (the closed-loop
    /// client it frees resubmits; a migrant's completion belongs to
    /// its source cell's loop, which already resubmitted on egress).
    fn on_stage_done(&mut self, u: usize, t: f64) -> bool {
        let Some(active) = self.units[u].run.take() else { return false };
        if active.done != Some(t) {
            // Stale retirement: the stage this event was scheduled for
            // was crash-killed and the unit has since started another.
            // Put the live stage back; its own StageDone is pending.
            self.units[u].run = Some(active);
            return false;
        }
        let Active { job: j, machine, verify, start_s: _, done } = active;
        let finish = done.unwrap_or(t);
        let cycles = machine.now();
        self.units[u].stats.busy_s += s_of(cycles) * self.units[u].mult;
        // Transient fault plane: the draw is an identity-keyed hash of
        // (seed, cell, job, stage, attempt) — never a stream RNG — so
        // the verdict for this attempt is invariant under event pop
        // order, reruns, and shard counts. A struck stage discards its
        // result and re-enters dispatch through the bounded-retry path.
        if self.faults.stage_fails(
            self.fault_seed,
            self.coupling.cell,
            self.jobs[j].id,
            self.jobs[j].stage,
            self.jobs[j].attempts,
        ) {
            drop(machine);
            self.retry_or_fail(j, finish, "transient stage fault");
            self.dispatch_free(u, finish);
            return false;
        }
        let verdict = verify(&machine);
        drop(machine);
        let mut completed = false;
        match verdict {
            Err(e) => {
                self.failed += 1;
                if !self.jobs[j].foreign {
                    self.mid_run_deaths += 1;
                }
                let job = &self.jobs[j];
                self.stage_errors.push(format!(
                    "cosim: job {} stage {} failed verification: {e}",
                    job.id, job.stage
                ));
            }
            Ok(_max_err) => {
                self.jobs[j].cycles.push(cycles);
                let nstages = self.class_of(j).stages.len();
                if self.jobs[j].stage + 1 < nstages {
                    // The handover stream is only consulted when a
                    // positive fraction is configured, so uncoupled
                    // runs make zero draws and stay bit-identical.
                    if self.coupling.handover_frac > 0.0
                        && self.coupling.active()
                        && self.hand_rng.f64() < self.coupling.handover_frac
                    {
                        self.migrate_out(j, finish);
                    } else {
                        self.request_handoff(j, finish);
                    }
                } else {
                    let job = &self.jobs[j];
                    let comp = Completion {
                        id: job.id,
                        class: job.class,
                        unit: u,
                        arrival_s: job.arrival_s,
                        start_s: job.start_s,
                        finish_s: finish,
                        stolen: job.stolen,
                    };
                    if finish > self.makespan_s {
                        self.makespan_s = finish;
                    }
                    self.done_jobs.push((job.start_ord, comp, job.cycles.clone()));
                    completed = !job.foreign;
                }
            }
        }
        self.dispatch_free(u, finish);
        completed
    }

    /// Hand job `j` over to the ring neighbor: its remaining stages
    /// run there after one fronthaul traversal. The fronthaul is a
    /// dedicated point-to-point link with fixed latency — the local
    /// shared interconnect is not involved, so a handover frees the
    /// bus slot an intra-cell handoff would have taken.
    fn migrate_out(&mut self, j: usize, now: f64) {
        self.migrated_out += 1;
        let job = &self.jobs[j];
        if !job.foreign {
            self.local_egress += 1;
        }
        let m = Migrant {
            id: job.id,
            class: job.class,
            arrival_s: job.arrival_s,
            start_s: job.start_s,
            stolen: job.stolen,
            stage: job.stage + 1,
            cycles: job.cycles.clone(),
        };
        let dst = Some((self.coupling.cell + 1) % self.coupling.cells);
        self.emit(dst, now, Msg::Migrate(m));
    }

    /// Put one cross-cell message on the fronthaul, applying any link
    /// fault window covering its send time. A *dropped* message is
    /// re-offered to this cell's own calendar after the (wasted)
    /// traversal — the subframe or arrival rejoins the origin cell's
    /// queue instead of being lost, so conservation holds with the link
    /// down. A *delayed* message stays outbound with extra latency;
    /// later delivery is always CMB-safe (strictly further into the
    /// receiver's future than the lookahead requires).
    fn emit(&mut self, dst: Option<usize>, now: f64, msg: Msg) {
        let t_s = now + self.coupling.fronthaul_s;
        match self.faults.link_fault_at(now).map(|l| l.extra_s) {
            Some(None) => {
                self.link_dropped += 1;
                match msg {
                    Msg::Migrate(m) => self.cal.push(t_s, Ev::MigrateIn(m)),
                    Msg::Shed(a) => self.cal.push(t_s, Ev::Rerouted(a)),
                }
            }
            Some(Some(extra_s)) => {
                self.link_delayed += 1;
                self.outbox.push(Outbound { dst, t_s: t_s + extra_s, msg });
            }
            None => self.outbox.push(Outbound { dst, t_s, msg }),
        }
    }

    /// Job `j`'s current stage must run again (transient fault, crash
    /// kill, or no unit available): consume one bounded-retry attempt.
    /// Within budget, the stage re-enters dispatch after an exponential
    /// virtual-time backoff; exhausted, the job lands in the `failed`
    /// terminal (freeing its closed-loop client via `mid_run_deaths`).
    fn retry_or_fail(&mut self, j: usize, now: f64, why: &str) {
        self.jobs[j].attempts += 1;
        let attempts = self.jobs[j].attempts;
        if attempts > self.faults.max_retries {
            self.failed += 1;
            if !self.jobs[j].foreign {
                self.mid_run_deaths += 1;
            }
            self.stage_errors.push(format!(
                "cosim: job {} stage {} failed after {} attempts: {why}",
                self.jobs[j].id,
                self.jobs[j].stage,
                attempts - 1
            ));
        } else {
            self.retries += 1;
            self.cal.push(now + self.faults.backoff_for(attempts), Ev::Retry(j));
        }
    }

    /// Re-enter dispatch for job `j`'s current stage, falling back to
    /// the bounded-retry path when no unit can take it (every unit
    /// down). Fault-free runs never hit the fallback — with `>= 1`
    /// healthy unit, mid-job and retried stages always find a queue.
    fn redispatch(&mut self, j: usize, now: f64) {
        if !self.try_assign(j, now) {
            self.retry_or_fail(j, now, "no unit available");
        }
    }

    /// Fault plane: unit `u` crashes. Its in-flight stage is killed
    /// (the partial compute stays charged as busy time) and re-enters
    /// dispatch through the retry path; its ready queue drains to the
    /// surviving peers. With *every* unit down, admission-queued jobs
    /// would deadlock the calendar — they enter the retry path too, so
    /// the run always terminates with clean `failed` accounting even
    /// when the only unit dies for good.
    fn on_crash(&mut self, u: usize, now: f64) {
        if self.units[u].down {
            return;
        }
        self.units[u].down = true;
        if let Some(active) = self.units[u].run.take() {
            self.crash_kills += 1;
            let j = active.job;
            self.units[u].stats.busy_s += (now - active.start_s).max(0.0);
            drop(active);
            self.retry_or_fail(j, now, "unit crashed");
        }
        self.units[u].est_end_s = now;
        let drained: Vec<usize> = self.units[u].queue.drain(..).collect();
        self.units[u].queued_s = 0.0;
        for j in drained {
            self.redispatch(j, now);
        }
        if self.units.iter().all(|un| un.down) {
            let stuck: Vec<usize> = self.admission.drain(..).collect();
            for j in stuck {
                self.retry_or_fail(j, now, "all units down");
            }
        }
    }

    /// Fault plane: unit `u` recovers from its outage and immediately
    /// pulls ready work (its queue is empty — crashes drain it — so
    /// this steals or drains admission).
    fn on_recover(&mut self, u: usize, now: f64) {
        if !self.units[u].down {
            return;
        }
        self.units[u].down = false;
        self.dispatch_free(u, now);
    }

    /// A backoff expired: the retried stage tries dispatch again (and
    /// consumes another attempt if every unit is still down).
    fn on_retry(&mut self, j: usize, now: f64) {
        self.redispatch(j, now);
    }

    /// A migrant landed: resume it at its carried stage. Mid-chain
    /// stages bypass admission (the job was admitted at its source
    /// cell), exactly like a local job between stages.
    fn on_migrate_in(&mut self, m: Migrant, now: f64) {
        self.migrated_in += 1;
        let j = self.jobs.len();
        self.jobs.push(Job {
            id: m.id,
            class: m.class,
            arrival_s: m.arrival_s,
            stage: m.stage,
            start_s: m.start_s,
            start_ord: 0,
            ord_set: false,
            foreign: true,
            stolen: m.stolen,
            attempts: 0,
            cycles: m.cycles,
        });
        // Mid-job stages always find a queue — unless every unit is
        // down, in which case the migrant rides the retry path.
        self.redispatch(j, now);
    }

    fn request_handoff(&mut self, j: usize, now: f64) {
        self.bus_fifo.push_back((j, now));
        self.try_grant(now);
    }

    /// Grant the interconnect to the oldest pending handoff (capacity
    /// one, FIFO — the serialization replay cannot model).
    fn try_grant(&mut self, now: f64) {
        if self.bus_busy {
            return;
        }
        let Some((j, req_s)) = self.bus_fifo.pop_front() else { return };
        let h_s = {
            let next = &self.class_of(j).stages[self.jobs[j].stage + 1];
            s_of(model::handoff_cycles(&next.kernel, next.n))
        };
        self.bus_busy = true;
        self.bus_wait_s += now - req_s;
        self.bus_busy_s += h_s;
        self.handoffs += 1;
        self.cal.push(now + h_s, Ev::BusDone(j));
    }

    /// Job `j`'s handoff left the interconnect: its next stage is
    /// ready and re-enters least-loaded dispatch (possibly on a
    /// different unit — stage-granularity load balancing).
    fn on_bus_done(&mut self, j: usize, now: f64) {
        self.bus_busy = false;
        self.jobs[j].stage += 1;
        // Mid-job stages bypass the queue cap, so with >= 1 healthy
        // unit this dispatch cannot fail; with every unit down the
        // stage rides the bounded-retry path instead.
        self.redispatch(j, now);
        self.try_grant(now);
    }

    /// Calendar-lookahead completion prediction for SLO admission: the
    /// least-loaded unit's backlog, this arrival's share of the
    /// admission queue, and the class's full service + handoff demand.
    fn predict_latency(&self, class: usize, now: f64) -> f64 {
        let demand = self.classes[class]
            .as_ref()
            .map(CosimClass::demand_s)
            .unwrap_or(0.0);
        let best_wait = (0..self.units.len())
            .filter(|&u| !self.units[u].down)
            .map(|u| self.load(u, now))
            .fold(f64::INFINITY, f64::min);
        let admitted: f64 = self
            .admission
            .iter()
            .filter_map(|&j| self.classes[self.jobs[j].class].as_ref())
            .map(CosimClass::demand_s)
            .sum();
        best_wait + admitted / self.units.len() as f64 + demand
    }

    /// An admission decision went against arrival `a`: re-offer it to
    /// the metro when this cell may (reroute enabled, first hop), else
    /// count it locally — `deadline_shed` for an SLO miss, `dropped`
    /// for admission overflow. Returns whether the arrival died at
    /// this cell's door.
    fn shed(&mut self, a: Arrival, now: f64, rerouted: bool, slo: bool) -> bool {
        if self.coupling.reroute && !rerouted && self.coupling.active() {
            self.rerouted_out += 1;
            self.local_egress += 1;
            self.emit(None, now, Msg::Shed(a));
            false
        } else if slo {
            self.deadline_shed += 1;
            true
        } else {
            self.dropped += 1;
            false
        }
    }

    /// Returns whether the arrival died at the door (degraded class or
    /// SLO shed) — the closed-loop workload resubmits those.
    /// `rerouted` marks an arrival re-offered by a peer: it already
    /// burned fronthaul time (`now > a.t_s`, charged against the
    /// deadline) and a second shed is terminal.
    fn on_arrive(&mut self, a: Arrival, now: f64, rerouted: bool) -> bool {
        if self.classes.get(a.class).and_then(|c| c.as_ref()).is_none() {
            self.failed += 1;
            return true;
        }
        if let Some(dl) = self.deadline_s {
            // Elapsed-since-arrival plus predicted completion. Local
            // arrivals pop at exactly `a.t_s`, so the elapsed term is
            // exactly zero and the predicate is unchanged from the
            // uncoupled engine.
            if (now - a.t_s) + self.predict_latency(a.class, now) > dl {
                return self.shed(a, now, rerouted, true);
            }
        }
        let j = self.jobs.len();
        self.jobs.push(Job {
            id: a.id,
            class: a.class,
            arrival_s: a.t_s,
            stage: 0,
            start_s: 0.0,
            start_ord: 0,
            ord_set: false,
            foreign: rerouted,
            stolen: false,
            attempts: 0,
            cycles: Vec::new(),
        });
        if self.try_assign(j, now) {
            return false;
        }
        if self.units.iter().all(|un| un.down) {
            // Every unit is down: the admission queue would never
            // drain, so the job waits out the outage in the bounded-
            // retry path (terminating in `failed` if nothing recovers).
            self.retry_or_fail(j, now, "all units down");
            return false;
        }
        if self.admission.len() < self.cfg.admit_cap {
            self.admission.push_back(j);
            self.peak_admit_queue = self.peak_admit_queue.max(self.admission.len());
            false
        } else {
            self.shed(a, now, rerouted, false)
        }
    }

    /// Move admission-queue jobs into freed run-queue slots, in FIFO
    /// order, until assignment backpressures again.
    fn drain_admission(&mut self, now: f64) {
        while let Some(&j) = self.admission.front() {
            if self.try_assign(j, now) {
                self.admission.pop_front();
            } else {
                break;
            }
        }
    }

    /// Put a freed unit back to work: its own FIFO head, else a stolen
    /// stage; loop past stages that fail to prepare.
    fn dispatch_free(&mut self, u: usize, now: f64) {
        while self.units[u].run.is_none() && !self.units[u].down {
            let next = if let Some(j) = self.units[u].queue.pop_front() {
                let est = self.cur_est(j);
                self.units[u].queued_s -= est;
                Some((j, false))
            } else {
                self.steal_for(u).map(|j| (j, true))
            };
            let Some((j, stolen)) = next else { break };
            self.start_stage(u, j, stolen, now);
        }
        self.drain_admission(now);
    }
}

/// A resumable co-simulation: the whole engine state between two
/// conservative synchronization horizons. The single-timeline [`run`]
/// below drives one session to exhaustion; the sharded multi-cell path
/// ([`super::shard`]) holds one session per cell and advances them
/// window by window on pool threads — which is why a session is `Send`
/// (live machines, verifiers, and the class picker all migrate with
/// it) while its behavior stays identical to the single-threaded run:
/// `advance_to(h)` processes exactly the events strictly before `h`,
/// in the same order [`run`] would.
pub struct CosimSession<'a> {
    eng: Engine<'a>,
    remaining: usize,
    next_id: u64,
    closed: bool,
    first_arrival: Option<f64>,
    seen_deaths: usize,
    seen_egress: usize,
    pick: Box<dyn FnMut() -> usize + Send + 'a>,
}

// A session migrates between pool threads at horizon barriers.
fn _cosim_session_is_send(s: CosimSession<'static>) -> impl Send {
    s
}

impl<'a> CosimSession<'a> {
    /// Build the session and schedule the workload's initial arrivals.
    /// Same inputs as [`run`]; the class picker must be `Send` so the
    /// session can advance on a pool thread. Uncoupled: the cell never
    /// emits or receives cross-cell events.
    pub fn new(
        cfg: &CosimConfig,
        classes: &'a [Option<CosimClass>],
        workload: Workload<'_>,
        pick_class: impl FnMut() -> usize + Send + 'a,
    ) -> Self {
        Self::with_coupling(cfg, classes, workload, pick_class, Coupling::none(), Rng::new(0))
    }

    /// [`CosimSession::new`] plus a cross-cell role: `coupling` names
    /// this cell's place in the metro and `hand_rng` seeds its
    /// dedicated handover stream (unused — zero draws — unless
    /// `coupling.handover_frac > 0`).
    pub fn with_coupling(
        cfg: &CosimConfig,
        classes: &'a [Option<CosimClass>],
        workload: Workload<'_>,
        pick_class: impl FnMut() -> usize + Send + 'a,
        coupling: Coupling,
        hand_rng: Rng,
    ) -> Self {
        // Live stages run real kernels; make sure the watchdog budget
        // covers the legitimately long ones (the harness's budget).
        crate::harness::ensure_budget();
        let cl = ClusterConfig {
            units: cfg.cluster.units.max(1),
            queue_cap: cfg.cluster.queue_cap.max(1),
            admit_cap: cfg.cluster.admit_cap,
        };
        let eng = Engine {
            units: (0..cl.units).map(|_| Unit::new()).collect(),
            cfg: cl,
            deadline_s: cfg.deadline_s,
            classes,
            cal: Calendar::new(),
            jobs: Vec::new(),
            admission: VecDeque::new(),
            bus_busy: false,
            bus_fifo: VecDeque::new(),
            next_ord: 0,
            mid_run_deaths: 0,
            done_jobs: Vec::new(),
            dropped: 0,
            deadline_shed: 0,
            failed: 0,
            makespan_s: 0.0,
            peak_admit_queue: 0,
            handoffs: 0,
            bus_busy_s: 0.0,
            bus_wait_s: 0.0,
            coupling,
            hand_rng,
            outbox: Vec::new(),
            migrated_out: 0,
            migrated_in: 0,
            rerouted_out: 0,
            rerouted_in: 0,
            local_egress: 0,
            last_t: f64::NEG_INFINITY,
            causality_violations: 0,
            faults: FaultPlan::default(),
            fault_seed: 0,
            retries: 0,
            crash_kills: 0,
            link_dropped: 0,
            link_delayed: 0,
            stage_errors: Vec::new(),
        };
        let mut s = CosimSession {
            eng,
            remaining: 0,
            next_id: 0,
            closed: false,
            first_arrival: None,
            seen_deaths: 0,
            seen_egress: 0,
            pick: Box::new(pick_class),
        };
        match workload {
            Workload::Open(trace) => {
                for a in trace {
                    s.eng.cal.push(a.t_s, Ev::Arrive(*a));
                }
            }
            Workload::Closed { clients, jobs } => {
                let c = clients.max(1).min(jobs);
                for id in 0..c {
                    let class = (s.pick)();
                    s.eng.cal.push(
                        0.0,
                        Ev::Arrive(Arrival { id: id as u64, class, t_s: 0.0 }),
                    );
                }
                s.remaining = jobs - c;
                s.next_id = c as u64;
                s.closed = true;
            }
        }
        s
    }

    /// Arm a fault scenario on this cell: store the plan (recovery
    /// policy included), seed the identity-keyed transient stream with
    /// the *cluster* seed (the cell index is folded into every draw's
    /// key), apply degraded-unit multipliers, and schedule this cell's
    /// crash/recover events. Call before the first
    /// [`CosimSession::advance_to`]; an unarmed session is fault-free.
    pub fn with_faults(mut self, plan: &FaultPlan, seed: u64) -> Self {
        let cell = self.eng.coupling.cell;
        for o in plan.outages_for(cell) {
            if o.unit < self.eng.units.len() {
                self.eng.cal.push(o.down_s, Ev::Crash(o.unit));
                if o.up_s.is_finite() {
                    self.eng.cal.push(o.up_s, Ev::Recover(o.unit));
                }
            }
        }
        for (u, unit) in self.eng.units.iter_mut().enumerate() {
            unit.mult = plan.mult_for(cell, u);
        }
        self.eng.faults = plan.clone();
        self.eng.fault_seed = seed;
        self
    }

    /// Timestamp of the next pending event, if any — what a sharded
    /// driver inspects to decide whether another window is needed.
    pub fn next_time(&self) -> Option<f64> {
        self.eng.cal.peek_time()
    }

    /// Process every event scheduled strictly before `horizon`, in
    /// calendar order (time, then FIFO within a timestamp). Returns
    /// `true` once the calendar is empty — the session is drained and
    /// ready to [`CosimSession::finish`]. Conservative-DES contract:
    /// any ascending horizon schedule yields the run [`run`] produces,
    /// because events an event creates never precede their creator.
    pub fn advance_to(&mut self, horizon: f64) -> bool {
        while let Some((now, ev)) = self.eng.cal.pop_before(horizon) {
            if now > self.eng.last_t {
                self.eng.last_t = now;
            }
            let resubmit = match ev {
                Ev::Arrive(a) => {
                    self.first_arrival.get_or_insert(now);
                    let dead = self.eng.on_arrive(a, now, false);
                    self.closed && dead
                }
                Ev::Step(u) => {
                    self.eng.on_step(u, now);
                    false
                }
                Ev::StageDone(u) => {
                    let completed = self.eng.on_stage_done(u, now);
                    self.closed && completed
                }
                Ev::BusDone(j) => {
                    self.eng.on_bus_done(j, now);
                    false
                }
                Ev::MigrateIn(m) => {
                    self.eng.on_migrate_in(m, now);
                    false
                }
                Ev::Rerouted(a) => {
                    self.eng.rerouted_in += 1;
                    // A foreign arrival's death never frees a local
                    // closed-loop client; its source cell already
                    // resubmitted on egress.
                    self.eng.on_arrive(a, now, true);
                    false
                }
                // Fault-plane events resubmit through the
                // `mid_run_deaths` delta below, never directly.
                Ev::Crash(u) => {
                    self.eng.on_crash(u, now);
                    false
                }
                Ev::Recover(u) => {
                    self.eng.on_recover(u, now);
                    false
                }
                Ev::Retry(j) => {
                    self.eng.on_retry(j, now);
                    false
                }
            };
            // Closed loop: a client resubmits when its job leaves the
            // system — on completion, on a dead arrival, when a job
            // dies mid-run (stage prepare/simulate/verify failure),
            // and when its job leaves the cell over the fronthaul
            // (handover or re-route egress) — so neither failures nor
            // migration ever silently starve the loop.
            let mut want = usize::from(resubmit);
            if self.closed {
                want += self.eng.mid_run_deaths - self.seen_deaths;
                want += self.eng.local_egress - self.seen_egress;
            }
            self.seen_deaths = self.eng.mid_run_deaths;
            self.seen_egress = self.eng.local_egress;
            while want > 0 && self.remaining > 0 {
                let class = (self.pick)();
                self.eng.cal.push(
                    now,
                    Ev::Arrive(Arrival { id: self.next_id, class, t_s: now }),
                );
                self.next_id += 1;
                self.remaining -= 1;
                want -= 1;
            }
        }
        self.eng.cal.is_empty()
    }

    /// Take every cross-cell message emitted since the last drain, in
    /// emit order. The sharded driver calls this at each horizon
    /// barrier; a coupled session advanced without draining would
    /// silently lose its cross-cell traffic, so only drive coupled
    /// sessions through [`super::shard::run_sharded`].
    pub fn drain_outbox(&mut self) -> Vec<Outbound> {
        std::mem::take(&mut self.eng.outbox)
    }

    /// Deliver a cross-cell message into this cell's calendar at its
    /// fronthaul arrival time. A delivery behind the cell's clock is
    /// counted as a causality violation (and processed anyway, late) —
    /// impossible while the horizon window respects the fronthaul
    /// lookahead, and exactly what the canary suite provokes to prove
    /// that bound is load-bearing.
    pub fn deliver(&mut self, out: Outbound) {
        if out.t_s < self.eng.last_t {
            self.eng.causality_violations += 1;
        }
        match out.msg {
            Msg::Migrate(m) => self.eng.cal.push(out.t_s, Ev::MigrateIn(m)),
            Msg::Shed(a) => self.eng.cal.push(out.t_s, Ev::Rerouted(a)),
        }
    }

    /// Predicted backlog seconds across the whole cell at `now`: every
    /// unit's in-service remainder and queue, plus the admission
    /// queue's demand. The sharded driver ranks cells by this at
    /// horizon barriers to route re-offered arrivals to the
    /// least-backlogged peer with horizon-consistent state.
    pub fn backlog_s(&self, now: f64) -> f64 {
        let e = &self.eng;
        let units: f64 = (0..e.units.len()).map(|u| e.load(u, now)).sum();
        let admitted: f64 = e
            .admission
            .iter()
            .filter_map(|&j| e.classes[e.jobs[j].class].as_ref())
            .map(CosimClass::demand_s)
            .sum();
        units + admitted
    }

    /// Seal the run: sort completions into service-start order and
    /// normalize the makespan to the first arrival (replay's
    /// convention). Call after [`CosimSession::advance_to`] drained the
    /// calendar; a non-drained session simply reports what completed.
    pub fn finish(self) -> CosimRun {
        let mut eng = self.eng;
        eng.done_jobs.sort_by_key(|&(ord, _, _)| ord);
        let mut out = CosimRun {
            completions: eng.done_jobs.iter().map(|(_, c, _)| *c).collect(),
            stage_cycles: eng.done_jobs.into_iter().map(|(_, _, cy)| cy).collect(),
            dropped: eng.dropped,
            deadline_shed: eng.deadline_shed,
            failed: eng.failed,
            units: eng.units.iter().map(|u| u.stats.clone()).collect(),
            makespan_s: eng.makespan_s,
            peak_admit_queue: eng.peak_admit_queue,
            handoffs: eng.handoffs,
            bus_busy_s: eng.bus_busy_s,
            bus_wait_s: eng.bus_wait_s,
            migrated_out: eng.migrated_out,
            migrated_in: eng.migrated_in,
            rerouted_out: eng.rerouted_out,
            rerouted_in: eng.rerouted_in,
            causality_violations: eng.causality_violations,
            retries: eng.retries,
            crash_kills: eng.crash_kills,
            link_dropped: eng.link_dropped,
            link_delayed: eng.link_delayed,
            stage_errors: eng.stage_errors,
        };
        // Events pop in time order, so the first Arrive seen is the
        // trace start; makespan is measured from it.
        if let Some(t0) = self.first_arrival {
            out.makespan_s = (out.makespan_s - t0).max(0.0);
        }
        out
    }
}

/// Co-simulate a workload on the cluster to completion on the calling
/// thread. Same contract as [`super::cluster::run`] — deterministic:
/// identical inputs give a bit-identical [`CosimRun`] — with per-class
/// stage chains instead of a memoized service table. All failures
/// (degraded classes, mid-run stage errors) are recorded in the run,
/// never panicked.
pub fn run(
    cfg: &CosimConfig,
    classes: &[Option<CosimClass>],
    workload: Workload<'_>,
    pick_class: impl FnMut() -> usize + Send,
) -> CosimRun {
    let mut s = CosimSession::new(cfg, classes, workload, pick_class);
    s.advance_to(f64::INFINITY);
    s.finish()
}

// ---------------------------------------------------------------------------
// Tiled task-graph factorizations (`revel dag`)
// ---------------------------------------------------------------------------

/// Scratchpad slot name pool for the DAG engine's tile-resident
/// regions (one name per live slot; the allocator requires static
/// names). 24 names cover the smallest supported tile (b = 8 fills the
/// default scratchpad at 24 slots before exhausting capacity).
const SLOT_NAMES: [&str; 24] = [
    "tg.s00", "tg.s01", "tg.s02", "tg.s03", "tg.s04", "tg.s05", "tg.s06",
    "tg.s07", "tg.s08", "tg.s09", "tg.s10", "tg.s11", "tg.s12", "tg.s13",
    "tg.s14", "tg.s15", "tg.s16", "tg.s17", "tg.s18", "tg.s19", "tg.s20",
    "tg.s21", "tg.s22", "tg.s23",
];

/// Configuration of one DAG-scheduled tiled factorization run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DagConfig {
    /// Which factorization to decompose.
    pub kernel: DagKernel,
    /// Problem size (`n x n`); must be a multiple of `tile`.
    pub n: usize,
    /// Tile dimension `b`.
    pub tile: usize,
    /// Number of persistent units to schedule across.
    pub units: usize,
}

/// Per-unit occupancy accounting of a DAG run.
#[derive(Clone, Debug, PartialEq)]
pub struct DagUnitStat {
    /// Unit index.
    pub unit: usize,
    /// Tile tasks this unit executed.
    pub tasks: usize,
    /// Cycles this unit spent computing (excludes transfer waits).
    pub busy_cycles: u64,
}

/// Result of a DAG-scheduled tiled factorization.
#[derive(Clone, Debug, PartialEq)]
pub struct DagRun {
    /// Total tile tasks executed.
    pub tasks: usize,
    /// Schedule-independent critical-path bound (per-class measured
    /// costs, no transfer time) — the makespan floor at infinite units.
    pub critical_path_cycles: u64,
    /// Achieved end-to-end cycles (last task completion).
    pub makespan_cycles: u64,
    /// Sum of all units' compute cycles.
    pub total_compute_cycles: u64,
    /// Tile transfers billed on the shared interconnect.
    pub handoffs: u64,
    /// Words those transfers moved.
    pub handoff_words: u64,
    /// Cycles the shared bus spent transferring.
    pub bus_busy_cycles: u64,
    /// Cycles transfers waited on the busy bus before starting.
    pub bus_wait_cycles: u64,
    /// Needed tiles found already resident in a unit's scratchpad
    /// (re-load skipped — the machine-state-reuse payoff).
    pub resident_hits: u64,
    /// Resident tiles displaced to make room (LRU).
    pub evictions: u64,
    /// FNV-1a digest of the factor bits ([`exec::digest`]): must be
    /// identical for every unit count and equal to the host replay.
    pub factor_digest: u64,
    /// Fault plane: units killed by a [`DagFaultPlan`] crash.
    pub unit_crashes: u64,
    /// Fault plane: in-flight tasks killed with their unit and
    /// re-executed on a survivor (timing only — the numerics of record
    /// were applied at first dispatch and are never re-applied, which
    /// is what pins the digest to the fault-free run).
    pub tasks_rescheduled: u64,
    /// Per-unit occupancy.
    pub per_unit: Vec<DagUnitStat>,
}

impl DagRun {
    /// Summary JSON for `BENCH_dag.json` (the digest renders as a hex
    /// string: JSON numbers cannot carry 64 bits losslessly).
    pub fn to_json(&self) -> Json {
        let mk = self.makespan_cycles.max(1) as f64;
        Json::obj(vec![
            ("tasks", Json::Num(self.tasks as f64)),
            ("critical_path_cycles", Json::Num(self.critical_path_cycles as f64)),
            ("makespan_cycles", Json::Num(self.makespan_cycles as f64)),
            ("total_compute_cycles", Json::Num(self.total_compute_cycles as f64)),
            ("handoffs", Json::Num(self.handoffs as f64)),
            ("handoff_words", Json::Num(self.handoff_words as f64)),
            ("bus_busy_cycles", Json::Num(self.bus_busy_cycles as f64)),
            ("bus_wait_cycles", Json::Num(self.bus_wait_cycles as f64)),
            ("resident_hits", Json::Num(self.resident_hits as f64)),
            ("evictions", Json::Num(self.evictions as f64)),
            ("unit_crashes", Json::Num(self.unit_crashes as f64)),
            ("tasks_rescheduled", Json::Num(self.tasks_rescheduled as f64)),
            ("factor_digest", Json::Str(format!("{:016x}", self.factor_digest))),
            (
                "per_unit",
                Json::Arr(
                    self.per_unit
                        .iter()
                        .map(|u| {
                            Json::obj(vec![
                                ("unit", Json::Num(u.unit as f64)),
                                ("tasks", Json::Num(u.tasks as f64)),
                                ("busy_cycles", Json::Num(u.busy_cycles as f64)),
                                (
                                    "occupancy",
                                    Json::Num(u.busy_cycles as f64 / mk),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// DAG-engine calendar payload. (Dispatch is not an event: it happens
/// eagerly whenever a completion frees a unit or releases successors.)
enum DagEv {
    /// A unit finishes its tile task.
    TaskDone { task: usize, unit: usize },
    /// Fault plane: the unit dies at this cycle, for good.
    Crash { unit: usize },
}

/// One tile-resident scratchpad slot of a unit.
struct DagSlot {
    region: Region,
    /// Which tile currently lives here, if any.
    tile: Option<(usize, usize)>,
    /// Host-side version of that tile at load/refresh time; stale
    /// (another unit advanced the tile since) means re-load.
    version: u64,
    /// Monotonic touch counter for LRU eviction.
    last_use: u64,
}

/// One persistent unit: a live machine whose scratchpad survives
/// between tile tasks, plus the slot allocator over it.
struct DagUnit {
    machine: Machine,
    alloc: SpadAlloc,
    slots: Vec<DagSlot>,
    busy: bool,
    /// Cleared by a fault-plane crash; dead units never dispatch again.
    alive: bool,
    /// Task currently in flight (fault plane kills it on crash).
    running: Option<usize>,
    tasks_done: usize,
    busy_cycles: u64,
}

/// Run a tiled factorization DAG across `cfg.units` persistent units.
///
/// Deterministic: identical configs give bit-identical [`DagRun`]s,
/// and the factor digest is invariant across unit counts (the
/// numerics of record are the host-side replay, applied in dispatch
/// order — a dependence-respecting order, which
/// [`crate::taskgraph::exec`] proves is digest-invariant). The
/// machines supply timing: per-task cycles measured live on the
/// persistent machine after [`Machine::reset_retaining_spad`].
pub fn run_dag(cfg: &DagConfig) -> Result<DagRun, String> {
    run_dag_faulted(cfg, &DagFaultPlan::default())
}

/// [`run_dag`] under a [`DagFaultPlan`]: scheduled unit crashes kill
/// the victim's in-flight task and invalidate its retained scratchpad
/// slots; the task re-executes on a survivor. Because the numerics of
/// record advance at *first* dispatch only, the factor digest is
/// pinned bit-identical to the fault-free run; only timing and the
/// fault counters differ. Every unit dead with work remaining is a
/// typed error, never a hang.
pub fn run_dag_faulted(
    cfg: &DagConfig,
    faults: &DagFaultPlan,
) -> Result<DagRun, String> {
    if cfg.units == 0 {
        return Err("units must be >= 1".into());
    }
    if let Some(&(u, _)) = faults.crashes.iter().find(|&&(u, _)| u >= cfg.units) {
        return Err(format!(
            "fault plan crashes unit {u}, but the run has {} units",
            cfg.units
        ));
    }
    let dag = TileDag::build(cfg.kernel, cfg.n, cfg.tile)?;
    let b = cfg.tile;
    let bb = (b * b) as i64;
    let spad_words = SimConfig::default().lane_spad_words;
    let align = |w: i64| -> i64 {
        let l = LINE_WORDS as i64;
        w.div_ceil(l) * l
    };
    // Slot budget: leave room for the per-era transient (plus the one
    // reusable hole it leaves behind) so slot growth can never starve
    // it. The gemm-class tasks need target + two operands resident.
    let max_slots = (((spad_words as i64 - 2 * align(b as i64)) / align(bb))
        .max(0) as usize)
        .min(SLOT_NAMES.len());
    if max_slots < 3 {
        return Err(format!(
            "tile {b} too large: {spad_words}-word scratchpad fits {max_slots} \
             slots, gemm-class tasks need 3"
        ));
    }
    let lowerer = Lowerer::new(cfg.kernel, cfg.tile).map_err(|e| e.to_string())?;
    let costs = lowerer.class_costs()?;
    let cost_of = |op: &crate::taskgraph::TileOp| -> u64 {
        *costs.get(op.class()).expect("every class was measured")
    };

    // Host matrix — the numerics of record.
    let mut host: Mat = match cfg.kernel {
        DagKernel::Cholesky => workloads::cholesky::instance(cfg.n, 0).a,
        DagKernel::Lu => workloads::lu::instance(cfg.n, 0).a,
    };
    let critical_path_cycles = dag.critical_path(cost_of);

    // Longest path to sink (own cost included): dispatch priority.
    let mut dependents: Vec<Vec<usize>> = vec![vec![]; dag.tasks.len()];
    for t in &dag.tasks {
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }
    let mut prio = vec![0u64; dag.tasks.len()];
    for id in (0..dag.tasks.len()).rev() {
        let down = dependents[id].iter().map(|&s| prio[s]).max().unwrap_or(0);
        prio[id] = down + cost_of(&dag.tasks[id].op);
    }

    let mut units: Vec<DagUnit> = (0..cfg.units)
        .map(|_| DagUnit {
            machine: workloads::machine(1),
            alloc: SpadAlloc::with_capacity(spad_words),
            slots: Vec::new(),
            busy: false,
            alive: true,
            running: None,
            tasks_done: 0,
            busy_cycles: 0,
        })
        .collect();

    let mut indeg: Vec<usize> = dag.tasks.iter().map(|t| t.deps.len()).collect();
    let mut ready: Vec<usize> =
        dag.tasks.iter().filter(|t| t.deps.is_empty()).map(|t| t.id).collect();
    let mut tile_version: BTreeMap<(usize, usize), u64> = BTreeMap::new();
    let mut cal: Calendar<DagEv> = Calendar::new();
    let mut now = 0.0f64;
    let mut bus_free = 0.0f64;
    let mut touch = 0u64;
    let mut done_tasks = 0usize;
    let mut run = DagRun {
        tasks: dag.tasks.len(),
        critical_path_cycles,
        makespan_cycles: 0,
        total_compute_cycles: 0,
        handoffs: 0,
        handoff_words: 0,
        bus_busy_cycles: 0,
        bus_wait_cycles: 0,
        resident_hits: 0,
        evictions: 0,
        factor_digest: 0,
        unit_crashes: 0,
        tasks_rescheduled: 0,
        per_unit: Vec::new(),
    };
    // Host numerics advance exactly once per task (at first dispatch);
    // fault-plane re-executions are timing-only.
    let mut applied = vec![false; dag.tasks.len()];
    for &(u, cycle) in &faults.crashes {
        cal.push(cycle as f64, DagEv::Crash { unit: u });
    }

    loop {
        // Greedy dispatch: drain (ready task, free unit) pairs.
        loop {
            // Highest priority first; ties to the lowest task id.
            let Some(&task_id) = ready
                .iter()
                .max_by(|&&a, &&b| prio[a].cmp(&prio[b]).then(b.cmp(&a)))
            else {
                break;
            };
            let op = dag.tasks[task_id].op;
            let mut needed: Vec<(usize, usize)> = vec![op.target()];
            needed.extend(op.operands());
            // Free live unit holding the most of this task's tiles
            // resident (current version); ties to the lowest unit
            // index.
            let Some(best_unit) = (0..units.len())
                .filter(|&u| units[u].alive && !units[u].busy)
                .max_by_key(|&u| {
                    let hits = needed
                        .iter()
                        .filter(|&&tl| {
                            units[u].slots.iter().any(|s| {
                                s.tile == Some(tl)
                                    && Some(&s.version) == tile_version.get(&tl)
                            })
                        })
                        .count();
                    (hits, std::cmp::Reverse(u))
                })
            else {
                break;
            };
            ready.retain(|&t| t != task_id);
            let u = &mut units[best_unit];

            // New era: drop the previous task's transient scratch.
            u.alloc.advance_era();

            // Bind each needed tile to a slot; remember which slots this
            // task claims so eviction never displaces them mid-bind.
            let mut claimed: Vec<usize> = Vec::new();
            let mut loads: Vec<(usize, (usize, usize))> = Vec::new();
            for &tl in &needed {
                let cur_ver = tile_version.get(&tl).copied().unwrap_or(0);
                if let Some(si) = u.slots.iter().position(|s| s.tile == Some(tl)) {
                    if u.slots[si].version == cur_ver {
                        run.resident_hits += 1;
                    } else {
                        loads.push((si, tl)); // stale: re-load in place
                    }
                    u.slots[si].last_use = touch;
                    touch += 1;
                    claimed.push(si);
                    continue;
                }
                let si = if u.slots.len() < max_slots {
                    let r = u
                        .alloc
                        .region(SLOT_NAMES[u.slots.len()], bb)
                        .map_err(|e| e.to_string())?;
                    u.alloc.retain(&r);
                    u.slots.push(DagSlot {
                        region: r,
                        tile: None,
                        version: 0,
                        last_use: touch,
                    });
                    u.slots.len() - 1
                } else {
                    // LRU among slots this task has not claimed.
                    let si = (0..u.slots.len())
                        .filter(|i| !claimed.contains(i))
                        .min_by_key(|&i| (u.slots[i].last_use, i))
                        .expect("max_slots >= 3 leaves an evictable slot");
                    // Recycle through the allocator so the region's
                    // lifetime is visible to it (exact-fit reuse keeps
                    // the base stable).
                    let old = u.slots[si].region;
                    let name = old.name();
                    u.alloc.free(&old);
                    let r = u.alloc.region(name, bb).map_err(|e| e.to_string())?;
                    u.alloc.retain(&r);
                    u.slots[si].region = r;
                    u.slots[si].tile = None;
                    run.evictions += 1;
                    si
                };
                u.slots[si].last_use = touch;
                touch += 1;
                claimed.push(si);
                loads.push((si, tl));
            }
            let tmp = u
                .alloc
                .region("tg.tmp", b as i64)
                .map_err(|e| e.to_string())?;

            // Bill and perform the loads: host tiles (pre-task values)
            // cross the shared interconnect into the unit's slots, one
            // transfer at a time on the capacity-1 bus.
            let mut compute_start = now;
            for &(si, (ti, tj)) in &loads {
                let cyc = model::handoff_cycles(cfg.kernel.name(), b) as f64;
                let start = now.max(bus_free);
                run.bus_wait_cycles += (start - now) as u64;
                run.bus_busy_cycles += cyc as u64;
                run.handoffs += 1;
                run.handoff_words += (b * b) as u64;
                bus_free = start + cyc;
                compute_start = bus_free;
                let base = u.slots[si].region;
                for j in 0..b {
                    for i in 0..b {
                        u.machine.lanes[0].spad.write(
                            base.addr((j * b + i) as i64),
                            host[(ti * b + i, tj * b + j)],
                        );
                    }
                }
            }

            // Advance the numerics of record (dispatch order is a
            // dependence-respecting order), then publish the new tile
            // version and mark every claimed slot current. A fault-
            // plane re-execution skips both — its numerics already
            // landed at first dispatch, so the digest cannot move.
            if !applied[task_id] {
                applied[task_id] = true;
                exec::apply(&op, b, &mut host);
                let tgt = op.target();
                let v = tile_version.entry(tgt).or_insert(0);
                *v += 1;
            }
            for (&tl, &si) in needed.iter().zip(&claimed) {
                u.slots[si].tile = Some(tl);
                u.slots[si].version = tile_version.get(&tl).copied().unwrap_or(0);
            }

            // Timing: run the relocated tile program on the persistent
            // machine (scratchpad and clock retained across tasks).
            let operand_regions: Vec<Region> = needed[1..]
                .iter()
                .zip(&claimed[1..])
                .map(|(_, &si)| u.slots[si].region)
                .collect();
            let target_region = u.slots[claimed[0]].region;
            let prog = lowerer.program(&op, &operand_regions, target_region, tmp);
            u.machine.reset_retaining_spad();
            let before = u.machine.now();
            u.machine
                .run(prog)
                .map_err(|e| format!("task {task_id} ({}): {e}", op.class()))?;
            let delta = u.machine.now() - before;
            u.busy = true;
            u.running = Some(task_id);
            u.busy_cycles += delta;
            run.total_compute_cycles += delta;
            cal.push(
                compute_start + delta as f64,
                DagEv::TaskDone { task: task_id, unit: best_unit },
            );
        }

        let Some((t, ev)) = cal.pop() else { break };
        now = t;
        match ev {
            DagEv::Crash { unit } => {
                let u = &mut units[unit];
                if u.alive {
                    u.alive = false;
                    run.unit_crashes += 1;
                    // Invalidate the dead unit's retained spad slots:
                    // nothing resident there may ever satisfy a hit
                    // again.
                    u.slots.clear();
                    if let Some(task) = u.running.take() {
                        // Kill the in-flight task back to ready; its
                        // stale TaskDone is dropped when it pops.
                        run.tasks_rescheduled += 1;
                        ready.push(task);
                    }
                }
            }
            DagEv::TaskDone { task, unit } => {
                if units[unit].alive {
                    run.makespan_cycles = run.makespan_cycles.max(t as u64);
                    units[unit].busy = false;
                    units[unit].running = None;
                    units[unit].tasks_done += 1;
                    done_tasks += 1;
                    for &s in &dependents[task] {
                        indeg[s] -= 1;
                        if indeg[s] == 0 {
                            ready.push(s);
                        }
                    }
                }
                // Dead unit: the crash already pushed `task` back to
                // ready; this retirement never happened.
            }
        }
    }

    if done_tasks != dag.tasks.len() {
        if units.iter().all(|u| !u.alive) {
            return Err(format!(
                "every unit crashed: {done_tasks}/{} tasks completed",
                dag.tasks.len()
            ));
        }
        return Err(format!(
            "scheduler stalled: {done_tasks}/{} tasks completed",
            dag.tasks.len()
        ));
    }
    exec::finalize(cfg.kernel, &mut host);
    run.factor_digest = exec::digest(&host);
    run.per_unit = units
        .iter()
        .enumerate()
        .map(|(i, u)| DagUnitStat {
            unit: i,
            tasks: u.tasks_done,
            busy_cycles: u.busy_cycles,
        })
        .collect();
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster;
    use crate::harness;

    /// Profiled estimate of one stage point, in virtual seconds (the
    /// same memoized cycles replay consumes).
    fn est(kernel: &str, n: usize) -> f64 {
        s_of(harness::cycles(kernel, n, Features::ALL, Goal::Latency).unwrap())
    }

    fn single_stage(kernel: &str, n: usize) -> Option<CosimClass> {
        Some(CosimClass {
            stages: vec![StageTask {
                kernel: kernel.into(),
                n,
                est_s: est(kernel, n),
            }],
        })
    }

    fn flood(n: usize, class: usize) -> Vec<Arrival> {
        (0..n).map(|i| Arrival { id: i as u64, class, t_s: 0.0 }).collect()
    }

    #[test]
    fn single_stage_flood_matches_replay_bit_exactly() {
        let classes = vec![single_stage("solver", 8)];
        let service =
            vec![Some([classes[0].as_ref().unwrap().stages[0].est_s, 0.0, 0.0, 0.0])];
        let cl = ClusterConfig { units: 2, queue_cap: 4, admit_cap: 32 };
        let tr = flood(10, 0);
        let replay = cluster::run(&cl, &service, Workload::Open(&tr), || 0);
        let cfg = CosimConfig { cluster: cl, deadline_s: None };
        let co = run(&cfg, &classes, Workload::Open(&tr), || 0);
        assert_eq!(co.completions, replay.completions, "per-job times/units");
        assert_eq!(co.units, replay.units, "per-unit stats");
        assert_eq!(co.makespan_s, replay.makespan_s);
        assert_eq!(co.dropped, replay.dropped);
        assert_eq!(co.handoffs, 0, "single-stage jobs never touch the bus");
        // Live cycles == the memoized cycles the estimates came from.
        let want = classes[0].as_ref().unwrap().stages[0].est_s;
        for (comp, cy) in co.completions.iter().zip(&co.stage_cycles) {
            assert_eq!(cy.len(), 1);
            assert_eq!(s_of(cy[0]), want, "job {}", comp.id);
        }
    }

    #[test]
    fn multi_stage_jobs_serialize_handoffs_on_the_shared_interconnect() {
        let two = |a: usize, b: usize| -> Option<CosimClass> {
            Some(CosimClass {
                stages: vec![
                    StageTask { kernel: "solver".into(), n: a, est_s: est("solver", a) },
                    StageTask { kernel: "gemm".into(), n: b, est_s: est("gemm", b) },
                ],
            })
        };
        let classes = vec![two(8, 12)];
        let cl = ClusterConfig { units: 2, queue_cap: 8, admit_cap: 32 };
        let cfg = CosimConfig { cluster: cl, deadline_s: None };
        let co = run(&cfg, &classes, Workload::Open(&flood(4, 0)), || 0);
        assert_eq!(co.completions.len(), 4);
        assert_eq!(co.handoffs, 4, "one handoff per job between its two stages");
        assert!(co.bus_busy_s > 0.0);
        // Every job's latency covers both stages plus its handoff.
        let demand = classes[0].as_ref().unwrap().demand_s();
        for c in &co.completions {
            assert!(
                c.finish_s - c.start_s >= demand - 1e-15,
                "job {}: {} < {}",
                c.id,
                c.finish_s - c.start_s,
                demand
            );
        }
        // Two jobs finish their first stage simultaneously (two idle
        // units, identical class): the second handoff must wait.
        assert!(co.bus_wait_s > 0.0, "concurrent handoffs must serialize");
    }

    #[test]
    fn slo_deadline_sheds_predicted_misses_at_admission() {
        let classes = vec![single_stage("solver", 8)];
        let svc = classes[0].as_ref().unwrap().stages[0].est_s;
        let cl = ClusterConfig { units: 1, queue_cap: 64, admit_cap: 64 };
        // Deadline admits ~3 queued jobs' worth of backlog.
        let cfg = CosimConfig { cluster: cl.clone(), deadline_s: Some(3.5 * svc) };
        let co = run(&cfg, &classes, Workload::Open(&flood(10, 0)), || 0);
        assert!(co.deadline_shed > 0, "flood must trip the deadline lookahead");
        assert!(co.completions.len() + co.deadline_shed == 10);
        // Admitted jobs all meet the deadline (estimates are exact here).
        for c in &co.completions {
            assert!(c.finish_s - c.arrival_s <= 3.5 * svc + 1e-12, "job {}", c.id);
        }
        // Without a deadline everything completes, some of it late.
        let all = run(
            &CosimConfig { cluster: cl, deadline_s: None },
            &classes,
            Workload::Open(&flood(10, 0)),
            || 0,
        );
        assert_eq!(all.completions.len(), 10);
        assert_eq!(all.deadline_shed, 0);
    }

    #[test]
    fn cosim_is_deterministic_and_closed_loop_self_limits() {
        let classes = vec![single_stage("solver", 8), single_stage("gemm", 12)];
        let cl = ClusterConfig { units: 2, queue_cap: 2, admit_cap: 8 };
        let cfg = CosimConfig { cluster: cl, deadline_s: None };
        let mk = || {
            let mut k = 0usize;
            run(&cfg, &classes, Workload::Closed { clients: 3, jobs: 9 }, move || {
                k += 1;
                k % 2
            })
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "bit-identical rerun");
        assert_eq!(a.completions.len(), 9);
        assert_eq!(a.dropped, 0, "closed loop self-limits");
    }

    #[test]
    fn windowed_advance_matches_one_shot_run_bit_exactly() {
        // The conservative-horizon contract: draining the session
        // through any ascending schedule of horizons yields the exact
        // run a single advance-to-infinity produces.
        let classes = vec![single_stage("solver", 8), single_stage("gemm", 12)];
        let cl = ClusterConfig { units: 2, queue_cap: 8, admit_cap: 64 };
        let cfg = CosimConfig { cluster: cl, deadline_s: None };
        let tr: Vec<Arrival> = (0..10)
            .map(|i| Arrival { id: i as u64, class: (i % 2) as usize, t_s: 0.0 })
            .collect();
        let one_shot = run(&cfg, &classes, Workload::Open(&tr), || 0);
        let mut s = CosimSession::new(&cfg, &classes, Workload::Open(&tr), || 0);
        let window = classes[0].as_ref().unwrap().stages[0].est_s / 3.0;
        let mut horizon = window;
        let mut windows = 0usize;
        while !s.advance_to(horizon) {
            horizon += window;
            windows += 1;
            assert!(windows < 100_000, "windowed run must terminate");
        }
        assert!(windows > 3, "the window must actually split the run");
        assert_eq!(s.finish(), one_shot, "windowing is bit-invisible");
    }

    #[test]
    fn degraded_class_fails_without_poisoning_the_run() {
        let classes = vec![single_stage("solver", 8), None];
        let service = vec![
            Some([classes[0].as_ref().unwrap().stages[0].est_s, 0.0, 0.0, 0.0]),
            None,
        ];
        let cl = ClusterConfig::default();
        let tr: Vec<Arrival> = (0..8)
            .map(|i| Arrival { id: i as u64, class: (i % 2) as usize, t_s: 0.0 })
            .collect();
        let co = run(
            &CosimConfig { cluster: cl.clone(), deadline_s: None },
            &classes,
            Workload::Open(&tr),
            || 0,
        );
        let replay = cluster::run(&cl, &service, Workload::Open(&tr), || 0);
        assert_eq!(co.failed, 4);
        assert_eq!(co.completions, replay.completions);
    }
}

#[cfg(test)]
mod dag_tests {
    use super::*;

    fn cfg(kernel: DagKernel, n: usize, tile: usize, units: usize) -> DagConfig {
        DagConfig { kernel, n, tile, units }
    }

    #[test]
    fn dag_rerun_is_bit_deterministic() {
        let c = cfg(DagKernel::Cholesky, 32, 8, 4);
        let a = run_dag(&c).unwrap();
        let b = run_dag(&c).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dag_digest_is_invariant_across_units_and_matches_replay() {
        for (kernel, a) in [
            (DagKernel::Cholesky, workloads::cholesky::instance(32, 0).a),
            (DagKernel::Lu, workloads::lu::instance(32, 0).a),
        ] {
            let dag = TileDag::build(kernel, 32, 8).unwrap();
            let want = exec::digest(&exec::replay(&dag, &a));
            for units in [1usize, 4, 8] {
                let r = run_dag(&cfg(kernel, 32, 8, units)).unwrap();
                assert_eq!(
                    r.factor_digest, want,
                    "{kernel:?} units={units}: factor bits moved"
                );
            }
        }
    }

    #[test]
    fn dag_multi_unit_beats_single_unit() {
        let one = run_dag(&cfg(DagKernel::Cholesky, 32, 8, 1)).unwrap();
        let eight = run_dag(&cfg(DagKernel::Cholesky, 32, 8, 8)).unwrap();
        assert!(
            eight.makespan_cycles < one.makespan_cycles,
            "8 units {} !< 1 unit {}",
            eight.makespan_cycles,
            one.makespan_cycles
        );
        // Both bound below by the dependence structure.
        assert!(eight.makespan_cycles >= eight.critical_path_cycles);
    }

    #[test]
    fn dag_residency_and_occupancy_accounting() {
        // One unit, 10 distinct tiles, 7 slots at b = 16: residency
        // must both hit (operand reuse) and churn (LRU evictions).
        let r = run_dag(&cfg(DagKernel::Cholesky, 64, 16, 1)).unwrap();
        assert!(r.resident_hits > 0, "no resident reuse");
        assert!(r.evictions > 0, "no slot churn");
        assert_eq!(r.per_unit.iter().map(|u| u.tasks).sum::<usize>(), r.tasks);
        assert_eq!(
            r.per_unit.iter().map(|u| u.busy_cycles).sum::<u64>(),
            r.total_compute_cycles
        );
        assert_eq!(r.handoff_words, r.handoffs * 16 * 16);
        assert!(r.bus_busy_cycles > 0);
        assert!(r.makespan_cycles >= r.critical_path_cycles);
    }

    #[test]
    fn dag_rejects_degenerate_configs() {
        assert!(run_dag(&cfg(DagKernel::Cholesky, 32, 8, 0)).is_err());
        assert!(run_dag(&cfg(DagKernel::Cholesky, 30, 8, 1)).is_err());
        let err = run_dag(&cfg(DagKernel::Cholesky, 64, 32, 1)).unwrap_err();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn dag_json_summary_round_trips() {
        let r = run_dag(&cfg(DagKernel::Lu, 16, 8, 2)).unwrap();
        let j = r.to_json().render();
        let back = crate::harness::json::parse(&j).unwrap();
        assert_eq!(back.get("tasks").and_then(Json::as_u64), Some(r.tasks as u64));
        assert_eq!(
            back.get("factor_digest").and_then(Json::as_str),
            Some(format!("{:016x}", r.factor_digest).as_str())
        );
        assert_eq!(
            back.get("per_unit").and_then(Json::as_arr).map(|a| a.len()),
            Some(2)
        );
    }
}
