//! `revel serve`: synthesize a deterministic 5G subframe arrival trace,
//! push it through the [`super::cluster`] dispatcher, and account
//! latency/SLO results into a `BENCH_serve.json` artifact (same
//! hand-rolled JSON dialect as `BENCH_sweep.json`).
//!
//! Host-side batching: each distinct stage kernel `(kernel, n,
//! features, goal)` across all job classes is simulated exactly once,
//! in one parallel [`crate::harness`] pass through the process-wide
//! memo cache — thousands of subframes amortize a handful of cycle-
//! accurate simulations. The replay engine ([`EngineKind::Replay`])
//! then replays those service times in virtual time; the co-simulation
//! engine ([`EngineKind::Cosim`]) uses them only as dispatch/admission
//! estimates and times every stage on a live machine instead. Either
//! way, for a fixed [`ServeConfig`] the whole report is
//! bit-deterministic; only the `host` block of the artifact (wall
//! clock, worker count) varies between runs.

use std::sync::Arc;

use crate::harness::{self, json, json::Json, SweepOutcome, SweepPoint};
use crate::model;
use crate::runtime::{Result, RtError};
use crate::util::Rng;
use crate::workloads::{Features, Goal};

use super::cluster::{self, Arrival, ClusterConfig, Completion, Workload};
use super::cosim::{self, CosimClass, CosimConfig, StageTask};
use super::slo::{Pctls, SloAccountant, SloDigest};
use super::{JobClass, CLASSES, STAGE_NAMES};

/// Per-job records are embedded in the artifact only up to this many
/// jobs (they exist to make determinism diffable, not to bloat disk).
pub const DETAIL_CAP: usize = 1024;

/// How the synthetic trace offers subframes to the cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalMode {
    /// Open loop: Poisson arrivals at `lambda` subframes per virtual
    /// second; `lambda <= 0` floods every job at t = 0 (peak load).
    Open { lambda: f64 },
    /// Closed loop: `clients` concurrent submitters with zero think
    /// time — each submits its next subframe when the previous one
    /// finishes.
    Closed { clients: usize },
}

/// Which cluster engine serves the trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Replay memoized per-stage service times; a job occupies one
    /// unit for its whole stage chain ([`super::cluster`]). The
    /// optimistic oracle: inter-stage handoffs are assumed free.
    Replay,
    /// Calendar-driven co-simulation: live per-unit machines,
    /// stage-pipelined subframes, a shared inter-stage interconnect,
    /// and optional SLO-aware admission ([`super::cosim`]).
    Cosim,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Replay => "replay",
            EngineKind::Cosim => "cosim",
        }
    }
}

/// Full configuration of one serve run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Total subframes in the trace.
    pub jobs: usize,
    /// Seed for the arrival trace and class mix ([`Rng`] — xoshiro).
    pub seed: u64,
    pub mode: ArrivalMode,
    pub cluster: ClusterConfig,
    /// Replay (memoized service times) or co-simulation (live
    /// machines on the shared calendar).
    pub engine: EngineKind,
    /// SLO deadline for the co-simulation engine's predictive
    /// admission, in virtual microseconds; `None` (and the replay
    /// engine) admit by queue depth only.
    pub slo_deadline_us: Option<f64>,
    /// Host worker threads for the batched stage pre-simulation
    /// (`None` = harness default / `REVEL_WORKERS`).
    pub workers: Option<usize>,
    /// Subframe classes in the traffic mix (defaults to [`CLASSES`]).
    pub classes: Vec<JobClass>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            jobs: 200,
            seed: 7,
            mode: ArrivalMode::Open { lambda: 0.0 },
            cluster: ClusterConfig::default(),
            engine: EngineKind::Replay,
            slo_deadline_us: None,
            workers: None,
            classes: CLASSES.to_vec(),
        }
    }
}

/// Per-unit slice of the report.
///
/// Granularity depends on the engine: replay places whole jobs on
/// units, so `jobs`/`stolen` count jobs; the co-sim engine
/// stage-pipelines, so they count stage executions (4x for the
/// four-stage classes). `busy_s`/`utilization` are compute occupancy
/// under both engines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnitReport {
    pub jobs: usize,
    pub busy_s: f64,
    /// busy_s / makespan — fraction of the run this unit served.
    pub utilization: f64,
    pub stolen: usize,
}

/// Per-class slice of the report.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassReport {
    pub name: String,
    pub weight: f64,
    pub completed: usize,
    /// Simulated cycles per stage; `None` when a stage failed and the
    /// class was degraded.
    pub stage_cycles: Option<[u64; 4]>,
}

/// Host-side batching accounting: how many cycle-accurate simulations
/// actually ran vs. how many stage executions the trace represents.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Batching {
    pub distinct_points: usize,
    pub stage_runs: usize,
}

/// Host-only payload carried inside an otherwise deterministic report.
/// Compares equal to everything, so two same-config runs still satisfy
/// `ServeReport == ServeReport` (the determinism contract CI diffs);
/// serialization routes it into the artifact's nondeterministic `host`
/// block, which readers drop.
#[derive(Clone, Debug, Default)]
pub struct HostOnly<T>(pub T);

impl<T> PartialEq for HostOnly<T> {
    fn eq(&self, _other: &Self) -> bool {
        true
    }
}

/// Host wall time of one distinct pre-simulated stage point. Memoized
/// stages report the wall time of their first (only) execution.
#[derive(Clone, Debug)]
pub struct StageWall {
    pub kernel: String,
    pub n: usize,
    pub wall_ns_mean: f64,
    pub wall_ns_min: f64,
}

/// Everything one serve run reports. All fields are deterministic in
/// the [`ServeConfig`]; host wall-clock data is added only at
/// serialization time ([`ServeReport::to_json`]) so two runs with the
/// same config compare equal.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    pub units: usize,
    pub jobs: usize,
    pub seed: u64,
    pub mode: ArrivalMode,
    pub engine: EngineKind,
    /// Echo of [`ServeConfig::slo_deadline_us`].
    pub slo_deadline_us: Option<f64>,
    pub queue_cap: usize,
    pub admit_cap: usize,
    pub completed: usize,
    pub dropped: usize,
    pub failed: usize,
    /// Arrivals shed by the co-sim engine's SLO deadline lookahead
    /// (always 0 for replay).
    pub deadline_shed: usize,
    /// Inter-stage handoffs granted on the shared interconnect
    /// (co-sim only; replay models handoffs as free).
    pub handoffs: usize,
    /// Virtual seconds handoffs waited for the shared interconnect —
    /// the cross-unit contention the replay engine cannot see.
    pub bus_wait_s: f64,
    pub peak_admit_queue: usize,
    /// Virtual seconds from first arrival to last pipeline exit.
    pub makespan_s: f64,
    /// Subframes per virtual second at the REVEL clock.
    pub throughput_per_s: f64,
    pub slo: SloDigest,
    pub per_unit: Vec<UnitReport>,
    pub classes: Vec<ClassReport>,
    pub batching: Batching,
    /// Human-readable reasons for degraded classes (empty when
    /// everything simulated cleanly).
    pub stage_errors: Vec<String>,
    /// Per-job timing (present when `jobs <= DETAIL_CAP`).
    pub jobs_detail: Vec<Completion>,
    /// Host wall time per distinct pre-simulated stage point. Excluded
    /// from equality and from the deterministic part of the artifact
    /// (it serializes into the `host` block).
    pub stage_wall: HostOnly<Vec<StageWall>>,
}

struct StageTable {
    per_class: Vec<Option<[u64; 4]>>,
    distinct_points: usize,
    errors: Vec<String>,
    stage_wall: Vec<StageWall>,
}

/// One batched harness pass over the distinct stage kernels of all
/// classes. A failing stage degrades only the classes that use it (the
/// error is recorded); it does not abort the serve run.
fn stage_table(classes: &[JobClass], workers: Option<usize>) -> StageTable {
    let mut points: Vec<SweepPoint> = Vec::new();
    for c in classes {
        for s in &c.stages {
            let p = SweepPoint::new(s.kernel, s.n, Features::ALL, Goal::Latency);
            if !points.contains(&p) {
                points.push(p);
            }
        }
    }
    let opts = harness::Options { workers, use_cache: true };
    let mut errors = Vec::new();
    let outcomes: Vec<Option<Arc<SweepOutcome>>> =
        match harness::run_all_opts(&points, &opts) {
            Ok(os) => os.into_iter().map(Some).collect(),
            // Some point failed: fall back to per-point execution (the
            // memo cache keeps the successful ones free) so only the
            // broken stages degrade.
            Err(_) => points
                .iter()
                .map(|p| {
                    match harness::run_all_opts(std::slice::from_ref(p), &opts) {
                        Ok(mut os) => Some(os.remove(0)),
                        Err(e) => {
                            errors.push(format!("{} n={}: {e}", p.kernel, p.n));
                            None
                        }
                    }
                })
                .collect(),
        };
    let cycles_of = |kernel: &str, n: usize| -> Option<u64> {
        points
            .iter()
            .zip(&outcomes)
            .find(|(p, _)| p.kernel == kernel && p.n == n)
            .and_then(|(_, o)| o.as_ref())
            .map(|o| o.cycles)
    };
    let per_class = classes
        .iter()
        .map(|c| {
            let mut cy = [0u64; 4];
            for (slot, s) in cy.iter_mut().zip(c.stages.iter()) {
                match cycles_of(s.kernel, s.n) {
                    Some(x) => *slot = x,
                    None => return None,
                }
            }
            Some(cy)
        })
        .collect();
    let stage_wall = points
        .iter()
        .zip(&outcomes)
        .filter_map(|(p, o)| {
            o.as_ref().map(|o| StageWall {
                kernel: p.kernel.clone(),
                n: p.n,
                wall_ns_mean: o.wall_ns_mean,
                wall_ns_min: o.wall_ns_min,
            })
        })
        .collect();
    StageTable { per_class, distinct_points: points.len(), errors, stage_wall }
}

/// Sample a class index from cumulative weights.
fn pick_weighted(rng: &mut Rng, cum: &[f64]) -> usize {
    let total = cum.last().copied().unwrap_or(1.0);
    let r = rng.f64() * total;
    cum.iter().position(|&c| r < c).unwrap_or(cum.len().saturating_sub(1))
}

/// Serve a synthetic subframe trace on a simulated REVEL cluster.
///
/// Stage failures degrade the affected class (recorded in
/// `stage_errors` / `failed`) instead of panicking a worker; a
/// [`RtError`] is returned only for unusable configurations.
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    if cfg.classes.is_empty() {
        return Err(RtError("serve: no job classes configured".into()));
    }
    harness::ensure_budget();
    let st = stage_table(&cfg.classes, cfg.workers);
    let class_service: Vec<Option<[f64; 4]>> = st
        .per_class
        .iter()
        .map(|o| o.map(|cy| cy.map(|c| model::cycles_to_us(c) * 1e-6)))
        .collect();
    let cum: Vec<f64> = cfg
        .classes
        .iter()
        .scan(0.0, |acc, c| {
            *acc += c.weight.max(0.0);
            Some(*acc)
        })
        .collect();
    // Normalize exactly as cluster::run will, so the artifact's config
    // block echoes the policy that actually ran.
    let cluster_cfg = ClusterConfig {
        units: cfg.cluster.units.max(1),
        queue_cap: cfg.cluster.queue_cap.max(1),
        admit_cap: cfg.cluster.admit_cap,
    };
    let mut rng = Rng::new(cfg.seed);
    // The open-loop trace is synthesized up front — identically for
    // both engines, so `--engine replay` vs `--engine cosim` compare
    // the very same traffic.
    let open_trace: Option<Vec<Arrival>> = match cfg.mode {
        ArrivalMode::Open { lambda } => {
            let mut t = 0.0;
            Some(
                (0..cfg.jobs)
                    .map(|id| {
                        if lambda > 0.0 {
                            t += rng.exp(lambda);
                        }
                        let class = pick_weighted(&mut rng, &cum);
                        Arrival { id: id as u64, class, t_s: t }
                    })
                    .collect(),
            )
        }
        ArrivalMode::Closed { .. } => None,
    };
    // Engine-neutral view of a run's outcome.
    struct EngineOut {
        completions: Vec<Completion>,
        dropped: usize,
        failed: usize,
        deadline_shed: usize,
        handoffs: usize,
        bus_wait_s: f64,
        units: Vec<cluster::UnitStats>,
        makespan_s: f64,
        peak_admit_queue: usize,
        extra_errors: Vec<String>,
    }
    let run = match cfg.engine {
        EngineKind::Replay => {
            let r = match cfg.mode {
                ArrivalMode::Open { .. } => cluster::run(
                    &cluster_cfg,
                    &class_service,
                    Workload::Open(open_trace.as_deref().unwrap_or(&[])),
                    || 0,
                ),
                ArrivalMode::Closed { clients } => cluster::run(
                    &cluster_cfg,
                    &class_service,
                    Workload::Closed { clients, jobs: cfg.jobs },
                    || pick_weighted(&mut rng, &cum),
                ),
            };
            EngineOut {
                completions: r.completions,
                dropped: r.dropped,
                failed: r.failed,
                deadline_shed: 0,
                handoffs: 0,
                bus_wait_s: 0.0,
                units: r.units,
                makespan_s: r.makespan_s,
                peak_admit_queue: r.peak_admit_queue,
                extra_errors: Vec::new(),
            }
        }
        EngineKind::Cosim => {
            // Per-class stage chains with profiled estimates (the same
            // memoized cycles replay consumes); a degraded class maps
            // to `None`, exactly like the replay service table.
            let cosim_classes: Vec<Option<CosimClass>> = cfg
                .classes
                .iter()
                .zip(&st.per_class)
                .map(|(c, cy)| {
                    cy.map(|cy| CosimClass {
                        stages: c
                            .stages
                            .iter()
                            .zip(cy.iter())
                            .map(|(s, &cycles)| StageTask {
                                kernel: s.kernel.to_string(),
                                n: s.n,
                                est_s: model::cycles_to_us(cycles) * 1e-6,
                            })
                            .collect(),
                    })
                })
                .collect();
            let ccfg = CosimConfig {
                cluster: cluster_cfg.clone(),
                deadline_s: cfg.slo_deadline_us.map(|us| us * 1e-6),
            };
            let r = match cfg.mode {
                ArrivalMode::Open { .. } => cosim::run(
                    &ccfg,
                    &cosim_classes,
                    Workload::Open(open_trace.as_deref().unwrap_or(&[])),
                    || 0,
                ),
                ArrivalMode::Closed { clients } => cosim::run(
                    &ccfg,
                    &cosim_classes,
                    Workload::Closed { clients, jobs: cfg.jobs },
                    || pick_weighted(&mut rng, &cum),
                ),
            };
            EngineOut {
                completions: r.completions,
                dropped: r.dropped,
                failed: r.failed,
                deadline_shed: r.deadline_shed,
                handoffs: r.handoffs,
                bus_wait_s: r.bus_wait_s,
                units: r.units,
                makespan_s: r.makespan_s,
                peak_admit_queue: r.peak_admit_queue,
                extra_errors: r.stage_errors,
            }
        }
    };
    let mut acc = SloAccountant::new();
    let mut per_class_done = vec![0usize; cfg.classes.len()];
    for c in &run.completions {
        per_class_done[c.class] += 1;
        let s = class_service[c.class].unwrap_or([0.0; 4]);
        let service: f64 = s.iter().sum();
        acc.record(
            (c.finish_s - c.arrival_s) * 1e6,
            (c.start_s - c.arrival_s) * 1e6,
            service * 1e6,
            [s[0] * 1e6, s[1] * 1e6, s[2] * 1e6, s[3] * 1e6],
        );
    }
    let completed = run.completions.len();
    let throughput =
        if run.makespan_s > 0.0 { completed as f64 / run.makespan_s } else { 0.0 };
    let per_unit = run
        .units
        .iter()
        .map(|u| UnitReport {
            jobs: u.jobs,
            busy_s: u.busy_s,
            utilization: if run.makespan_s > 0.0 { u.busy_s / run.makespan_s } else { 0.0 },
            stolen: u.stolen,
        })
        .collect();
    let classes = cfg
        .classes
        .iter()
        .enumerate()
        .map(|(i, c)| ClassReport {
            name: c.name.to_string(),
            weight: c.weight,
            completed: per_class_done[i],
            stage_cycles: st.per_class[i],
        })
        .collect();
    let mut stage_errors = st.errors;
    stage_errors.extend(run.extra_errors);
    Ok(ServeReport {
        units: cluster_cfg.units,
        jobs: cfg.jobs,
        seed: cfg.seed,
        mode: cfg.mode,
        engine: cfg.engine,
        slo_deadline_us: cfg.slo_deadline_us,
        queue_cap: cluster_cfg.queue_cap,
        admit_cap: cluster_cfg.admit_cap,
        completed,
        dropped: run.dropped,
        failed: run.failed,
        deadline_shed: run.deadline_shed,
        handoffs: run.handoffs,
        bus_wait_s: run.bus_wait_s,
        peak_admit_queue: run.peak_admit_queue,
        makespan_s: run.makespan_s,
        throughput_per_s: throughput,
        slo: acc.digest(),
        per_unit,
        classes,
        batching: Batching { distinct_points: st.distinct_points, stage_runs: 4 * completed },
        stage_errors,
        jobs_detail: if cfg.jobs <= DETAIL_CAP { run.completions.clone() } else { Vec::new() },
        stage_wall: HostOnly(st.stage_wall),
    })
}

fn completion_to_json(c: &Completion) -> Json {
    Json::obj(vec![
        ("id", Json::Num(c.id as f64)),
        ("class", Json::Num(c.class as f64)),
        ("unit", Json::Num(c.unit as f64)),
        ("arrival_s", Json::Num(c.arrival_s)),
        ("start_s", Json::Num(c.start_s)),
        ("finish_s", Json::Num(c.finish_s)),
        ("stolen", Json::Bool(c.stolen)),
    ])
}

fn completion_from_json(v: &Json) -> std::result::Result<Completion, String> {
    let err = |f: &str| format!("jobs_detail entry missing/invalid {f:?}");
    let num = |k: &str| v.get(k).and_then(Json::as_f64).ok_or_else(|| err(k));
    Ok(Completion {
        id: v.get("id").and_then(Json::as_u64).ok_or_else(|| err("id"))?,
        class: v.get("class").and_then(Json::as_usize).ok_or_else(|| err("class"))?,
        unit: v.get("unit").and_then(Json::as_usize).ok_or_else(|| err("unit"))?,
        arrival_s: num("arrival_s")?,
        start_s: num("start_s")?,
        finish_s: num("finish_s")?,
        stolen: v.get("stolen").and_then(Json::as_bool).ok_or_else(|| err("stolen"))?,
    })
}

impl ServeReport {
    /// Build the `BENCH_serve.json` document. Everything except the
    /// `host` block is deterministic in the serve config.
    pub fn to_json(&self, host_wall_s: f64, host_workers: usize) -> Json {
        let (mode, lambda, clients) = match self.mode {
            ArrivalMode::Open { lambda } => ("open", lambda, 0usize),
            ArrivalMode::Closed { clients } => ("closed", 0.0, clients),
        };
        Json::obj(vec![
            ("schema", Json::Str("revel-bench-serve".into())),
            ("version", Json::Num(1.0)),
            ("freq_ghz", Json::Num(model::FREQ_GHZ)),
            (
                "config",
                Json::obj(vec![
                    ("units", Json::Num(self.units as f64)),
                    ("jobs", Json::Num(self.jobs as f64)),
                    ("seed", Json::Num(self.seed as f64)),
                    ("mode", Json::Str(mode.into())),
                    ("engine", Json::Str(self.engine.name().into())),
                    (
                        "slo_deadline_us",
                        match self.slo_deadline_us {
                            None => Json::Null,
                            Some(us) => Json::Num(us),
                        },
                    ),
                    ("lambda", Json::Num(lambda)),
                    ("clients", Json::Num(clients as f64)),
                    ("queue_cap", Json::Num(self.queue_cap as f64)),
                    ("admit_cap", Json::Num(self.admit_cap as f64)),
                ]),
            ),
            (
                "host",
                Json::obj(vec![
                    ("wall_s", Json::Num(host_wall_s)),
                    ("workers", Json::Num(host_workers as f64)),
                    (
                        // Per-point host wall time of the batched stage
                        // pre-simulation (nondeterministic, so it lives
                        // in the host block readers drop).
                        "stage_wall_ns",
                        Json::Arr(
                            self.stage_wall
                                .0
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("kernel", Json::Str(s.kernel.clone())),
                                        ("n", Json::Num(s.n as f64)),
                                        ("mean", Json::Num(s.wall_ns_mean)),
                                        ("min", Json::Num(s.wall_ns_min)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "summary",
                Json::obj(vec![
                    ("completed", Json::Num(self.completed as f64)),
                    ("dropped", Json::Num(self.dropped as f64)),
                    ("failed", Json::Num(self.failed as f64)),
                    ("deadline_shed", Json::Num(self.deadline_shed as f64)),
                    ("handoffs", Json::Num(self.handoffs as f64)),
                    ("bus_wait_s", Json::Num(self.bus_wait_s)),
                    ("peak_admit_queue", Json::Num(self.peak_admit_queue as f64)),
                    ("makespan_s", Json::Num(self.makespan_s)),
                    ("throughput_per_s", Json::Num(self.throughput_per_s)),
                    ("latency_us", self.slo.latency_us.to_json()),
                    ("queue_us", self.slo.queue_us.to_json()),
                    ("service_us", self.slo.service_us.to_json()),
                ]),
            ),
            (
                // Keyed by pipeline *position* (STAGE_NAMES slot labels):
                // the "cholesky" slot aggregates every channel estimator
                // in the mix, including the LU classes.
                "stage_us",
                Json::Obj(
                    STAGE_NAMES
                        .iter()
                        .zip(self.slo.stage_us.iter())
                        .map(|(n, p)| (n.to_string(), p.to_json()))
                        .collect(),
                ),
            ),
            (
                "per_unit",
                Json::Arr(
                    self.per_unit
                        .iter()
                        .map(|u| {
                            Json::obj(vec![
                                ("jobs", Json::Num(u.jobs as f64)),
                                ("busy_s", Json::Num(u.busy_s)),
                                ("utilization", Json::Num(u.utilization)),
                                ("stolen", Json::Num(u.stolen as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::Str(c.name.clone())),
                                ("weight", Json::Num(c.weight)),
                                ("completed", Json::Num(c.completed as f64)),
                                (
                                    "stage_cycles",
                                    match c.stage_cycles {
                                        None => Json::Null,
                                        Some(cy) => Json::Arr(
                                            cy.iter().map(|&x| Json::Num(x as f64)).collect(),
                                        ),
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "batching",
                Json::obj(vec![
                    ("distinct_points", Json::Num(self.batching.distinct_points as f64)),
                    ("stage_runs", Json::Num(self.batching.stage_runs as f64)),
                ]),
            ),
            (
                "stage_errors",
                Json::Arr(self.stage_errors.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "jobs_detail",
                Json::Arr(self.jobs_detail.iter().map(completion_to_json).collect()),
            ),
        ])
    }

    /// Inverse of [`to_json`] (the `host` block is intentionally
    /// dropped — it is the only nondeterministic part of the artifact).
    pub fn from_json(v: &Json) -> std::result::Result<ServeReport, String> {
        let err = |f: &str| format!("BENCH_serve document missing/invalid {f:?}");
        let cfg = v.get("config").ok_or_else(|| err("config"))?;
        let summary = v.get("summary").ok_or_else(|| err("summary"))?;
        let cnum = |k: &str| cfg.get(k).and_then(Json::as_usize).ok_or_else(|| err(k));
        let snum = |k: &str| summary.get(k).and_then(Json::as_usize).ok_or_else(|| err(k));
        let mode = match cfg.get("mode").and_then(Json::as_str) {
            Some("open") => ArrivalMode::Open {
                lambda: cfg.get("lambda").and_then(Json::as_f64).ok_or_else(|| err("lambda"))?,
            },
            Some("closed") => ArrivalMode::Closed { clients: cnum("clients")? },
            _ => return Err(err("mode")),
        };
        // Engine and SLO fields arrived with the co-sim engine; absent
        // (pre-cosim) artifacts parse as replay with no deadline.
        let engine = match cfg.get("engine").and_then(Json::as_str) {
            None | Some("replay") => EngineKind::Replay,
            Some("cosim") => EngineKind::Cosim,
            _ => return Err(err("engine")),
        };
        let slo_deadline_us = match cfg.get("slo_deadline_us") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| err("slo_deadline_us"))?),
        };
        let digest = |k: &str| -> std::result::Result<Pctls, String> {
            Pctls::from_json(summary.get(k).ok_or_else(|| err(k))?)
        };
        let stage_obj = v.get("stage_us").ok_or_else(|| err("stage_us"))?;
        let mut stage_us = [Pctls::default(); 4];
        for (slot, name) in stage_us.iter_mut().zip(STAGE_NAMES) {
            *slot = Pctls::from_json(stage_obj.get(name).ok_or_else(|| err(name))?)?;
        }
        let per_unit = v
            .get("per_unit")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("per_unit"))?
            .iter()
            .map(|u| {
                Ok(UnitReport {
                    jobs: u.get("jobs").and_then(Json::as_usize).ok_or_else(|| err("jobs"))?,
                    busy_s: u
                        .get("busy_s")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| err("busy_s"))?,
                    utilization: u
                        .get("utilization")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| err("utilization"))?,
                    stolen: u
                        .get("stolen")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| err("stolen"))?,
                })
            })
            .collect::<std::result::Result<Vec<_>, String>>()?;
        let classes = v
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("classes"))?
            .iter()
            .map(|c| {
                let stage_cycles = match c.get("stage_cycles") {
                    None | Some(Json::Null) => None,
                    Some(Json::Arr(a)) if a.len() == 4 => {
                        let mut cy = [0u64; 4];
                        for (slot, e) in cy.iter_mut().zip(a) {
                            *slot = e.as_u64().ok_or_else(|| err("stage_cycles"))?;
                        }
                        Some(cy)
                    }
                    _ => return Err(err("stage_cycles")),
                };
                Ok(ClassReport {
                    name: c
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| err("name"))?
                        .to_string(),
                    weight: c
                        .get("weight")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| err("weight"))?,
                    completed: c
                        .get("completed")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| err("completed"))?,
                    stage_cycles,
                })
            })
            .collect::<std::result::Result<Vec<_>, String>>()?;
        let batching = v.get("batching").ok_or_else(|| err("batching"))?;
        let stage_errors = v
            .get("stage_errors")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("stage_errors"))?
            .iter()
            .map(|e| e.as_str().map(str::to_string).ok_or_else(|| err("stage_errors")))
            .collect::<std::result::Result<Vec<_>, String>>()?;
        let jobs_detail = v
            .get("jobs_detail")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("jobs_detail"))?
            .iter()
            .map(completion_from_json)
            .collect::<std::result::Result<Vec<_>, String>>()?;
        Ok(ServeReport {
            units: cnum("units")?,
            jobs: cnum("jobs")?,
            seed: cfg.get("seed").and_then(Json::as_u64).ok_or_else(|| err("seed"))?,
            mode,
            engine,
            slo_deadline_us,
            queue_cap: cnum("queue_cap")?,
            admit_cap: cnum("admit_cap")?,
            completed: snum("completed")?,
            dropped: snum("dropped")?,
            failed: snum("failed")?,
            // Pre-cosim artifacts carry none of these; default to the
            // replay engine's values.
            deadline_shed: summary
                .get("deadline_shed")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            handoffs: summary.get("handoffs").and_then(Json::as_usize).unwrap_or(0),
            bus_wait_s: summary
                .get("bus_wait_s")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            peak_admit_queue: snum("peak_admit_queue")?,
            makespan_s: summary
                .get("makespan_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| err("makespan_s"))?,
            throughput_per_s: summary
                .get("throughput_per_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| err("throughput_per_s"))?,
            slo: SloDigest {
                latency_us: digest("latency_us")?,
                queue_us: digest("queue_us")?,
                service_us: digest("service_us")?,
                stage_us,
            },
            per_unit,
            classes,
            batching: Batching {
                distinct_points: batching
                    .get("distinct_points")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("distinct_points"))?,
                stage_runs: batching
                    .get("stage_runs")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("stage_runs"))?,
            },
            stage_errors,
            jobs_detail,
            // Host-block content is intentionally not round-tripped.
            stage_wall: HostOnly::default(),
        })
    }
}

/// Write the `BENCH_serve.json` artifact to `path`.
pub fn write_artifact(
    path: &str,
    report: &ServeReport,
    host_wall_s: f64,
    host_workers: usize,
) -> std::io::Result<()> {
    std::fs::write(path, report.to_json(host_wall_s, host_workers).pretty())
}

/// Parse a serve artifact back (schema round-trip).
pub fn read_artifact(text: &str) -> std::result::Result<ServeReport, String> {
    let doc = json::parse(text)?;
    if doc.get("schema").and_then(Json::as_str) != Some("revel-bench-serve") {
        return Err("not a revel-bench-serve document".into());
    }
    ServeReport::from_json(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StageSpec;

    /// Cheap stage mixes (small solver/gemm/fir points shared with the
    /// harness tests) so serving tests stay fast.
    fn cheap_classes() -> Vec<JobClass> {
        vec![
            JobClass {
                name: "lite",
                stages: [
                    StageSpec { kernel: "solver", n: 8 },
                    StageSpec { kernel: "solver", n: 12 },
                    StageSpec { kernel: "gemm", n: 12 },
                    StageSpec { kernel: "fir", n: 12 },
                ],
                weight: 0.7,
            },
            JobClass {
                name: "heavy",
                stages: [
                    StageSpec { kernel: "solver", n: 16 },
                    StageSpec { kernel: "solver", n: 12 },
                    StageSpec { kernel: "gemm", n: 12 },
                    StageSpec { kernel: "fir", n: 12 },
                ],
                weight: 0.3,
            },
        ]
    }

    fn cfg(units: usize) -> ServeConfig {
        ServeConfig {
            jobs: 24,
            seed: 7,
            mode: ArrivalMode::Open { lambda: 0.0 },
            cluster: ClusterConfig { units, ..ClusterConfig::default() },
            workers: Some(2),
            classes: cheap_classes(),
            ..ServeConfig::default()
        }
    }

    /// A small co-sim run (live machines make each job's stages real
    /// simulations, so the test traces stay short).
    fn cosim_cfg(units: usize, jobs: usize) -> ServeConfig {
        ServeConfig {
            jobs,
            engine: EngineKind::Cosim,
            cluster: ClusterConfig { units, ..ClusterConfig::default() },
            ..cfg(units)
        }
    }

    #[test]
    fn deterministic_and_scales_with_units() {
        let a = serve(&cfg(1)).unwrap();
        let b = serve(&cfg(1)).unwrap();
        assert_eq!(a, b, "same config, same seed => identical report");
        assert_eq!(a.completed, 24);
        assert!(a.slo.latency_us.p99 > 0.0);
        let c = serve(&cfg(4)).unwrap();
        assert_eq!(c.completed, 24, "same trace, more units");
        assert!(
            c.throughput_per_s > a.throughput_per_s,
            "4 units beat 1 on the same flood trace ({} vs {})",
            c.throughput_per_s,
            a.throughput_per_s
        );
        assert!(c.makespan_s < a.makespan_s);
    }

    #[test]
    fn artifact_roundtrip_through_json() {
        let r = serve(&cfg(2)).unwrap();
        let text = r.to_json(1.5, 8).pretty();
        let back = read_artifact(&text).unwrap();
        assert_eq!(back, r, "host block drops; everything else round-trips");
        assert!(read_artifact("{\"schema\": \"other\"}").is_err());
        // Stage wall times ride in the (dropped) host block only.
        let doc = json::parse(&text).unwrap();
        let walls = doc
            .get("host")
            .and_then(|h| h.get("stage_wall_ns"))
            .and_then(Json::as_arr)
            .expect("host.stage_wall_ns present");
        assert_eq!(walls.len(), r.stage_wall.0.len());
        assert!(back.stage_wall.0.is_empty(), "host block not round-tripped");
    }

    #[test]
    fn closed_loop_and_paced_open_complete_everything() {
        let mut closed = cfg(2);
        closed.mode = ArrivalMode::Closed { clients: 3 };
        let r = serve(&closed).unwrap();
        assert_eq!(r.completed, 24);
        assert_eq!(r.dropped, 0, "closed loop self-limits");

        let mut paced = cfg(2);
        // Pace arrivals near half the flood capacity: queues stay short.
        let flood = serve(&cfg(2)).unwrap();
        paced.mode = ArrivalMode::Open { lambda: flood.throughput_per_s * 0.5 };
        let p = serve(&paced).unwrap();
        assert_eq!(p.completed, 24);
        assert!(p.slo.queue_us.p99 <= flood.slo.queue_us.p99);
    }

    #[test]
    fn cosim_engine_is_deterministic_and_never_beats_replay_makespan() {
        let a = serve(&cosim_cfg(1, 12)).unwrap();
        let b = serve(&cosim_cfg(1, 12)).unwrap();
        assert_eq!(a, b, "cosim: same config, same seed => identical report");
        assert_eq!(a.engine, EngineKind::Cosim);
        assert_eq!(a.completed, 12);
        assert!(a.handoffs > 0, "4-stage jobs hand off between stages");
        assert!(a.stage_errors.is_empty(), "{:?}", a.stage_errors);
        // Replay is the optimistic oracle: on one unit its flood
        // makespan equals the total compute — a lower bound for any
        // schedule that additionally pays inter-stage handoffs.
        let mut rcfg = cfg(1);
        rcfg.jobs = 12;
        let replay = serve(&rcfg).unwrap();
        assert_eq!(replay.completed, 12);
        assert!(
            a.makespan_s >= replay.makespan_s,
            "cosim {} < replay {}",
            a.makespan_s,
            replay.makespan_s
        );
        assert_eq!(replay.handoffs, 0);
        assert_eq!(replay.bus_wait_s, 0.0);
    }

    #[test]
    fn slo_admission_sheds_through_the_serve_path() {
        let mut c = cosim_cfg(1, 10);
        // Far below one subframe's service demand: every arrival is
        // predicted late and shed at admission.
        c.slo_deadline_us = Some(1.0);
        let r = serve(&c).unwrap();
        assert!(r.deadline_shed > 0, "flood must trip the deadline lookahead");
        assert_eq!(r.completed + r.deadline_shed + r.dropped + r.failed, 10);
        // Replay ignores the knob entirely.
        let mut rc = cfg(1);
        rc.slo_deadline_us = Some(1.0);
        rc.jobs = 10;
        let rr = serve(&rc).unwrap();
        assert_eq!(rr.deadline_shed, 0);
        assert_eq!(rr.completed, 10);
    }

    #[test]
    fn cosim_artifact_roundtrips_and_precosim_artifacts_parse_as_replay() {
        let mut c = cosim_cfg(2, 8);
        c.slo_deadline_us = Some(1e9); // generous: nothing sheds
        let r = serve(&c).unwrap();
        assert_eq!(r.deadline_shed, 0);
        let text = r.to_json(0.5, 4).pretty();
        let back = read_artifact(&text).unwrap();
        assert_eq!(back, r, "host block drops; everything else round-trips");
        assert_eq!(back.engine, EngineKind::Cosim);
        assert_eq!(back.slo_deadline_us, Some(1e9));
        // Emulate a pre-cosim (version-1) artifact by dropping the new
        // keys line-wise (keys sort alphabetically, so none of them is
        // the last entry of its object and the JSON stays valid).
        let replay = serve(&cfg(1)).unwrap();
        let new_keys = [
            "\"engine\"",
            "\"slo_deadline_us\"",
            "\"deadline_shed\"",
            "\"handoffs\"",
            "\"bus_wait_s\"",
        ];
        let old_text: String = replay
            .to_json(0.5, 4)
            .pretty()
            .lines()
            .filter(|l| !new_keys.iter().any(|k| l.trim_start().starts_with(k)))
            .collect::<Vec<_>>()
            .join("\n");
        let old = read_artifact(&old_text).unwrap();
        assert_eq!(old.engine, EngineKind::Replay);
        assert_eq!(old.slo_deadline_us, None);
        assert_eq!(old.deadline_shed, 0);
        assert_eq!(old, replay, "defaults reconstruct the replay report");
    }

    #[test]
    fn batching_amortizes_stage_sims() {
        let r = serve(&cfg(2)).unwrap();
        // 2 classes share gemm/fir/solver-12 points: 5 distinct sims
        // behind 24 * 4 stage executions.
        assert_eq!(r.batching.distinct_points, 5);
        assert_eq!(r.batching.stage_runs, 96);
        assert!(r.stage_errors.is_empty());
        // One wall-time record per distinct stage point, all measured.
        assert_eq!(r.stage_wall.0.len(), 5);
        assert!(r.stage_wall.0.iter().all(|s| s.wall_ns_mean > 0.0));
    }
}
