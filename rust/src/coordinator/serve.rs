//! `revel serve`: synthesize deterministic per-cell arrival traces,
//! push them through the cluster engines, and account latency/SLO
//! results into a `BENCH_serve.json` artifact (same hand-rolled JSON
//! dialect as `BENCH_sweep.json`).
//!
//! A serve run is described by a typed [`ClusterSpec`]: a metro of N
//! [`CellSpec`] cells, each its own cluster (units, queue policy) with
//! its own job mix and [`ArrivalProcess`] (Poisson, bursty MMPP,
//! diurnal, recorded-trace replay, or a closed client loop). Every
//! cell draws from an independent RNG stream ([`cell_seed`]), so the
//! whole metro report is bit-deterministic in `(spec, seed)`.
//!
//! Host-side batching: each distinct stage kernel `(kernel, n,
//! features, goal)` across *all* cells' job classes is simulated
//! exactly once, in one parallel [`crate::harness`] pass through the
//! process-wide memo cache — thousands of subframes across the metro
//! amortize a handful of cycle-accurate simulations. The replay engine
//! ([`EngineKind::Replay`]) then replays those service times in
//! virtual time; the co-simulation engine ([`EngineKind::Cosim`]) uses
//! them only as dispatch/admission estimates, times every stage on a
//! live machine, and — with more than one cell — advances the cells as
//! conservative shards on pool threads ([`super::shard`]). Shard count
//! never changes results: only the `host` block of the artifact (wall
//! clock, worker/shard counts, strong-scaling rows) varies between
//! runs.
//!
//! Cross-cell coupling (co-sim metros only): [`CellSpec::handover_frac`]
//! migrates that fraction of inter-stage handoffs to the ring neighbor
//! over a modeled fronthaul link, and [`ClusterSpec::reroute`] re-offers
//! shed arrivals to the least-backlogged peer before they count as
//! `deadline_shed`/`dropped`. The resolved fronthaul latency
//! (`--fronthaul-us`, default [`DEFAULT_FRONTHAUL_US`], floored at the
//! union mix's [`ShardPlan::lookahead_s`]) becomes the cross-shard
//! lookahead of [`ShardPlan::for_metro`] — the Chandy–Misra–Bryant
//! bound that keeps coupled runs bit-identical for every shard count.
//!
//! Fault injection (co-sim only): an optional [`FaultPlan`]
//! (`--faults <spec>`) arms unit crash/recover schedules, degraded
//! units, fronthaul drop/delay windows, and identity-keyed transient
//! stage faults on every cell. Recovery is bounded re-dispatch with
//! exponential virtual-time backoff; jobs that exhaust their retries
//! land in the `failed` terminal, so `admitted == completed + shed +
//! failed` holds metro-wide with any plan active, and the fault
//! counters ride the schema-v5 artifact.

use std::sync::Arc;

use crate::harness::{self, json, json::Json, pool, SweepOutcome, SweepPoint};
use crate::model;
use crate::runtime::{Result, RtError};
use crate::util::Rng;
use crate::workloads::{Features, Goal};

use super::arrival::ArrivalProcess;
use super::cluster::{self, Arrival, ClusterConfig, Completion, Workload};
use super::cosim::{CosimClass, CosimConfig, CosimSession, Coupling};
use super::faults::FaultPlan;
use super::shard::{self, ShardPlan};
use super::slo::{Pctls, SloAccountant, SloDigest};
use super::{JobClass, CLASSES, STAGE_NAMES};

/// Per-job records are embedded in the artifact only up to this many
/// jobs metro-wide (they exist to make determinism diffable and
/// replayable, not to bloat disk).
pub const DETAIL_CAP: usize = 1024;

/// Default one-way fronthaul latency between neighboring cells, in
/// virtual microseconds (metro dark-fiber scale — orders of magnitude
/// above any intra-cell interconnect handoff). Used when a coupled
/// spec leaves [`ClusterSpec::fronthaul_us`] unset; always floored at
/// the union mix's [`ShardPlan::lookahead_s`] before use.
pub const DEFAULT_FRONTHAUL_US: f64 = 50.0;

/// Salt XORed into [`cell_seed`] for the per-cell handover coin-flip
/// stream, so migration draws never correlate with trace synthesis.
const HANDOVER_SALT: u64 = 0x4841_4E44_4F56_4552; // "HANDOVER"

/// Which cluster engine serves the traces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Replay memoized per-stage service times; a job occupies one
    /// unit for its whole stage chain ([`super::cluster`]). The
    /// optimistic oracle: inter-stage handoffs are assumed free.
    Replay,
    /// Calendar-driven co-simulation: live per-unit machines,
    /// stage-pipelined subframes, a shared inter-stage interconnect,
    /// and optional SLO-aware admission ([`super::cosim`]). Multi-cell
    /// specs advance as conservative shards ([`super::shard`]).
    Cosim,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Replay => "replay",
            EngineKind::Cosim => "cosim",
        }
    }
}

/// Mix `cell` into the metro seed: each cell gets an independent,
/// reproducible RNG stream. Cell 0 uses the raw seed, so a one-cell
/// spec synthesizes exactly the trace the pre-metro serve command did.
pub fn cell_seed(seed: u64, cell: usize) -> u64 {
    seed ^ (cell as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One cell of the metro: a cluster of units with its own admission
/// policy, job mix, and arrival process.
#[derive(Clone, Debug, PartialEq)]
pub struct CellSpec {
    /// Simulated REVEL units in this cell (min 1).
    pub units: usize,
    /// Per-unit run-queue bound (min 1).
    pub queue_cap: usize,
    /// Cell-wide admission-queue bound; beyond it arrivals drop.
    pub admit_cap: usize,
    /// Jobs this cell's trace offers (ignored by `Replay` arrivals,
    /// which carry their own length).
    pub jobs: usize,
    /// How jobs arrive at this cell.
    pub arrival: ArrivalProcess,
    /// Subframe classes in this cell's traffic mix.
    pub job_mix: Vec<JobClass>,
    /// Fraction of this cell's inter-stage boundaries that hand the
    /// subframe over to the ring-neighbor cell (co-sim metros only;
    /// drawn from a dedicated per-cell seed stream). 0 = no handover.
    pub handover_frac: f64,
}

impl Default for CellSpec {
    fn default() -> Self {
        let cl = ClusterConfig::default();
        Self {
            units: cl.units,
            queue_cap: cl.queue_cap,
            admit_cap: cl.admit_cap,
            jobs: 200,
            arrival: ArrivalProcess::default(),
            job_mix: CLASSES.to_vec(),
            handover_frac: 0.0,
        }
    }
}

impl CellSpec {
    pub fn new(units: usize) -> Self {
        Self { units, ..Self::default() }
    }

    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }

    pub fn arrival(mut self, arrival: ArrivalProcess) -> Self {
        self.arrival = arrival;
        self
    }

    pub fn job_mix(mut self, mix: Vec<JobClass>) -> Self {
        self.job_mix = mix;
        self
    }

    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    pub fn admit_cap(mut self, cap: usize) -> Self {
        self.admit_cap = cap;
        self
    }

    pub fn handover_frac(mut self, frac: f64) -> Self {
        self.handover_frac = frac;
        self
    }

    /// The normalized cluster policy this cell actually runs with.
    fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig {
            units: self.units.max(1),
            queue_cap: self.queue_cap.max(1),
            admit_cap: self.admit_cap,
        }
    }
}

/// Full configuration of one serve run: the typed multi-cell spec.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Metro seed; each cell derives its stream via [`cell_seed`].
    pub seed: u64,
    /// Replay (memoized service times) or co-simulation (live
    /// machines on shared calendars).
    pub engine: EngineKind,
    /// SLO deadline for the co-simulation engine's predictive
    /// admission, in virtual microseconds; `None` (and the replay
    /// engine) admit by queue depth only.
    pub slo_deadline_us: Option<f64>,
    /// Host worker threads for the batched stage pre-simulation
    /// (`None` = harness default / `REVEL_WORKERS`).
    pub workers: Option<usize>,
    /// Worker shards for the multi-cell co-simulation (`None` = one
    /// per cell, capped at the host's worker default). Results are
    /// bit-identical for every value; only wall time varies.
    pub shards: Option<usize>,
    /// One-way fronthaul latency between neighboring cells, in virtual
    /// microseconds (`None` = [`DEFAULT_FRONTHAUL_US`]). Only read by
    /// coupled co-sim metros; always floored at the union mix's
    /// [`ShardPlan::lookahead_s`].
    pub fronthaul_us: Option<f64>,
    /// Re-offer SLO/queue-shed arrivals to the least-backlogged peer
    /// cell (one hop over the fronthaul) before counting them as
    /// `deadline_shed`/`dropped`. Co-sim metros only.
    pub reroute: bool,
    /// Optional fault-injection scenario (unit outages, degraded
    /// units, fronthaul faults, transient stage failures, recovery
    /// policy). Co-sim engine only; `None` = fault-free.
    pub faults: Option<FaultPlan>,
    /// The cells of the metro, in fixed cell order.
    pub cells: Vec<CellSpec>,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            seed: 7,
            engine: EngineKind::Replay,
            slo_deadline_us: None,
            workers: None,
            shards: None,
            fronthaul_us: None,
            reroute: false,
            faults: None,
            cells: vec![CellSpec::default()],
        }
    }
}

impl ClusterSpec {
    /// Start an empty metro (add cells with [`ClusterSpec::cell`] /
    /// [`ClusterSpec::cells`]).
    pub fn new(seed: u64) -> Self {
        Self { seed, cells: Vec::new(), ..Self::default() }
    }

    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn slo_deadline_us(mut self, us: Option<f64>) -> Self {
        self.slo_deadline_us = us;
        self
    }

    pub fn workers(mut self, workers: Option<usize>) -> Self {
        self.workers = workers;
        self
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = Some(shards);
        self
    }

    pub fn fronthaul_us(mut self, us: Option<f64>) -> Self {
        self.fronthaul_us = us;
        self
    }

    pub fn reroute(mut self, on: bool) -> Self {
        self.reroute = on;
        self
    }

    pub fn faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan;
        self
    }

    /// Append one cell.
    pub fn cell(mut self, cell: CellSpec) -> Self {
        self.cells.push(cell);
        self
    }

    /// Append `n` clones of `proto` (a homogeneous metro).
    pub fn cells(mut self, n: usize, proto: CellSpec) -> Self {
        self.cells.extend((0..n).map(|_| proto.clone()));
        self
    }

    /// Total jobs the spec's traces offer (replay cells resolve their
    /// length only at serve time).
    pub fn jobs(&self) -> usize {
        self.cells.iter().map(|c| c.jobs).sum()
    }

    /// Whether this spec couples its cells: more than one cell with
    /// handover or re-routing enabled. Coupling needs the co-sim
    /// engine; [`serve`] rejects coupling knobs under replay.
    pub fn coupled(&self) -> bool {
        self.cells.len() > 1
            && (self.reroute || self.cells.iter().any(|c| c.handover_frac > 0.0))
    }

    /// The shard count a co-simulated run of this spec would use.
    pub fn effective_shards(&self) -> usize {
        self.shards
            .unwrap_or_else(|| self.cells.len().min(pool::default_workers()))
            .max(1)
    }

    /// Check the spec is runnable: non-empty cells and mixes, coupling
    /// and fault knobs in range, and any [`FaultPlan`] naming real
    /// cells/units. [`serve`] calls this first, so a bad knob is a
    /// typed [`RtError`] at build time — never a silent clamp or a
    /// fault clause that lands on a unit that does not exist.
    pub fn validate(&self) -> Result<()> {
        if self.cells.is_empty() {
            return Err(RtError("serve: spec has no cells".into()));
        }
        for (i, cell) in self.cells.iter().enumerate() {
            if cell.job_mix.is_empty() {
                return Err(RtError(format!("serve: cell {i} has no job classes")));
            }
            if !(0.0..=1.0).contains(&cell.handover_frac) {
                return Err(RtError(format!(
                    "serve: cell {i}: handover_frac {} is outside [0, 1]",
                    cell.handover_frac
                )));
            }
            cell.arrival
                .validate()
                .map_err(|e| RtError(format!("serve: cell {i}: {e}")))?;
        }
        let wants_coupling =
            self.reroute || self.cells.iter().any(|c| c.handover_frac > 0.0);
        if wants_coupling && self.engine != EngineKind::Cosim {
            return Err(RtError(
                "serve: cross-cell coupling (--handover-frac / --reroute) \
                 requires the cosim engine"
                    .into(),
            ));
        }
        if let Some(us) = self.fronthaul_us {
            // Zero is a valid degenerate spec (co-located cells): it
            // falls back to the one-bus-cycle lookahead floor
            // downstream. Only negative or non-finite latencies are
            // rejected.
            if !(us.is_finite() && us >= 0.0) {
                return Err(RtError(format!(
                    "serve: fronthaul latency {us} us is not a non-negative \
                     finite value"
                )));
            }
        }
        if self.coupled() {
            // Cross-cell messages carry class *indices*; they only mean
            // the same thing everywhere if every cell runs the same mix.
            if self.cells.iter().any(|c| c.job_mix != self.cells[0].job_mix) {
                return Err(RtError(
                    "serve: cross-cell coupling requires an identical job_mix \
                     in every cell (migrants carry class indices)"
                        .into(),
                ));
            }
        }
        if let Some(plan) = &self.faults {
            if self.engine != EngineKind::Cosim {
                return Err(RtError(
                    "serve: fault injection (--faults) requires the cosim \
                     engine"
                        .into(),
                ));
            }
            let locate = |what: &str, cell: usize, unit: usize| -> Result<()> {
                if cell >= self.cells.len() {
                    return Err(RtError(format!(
                        "serve: fault plan {what} names cell {cell}, but the \
                         spec has {} cells",
                        self.cells.len()
                    )));
                }
                let units = self.cells[cell].units.max(1);
                if unit >= units {
                    return Err(RtError(format!(
                        "serve: fault plan {what} names cell {cell} unit \
                         {unit}, but that cell has {units} units"
                    )));
                }
                Ok(())
            };
            for o in &plan.outages {
                locate("crash", o.cell, o.unit)?;
            }
            for d in &plan.degrades {
                locate("degrade", d.cell, d.unit)?;
            }
        }
        Ok(())
    }
}

/// Per-unit slice of a cell report.
///
/// Granularity depends on the engine: replay places whole jobs on
/// units, so `jobs`/`stolen` count jobs; the co-sim engine
/// stage-pipelines, so they count stage executions (4x for the
/// four-stage classes). `busy_s`/`utilization` are compute occupancy
/// under both engines.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UnitReport {
    pub jobs: usize,
    pub busy_s: f64,
    /// busy_s / cell makespan — fraction of the run this unit served.
    pub utilization: f64,
    pub stolen: usize,
}

/// Per-class slice of a cell report.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassReport {
    pub name: String,
    pub weight: f64,
    pub completed: usize,
    /// Simulated cycles per stage; `None` when a stage failed and the
    /// class was degraded.
    pub stage_cycles: Option<[u64; 4]>,
}

/// Host-side batching accounting: how many cycle-accurate simulations
/// actually ran vs. how many stage executions the traces represent.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Batching {
    pub distinct_points: usize,
    pub stage_runs: usize,
}

/// Host-only payload carried inside an otherwise deterministic report.
/// Compares equal to everything, so two same-spec runs still satisfy
/// `ServeReport == ServeReport` (the determinism contract CI diffs);
/// serialization routes it into the artifact's nondeterministic `host`
/// block, which readers drop.
#[derive(Clone, Debug, Default)]
pub struct HostOnly<T>(pub T);

impl<T> PartialEq for HostOnly<T> {
    fn eq(&self, _other: &Self) -> bool {
        true
    }
}

/// Host wall time of one distinct pre-simulated stage point. Memoized
/// stages report the wall time of their first (only) execution.
#[derive(Clone, Debug)]
pub struct StageWall {
    pub kernel: String,
    pub n: usize,
    pub wall_ns_mean: f64,
    pub wall_ns_min: f64,
}

/// One host strong-scaling measurement: metro wall time at a shard
/// count (the deterministic results are identical across rows —
/// that's the point).
#[derive(Clone, Copy, Debug)]
pub struct ScalingRow {
    pub shards: usize,
    pub wall_s: f64,
}

/// One completed job, tagged with the cell that served it. The
/// `jobs_detail` rows of the artifact — and the rows
/// [`ArrivalProcess::Replay`] feeds back in.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobRecord {
    pub cell: usize,
    pub completion: Completion,
}

/// Everything one cell of a serve run reports: its config echo plus
/// its outcome. All fields are deterministic in the spec.
#[derive(Clone, Debug, PartialEq)]
pub struct CellReport {
    // -- config echo (normalized, as run) --
    pub units: usize,
    pub queue_cap: usize,
    pub admit_cap: usize,
    /// Jobs this cell's trace offered (resolved length for replay).
    pub jobs: usize,
    pub arrival: ArrivalProcess,
    /// Echo of [`CellSpec::handover_frac`].
    pub handover_frac: f64,
    // -- outcome --
    pub completed: usize,
    pub dropped: usize,
    pub failed: usize,
    /// Arrivals shed by the co-sim engine's SLO deadline lookahead
    /// (always 0 for replay).
    pub deadline_shed: usize,
    /// Inter-stage handoffs granted on this cell's interconnect
    /// (co-sim only; replay models handoffs as free).
    pub handoffs: usize,
    /// Virtual seconds handoffs waited for the cell's interconnect.
    pub bus_wait_s: f64,
    /// Subframes this cell handed over to its ring neighbor (fronthaul
    /// egress; coupled co-sim metros only).
    pub migrated_out: usize,
    /// Subframes that arrived mid-chain from a neighbor (fronthaul
    /// ingress).
    pub migrated_in: usize,
    /// Shed arrivals this cell re-offered to a peer instead of
    /// counting them as `deadline_shed`/`dropped`.
    pub rerouted_out: usize,
    /// Re-offered arrivals this cell received from peers.
    pub rerouted_in: usize,
    /// Stage re-dispatches scheduled by the fault plane (transient
    /// faults, crash kills, outage waits). 0 without an active plan.
    pub retries: usize,
    /// In-flight stages killed by a scheduled unit crash.
    pub crash_kills: usize,
    /// Fronthaul messages a link-fault window dropped (each was
    /// re-offered to this cell's own queue, not lost).
    pub link_dropped: usize,
    /// Fronthaul messages a link-fault window delayed.
    pub link_delayed: usize,
    pub peak_admit_queue: usize,
    /// Virtual seconds from this cell's first arrival to its last
    /// pipeline exit.
    pub makespan_s: f64,
    pub throughput_per_s: f64,
    pub slo: SloDigest,
    pub per_unit: Vec<UnitReport>,
    pub classes: Vec<ClassReport>,
}

/// Everything one serve run reports: the per-cell reports plus the
/// metro-wide aggregate. All fields are deterministic in the
/// [`ClusterSpec`]; host wall-clock data is added only at
/// serialization time ([`ServeReport::to_json`]) so two runs with the
/// same spec compare equal — for any shard count.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    pub seed: u64,
    pub engine: EngineKind,
    /// Echo of [`ClusterSpec::slo_deadline_us`].
    pub slo_deadline_us: Option<f64>,
    /// Resolved one-way fronthaul latency in virtual microseconds
    /// (spec value or [`DEFAULT_FRONTHAUL_US`], after the lookahead
    /// floor); `None` for uncoupled runs.
    pub fronthaul_us: Option<f64>,
    /// Echo of [`ClusterSpec::reroute`].
    pub reroute: bool,
    /// Echo of the armed fault scenario's spec string (`None` =
    /// fault-free run).
    pub faults: Option<String>,
    /// Total jobs offered across all cells.
    pub jobs: usize,
    /// Per-cell reports, in cell order.
    pub cells: Vec<CellReport>,
    // -- metro aggregates (sums/maxes over cells, in cell order) --
    pub completed: usize,
    pub dropped: usize,
    pub failed: usize,
    pub deadline_shed: usize,
    pub handoffs: usize,
    pub bus_wait_s: f64,
    /// Metro-wide subframe handovers (sum of per-cell `migrated_out`;
    /// every migrant lands, so ingress sums to the same number).
    pub migrations: usize,
    /// Metro-wide shed re-offers (sum of per-cell `rerouted_out`).
    pub reroutes: usize,
    /// Metro-wide fault-plane re-dispatches (sum of per-cell
    /// `retries`).
    pub retries: usize,
    /// Metro-wide stages killed by unit crashes.
    pub crash_kills: usize,
    /// Metro-wide fronthaul messages dropped by link faults.
    pub link_dropped: usize,
    /// Metro-wide fronthaul messages delayed by link faults.
    pub link_delayed: usize,
    pub peak_admit_queue: usize,
    /// Max over cell makespans (cells start at virtual t = 0).
    pub makespan_s: f64,
    pub throughput_per_s: f64,
    /// Metro-wide digest: cell samples absorbed in fixed cell order.
    pub slo: SloDigest,
    pub batching: Batching,
    /// Human-readable reasons for degraded classes or mid-run stage
    /// failures, prefixed with their cell.
    pub stage_errors: Vec<String>,
    /// Per-job timing (present when total jobs <= [`DETAIL_CAP`]).
    pub jobs_detail: Vec<JobRecord>,
    /// Host wall time per distinct pre-simulated stage point. Excluded
    /// from equality and from the deterministic part of the artifact
    /// (it serializes into the `host` block).
    pub stage_wall: HostOnly<Vec<StageWall>>,
    /// Host strong-scaling rows ([`strong_scaling`]); same `host`
    /// block treatment as `stage_wall`.
    pub strong_scaling: HostOnly<Vec<ScalingRow>>,
}

impl ServeReport {
    /// Aggregate per-class completions across cells (cells with the
    /// same class name fold together; mixes may differ per cell).
    pub fn class_totals(&self) -> Vec<ClassReport> {
        let mut out: Vec<ClassReport> = Vec::new();
        for cell in &self.cells {
            for c in &cell.classes {
                match out.iter_mut().find(|o| o.name == c.name) {
                    Some(o) => o.completed += c.completed,
                    None => out.push(c.clone()),
                }
            }
        }
        out
    }
}

struct StageTable {
    per_class: Vec<Option<[u64; 4]>>,
    distinct_points: usize,
    errors: Vec<String>,
    stage_wall: Vec<StageWall>,
}

/// One batched harness pass over the distinct stage kernels of all
/// cells' classes. A failing stage degrades only the classes that use
/// it (the error is recorded); it does not abort the serve run.
fn stage_table(classes: &[JobClass], workers: Option<usize>) -> StageTable {
    let mut points: Vec<SweepPoint> = Vec::new();
    for c in classes {
        for s in &c.stages {
            let p = SweepPoint::new(s.kernel, s.n, Features::ALL, Goal::Latency);
            if !points.contains(&p) {
                points.push(p);
            }
        }
    }
    let opts = harness::Options { workers, use_cache: true };
    let mut errors = Vec::new();
    let outcomes: Vec<Option<Arc<SweepOutcome>>> =
        match harness::run_all_opts(&points, &opts) {
            Ok(os) => os.into_iter().map(Some).collect(),
            // Some point failed: fall back to per-point execution (the
            // memo cache keeps the successful ones free) so only the
            // broken stages degrade.
            Err(_) => points
                .iter()
                .map(|p| {
                    match harness::run_all_opts(std::slice::from_ref(p), &opts) {
                        Ok(mut os) => Some(os.remove(0)),
                        Err(e) => {
                            errors.push(format!("{} n={}: {e}", p.kernel, p.n));
                            None
                        }
                    }
                })
                .collect(),
        };
    let cycles_of = |kernel: &str, n: usize| -> Option<u64> {
        points
            .iter()
            .zip(&outcomes)
            .find(|(p, _)| p.kernel == kernel && p.n == n)
            .and_then(|(_, o)| o.as_ref())
            .map(|o| o.cycles)
    };
    let per_class = classes
        .iter()
        .map(|c| {
            let mut cy = [0u64; 4];
            for (slot, s) in cy.iter_mut().zip(c.stages.iter()) {
                match cycles_of(s.kernel, s.n) {
                    Some(x) => *slot = x,
                    None => return None,
                }
            }
            Some(cy)
        })
        .collect();
    let stage_wall = points
        .iter()
        .zip(&outcomes)
        .filter_map(|(p, o)| {
            o.as_ref().map(|o| StageWall {
                kernel: p.kernel.clone(),
                n: p.n,
                wall_ns_mean: o.wall_ns_mean,
                wall_ns_min: o.wall_ns_min,
            })
        })
        .collect();
    StageTable { per_class, distinct_points: points.len(), errors, stage_wall }
}

/// Sample a class index from cumulative weights.
fn pick_weighted(rng: &mut Rng, cum: &[f64]) -> usize {
    let total = cum.last().copied().unwrap_or(1.0);
    let r = rng.f64() * total;
    cum.iter().position(|&c| r < c).unwrap_or(cum.len().saturating_sub(1))
}

/// Everything one cell needs to run, resolved from its spec.
struct Prep {
    cl: ClusterConfig,
    /// Per-class memoized stage cycles (this cell's slice of the
    /// metro-wide stage table).
    cycles: Vec<Option<[u64; 4]>>,
    /// The same, as per-stage virtual seconds (replay service table).
    service: Vec<Option<[f64; 4]>>,
    cum: Vec<f64>,
    rng: Rng,
    /// Synthesized or replayed open-loop trace; `None` = closed loop.
    trace: Option<Vec<Arrival>>,
    clients: Option<usize>,
    jobs: usize,
}

/// Load an [`ArrivalProcess::Replay`] trace: the `jobs_detail` rows of
/// the artifact at `path` that belong to `cell`, re-sorted into the
/// original synthesis push order (arrival time, then id).
fn load_replay_trace(path: &str, cell: usize, mix_len: usize) -> Result<Vec<Arrival>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| RtError(format!("cell {cell}: replay trace {path}: {e}")))?;
    let src = read_artifact(&text)
        .map_err(|e| RtError(format!("cell {cell}: replay trace {path}: {e}")))?;
    if src.jobs_detail.is_empty() {
        return Err(RtError(format!(
            "cell {cell}: replay trace {path} has no jobs_detail \
             (recorded runs keep it only up to {DETAIL_CAP} jobs)"
        )));
    }
    let mut trace: Vec<Arrival> = src
        .jobs_detail
        .iter()
        .filter(|r| r.cell == cell)
        .map(|r| Arrival {
            id: r.completion.id,
            class: r.completion.class,
            t_s: r.completion.arrival_s,
        })
        .collect();
    for a in &trace {
        if a.class >= mix_len {
            return Err(RtError(format!(
                "cell {cell}: replay trace {path} job {} names class {} but the \
                 cell's mix has {mix_len} classes",
                a.id, a.class
            )));
        }
    }
    trace.sort_by(|a, b| a.t_s.total_cmp(&b.t_s).then(a.id.cmp(&b.id)));
    Ok(trace)
}

/// Engine-neutral view of one cell's outcome.
struct EngineOut {
    completions: Vec<Completion>,
    dropped: usize,
    failed: usize,
    deadline_shed: usize,
    handoffs: usize,
    bus_wait_s: f64,
    migrated_out: usize,
    migrated_in: usize,
    rerouted_out: usize,
    rerouted_in: usize,
    retries: usize,
    crash_kills: usize,
    link_dropped: usize,
    link_delayed: usize,
    units: Vec<cluster::UnitStats>,
    makespan_s: f64,
    peak_admit_queue: usize,
    extra_errors: Vec<String>,
}

/// Serve the spec's traces on the simulated REVEL metro.
///
/// Stage failures degrade the affected class (recorded in
/// `stage_errors` / `failed`) instead of panicking a worker; a
/// [`RtError`] is returned only for unusable specs (no cells, empty
/// mixes, degenerate arrival parameters, unreadable replay traces).
pub fn serve(spec: &ClusterSpec) -> Result<ServeReport> {
    spec.validate()?;
    harness::ensure_budget();
    // One batched pre-simulation over the union of every cell's mix;
    // each cell then slices its rows back out by offset.
    let mut all_classes: Vec<JobClass> = Vec::new();
    let mut offsets: Vec<usize> = Vec::new();
    for cell in &spec.cells {
        offsets.push(all_classes.len());
        all_classes.extend(cell.job_mix.iter().cloned());
    }
    let st = stage_table(&all_classes, spec.workers);

    let mut preps: Vec<Prep> = Vec::with_capacity(spec.cells.len());
    for (i, cell) in spec.cells.iter().enumerate() {
        let off = offsets[i];
        let cycles: Vec<Option<[u64; 4]>> =
            st.per_class[off..off + cell.job_mix.len()].to_vec();
        let service: Vec<Option<[f64; 4]>> = cycles
            .iter()
            .map(|o| o.map(|cy| cy.map(|c| model::cycles_to_us(c) * 1e-6)))
            .collect();
        let cum: Vec<f64> = cell
            .job_mix
            .iter()
            .scan(0.0, |acc, c| {
                *acc += c.weight.max(0.0);
                Some(*acc)
            })
            .collect();
        let mut rng = Rng::new(cell_seed(spec.seed, i));
        let (trace, clients, jobs) = match &cell.arrival {
            ArrivalProcess::Closed { clients } => (None, Some(*clients), cell.jobs),
            ArrivalProcess::Replay { path } => {
                let t = load_replay_trace(path, i, cell.job_mix.len())?;
                let n = t.len();
                (Some(t), None, n)
            }
            open => {
                let t = open
                    .synthesize(cell.jobs, &mut rng, |r| pick_weighted(r, &cum))
                    .expect("open-loop arrival synthesizes a trace");
                (Some(t), None, cell.jobs)
            }
        };
        preps.push(Prep { cl: cell.cluster_config(), cycles, service, cum, rng, trace, clients, jobs });
    }

    // `fronthaul_us` is the resolved cross-cell latency (None when the
    // spec is uncoupled) — echoed into the report and the v4 artifact.
    let (outs, fronthaul_us): (Vec<EngineOut>, Option<f64>) = match spec.engine {
        EngineKind::Replay => (preps
            .iter_mut()
            .map(|p| {
                let Prep { cl, service, cum, rng, trace, clients, jobs, .. } = p;
                let r = match (trace.as_deref(), *clients) {
                    (Some(t), _) => cluster::run(cl, service, Workload::Open(t), || 0),
                    (None, clients) => cluster::run(
                        cl,
                        service,
                        Workload::Closed {
                            clients: clients.unwrap_or(1),
                            jobs: *jobs,
                        },
                        || pick_weighted(rng, cum),
                    ),
                };
                EngineOut {
                    completions: r.completions,
                    dropped: r.dropped,
                    failed: r.failed,
                    deadline_shed: 0,
                    handoffs: 0,
                    bus_wait_s: 0.0,
                    migrated_out: 0,
                    migrated_in: 0,
                    rerouted_out: 0,
                    rerouted_in: 0,
                    retries: 0,
                    crash_kills: 0,
                    link_dropped: 0,
                    link_delayed: 0,
                    units: r.units,
                    makespan_s: r.makespan_s,
                    peak_admit_queue: r.peak_admit_queue,
                    extra_errors: Vec::new(),
                }
            })
            .collect(), None),
        EngineKind::Cosim => {
            // Per-class stage chains with profiled estimates (the same
            // memoized cycles replay consumes); a degraded class maps
            // to `None`, exactly like the replay service table.
            let tables: Vec<Vec<Option<CosimClass>>> = spec
                .cells
                .iter()
                .zip(&preps)
                .map(|(cell, p)| {
                    cell.job_mix
                        .iter()
                        .zip(&p.cycles)
                        .map(|(c, cy)| cy.map(|cy| c.cosim_class(&cy)))
                        .collect()
                })
                .collect();
            let union: Vec<Option<CosimClass>> =
                tables.iter().flatten().cloned().collect();
            // Coupled metros window rounds by the fronthaul latency —
            // the CMB lookahead that makes horizon exchange safe — so
            // it is floored at the mix's conservative lookahead.
            let fronthaul_s = if spec.coupled() {
                let f = spec.fronthaul_us.unwrap_or(DEFAULT_FRONTHAUL_US) * 1e-6;
                Some(f.max(ShardPlan::lookahead_s(&union)))
            } else {
                None
            };
            let plan =
                ShardPlan::for_metro(spec.effective_shards(), &union, fronthaul_s);
            let deadline_s = spec.slo_deadline_us.map(|us| us * 1e-6);
            let cells_n = spec.cells.len();
            let mut sessions: Vec<CosimSession<'_>> = Vec::new();
            for (i, (p, table)) in preps.iter_mut().zip(&tables).enumerate() {
                let ccfg = CosimConfig { cluster: p.cl.clone(), deadline_s };
                let coupling = match fronthaul_s {
                    Some(f) => Coupling {
                        cell: i,
                        cells: cells_n,
                        handover_frac: spec.cells[i].handover_frac,
                        fronthaul_s: f,
                        reroute: spec.reroute,
                    },
                    // Uncoupled cells still carry their true metro
                    // index: handover_frac 0 + reroute off emit nothing
                    // (behaviorally Coupling::none()), but the fault
                    // plane keys its per-cell schedules and transient
                    // draws on `cell`.
                    None => Coupling {
                        cell: i,
                        cells: cells_n,
                        ..Coupling::none()
                    },
                };
                let hand_rng = Rng::new(cell_seed(spec.seed, i) ^ HANDOVER_SALT);
                let workload = match (p.trace.as_deref(), p.clients) {
                    (Some(t), _) => Workload::Open(t),
                    (None, clients) => Workload::Closed {
                        clients: clients.unwrap_or(1),
                        jobs: p.jobs,
                    },
                };
                // The class picker migrates into the session (and onto
                // pool threads), so it owns its RNG and weights.
                let mut rng = std::mem::replace(&mut p.rng, Rng::new(0));
                let cum = p.cum.clone();
                let mut session = CosimSession::with_coupling(
                    &ccfg,
                    table,
                    workload,
                    move || pick_weighted(&mut rng, &cum),
                    coupling,
                    hand_rng,
                );
                if let Some(plan) = &spec.faults {
                    session = session.with_faults(plan, spec.seed);
                }
                sessions.push(session);
            }
            let outs = shard::run_sharded(sessions, &plan)?
                .into_iter()
                .map(|r| {
                    // serve() never shrinks the horizon below the
                    // fronthaul bound, so no message can arrive late.
                    debug_assert_eq!(r.causality_violations, 0);
                    EngineOut {
                        completions: r.completions,
                        dropped: r.dropped,
                        failed: r.failed,
                        deadline_shed: r.deadline_shed,
                        handoffs: r.handoffs,
                        bus_wait_s: r.bus_wait_s,
                        migrated_out: r.migrated_out,
                        migrated_in: r.migrated_in,
                        rerouted_out: r.rerouted_out,
                        rerouted_in: r.rerouted_in,
                        retries: r.retries,
                        crash_kills: r.crash_kills,
                        link_dropped: r.link_dropped,
                        link_delayed: r.link_delayed,
                        units: r.units,
                        makespan_s: r.makespan_s,
                        peak_admit_queue: r.peak_admit_queue,
                        extra_errors: r.stage_errors,
                    }
                })
                .collect();
            (outs, fronthaul_s.map(|f| f * 1e6))
        }
    };

    // Merge in fixed cell order — the bitwise-determinism contract the
    // sharded engine relies on (see SloAccountant::absorb).
    let total_jobs: usize = preps.iter().map(|p| p.jobs).sum();
    let mut metro_acc = SloAccountant::new();
    let mut stage_errors = st.errors;
    let mut cells: Vec<CellReport> = Vec::with_capacity(outs.len());
    let mut jobs_detail: Vec<JobRecord> = Vec::new();
    for (i, (out, p)) in outs.iter().zip(&preps).enumerate() {
        let mut cell_acc = SloAccountant::new();
        let mut per_class_done = vec![0usize; spec.cells[i].job_mix.len()];
        for c in &out.completions {
            per_class_done[c.class] += 1;
            let s = p.service[c.class].unwrap_or([0.0; 4]);
            let service: f64 = s.iter().sum();
            cell_acc.record(
                (c.finish_s - c.arrival_s) * 1e6,
                (c.start_s - c.arrival_s) * 1e6,
                service * 1e6,
                [s[0] * 1e6, s[1] * 1e6, s[2] * 1e6, s[3] * 1e6],
            );
        }
        metro_acc.absorb(&cell_acc);
        let completed = out.completions.len();
        let throughput =
            if out.makespan_s > 0.0 { completed as f64 / out.makespan_s } else { 0.0 };
        let per_unit = out
            .units
            .iter()
            .map(|u| UnitReport {
                jobs: u.jobs,
                busy_s: u.busy_s,
                utilization: if out.makespan_s > 0.0 {
                    u.busy_s / out.makespan_s
                } else {
                    0.0
                },
                stolen: u.stolen,
            })
            .collect();
        let classes = spec.cells[i]
            .job_mix
            .iter()
            .enumerate()
            .map(|(k, c)| ClassReport {
                name: c.name.to_string(),
                weight: c.weight,
                completed: per_class_done[k],
                stage_cycles: p.cycles[k],
            })
            .collect();
        stage_errors
            .extend(out.extra_errors.iter().map(|e| format!("cell {i}: {e}")));
        if total_jobs <= DETAIL_CAP {
            jobs_detail.extend(
                out.completions.iter().map(|&completion| JobRecord { cell: i, completion }),
            );
        }
        cells.push(CellReport {
            units: p.cl.units,
            queue_cap: p.cl.queue_cap,
            admit_cap: p.cl.admit_cap,
            jobs: p.jobs,
            arrival: spec.cells[i].arrival.clone(),
            handover_frac: spec.cells[i].handover_frac,
            completed,
            dropped: out.dropped,
            failed: out.failed,
            deadline_shed: out.deadline_shed,
            handoffs: out.handoffs,
            bus_wait_s: out.bus_wait_s,
            migrated_out: out.migrated_out,
            migrated_in: out.migrated_in,
            rerouted_out: out.rerouted_out,
            rerouted_in: out.rerouted_in,
            retries: out.retries,
            crash_kills: out.crash_kills,
            link_dropped: out.link_dropped,
            link_delayed: out.link_delayed,
            peak_admit_queue: out.peak_admit_queue,
            makespan_s: out.makespan_s,
            throughput_per_s: throughput,
            slo: cell_acc.digest(),
            per_unit,
            classes,
        });
    }
    let completed: usize = cells.iter().map(|c| c.completed).sum();
    let makespan_s = cells.iter().map(|c| c.makespan_s).fold(0.0f64, f64::max);
    Ok(ServeReport {
        seed: spec.seed,
        engine: spec.engine,
        slo_deadline_us: spec.slo_deadline_us,
        fronthaul_us,
        reroute: spec.reroute,
        faults: spec.faults.as_ref().map(|p| p.spec.clone()),
        jobs: total_jobs,
        completed,
        dropped: cells.iter().map(|c| c.dropped).sum(),
        failed: cells.iter().map(|c| c.failed).sum(),
        deadline_shed: cells.iter().map(|c| c.deadline_shed).sum(),
        handoffs: cells.iter().map(|c| c.handoffs).sum(),
        bus_wait_s: cells.iter().map(|c| c.bus_wait_s).sum(),
        migrations: cells.iter().map(|c| c.migrated_out).sum(),
        reroutes: cells.iter().map(|c| c.rerouted_out).sum(),
        retries: cells.iter().map(|c| c.retries).sum(),
        crash_kills: cells.iter().map(|c| c.crash_kills).sum(),
        link_dropped: cells.iter().map(|c| c.link_dropped).sum(),
        link_delayed: cells.iter().map(|c| c.link_delayed).sum(),
        peak_admit_queue: cells.iter().map(|c| c.peak_admit_queue).max().unwrap_or(0),
        makespan_s,
        throughput_per_s: if makespan_s > 0.0 { completed as f64 / makespan_s } else { 0.0 },
        slo: metro_acc.digest(),
        batching: Batching {
            distinct_points: st.distinct_points,
            stage_runs: 4 * completed,
        },
        stage_errors,
        jobs_detail,
        stage_wall: HostOnly(st.stage_wall),
        strong_scaling: HostOnly(Vec::new()),
        cells,
    })
}

/// Serve `spec` once per shard count, wall-timing each run, and return
/// the (bit-identical) report with the strong-scaling rows attached to
/// its `host`-only block. Returns an error if any shard count produces
/// a divergent report — that would be a determinism bug, and this
/// helper doubles as its detector in CI.
///
/// Wall times are informational: the first row also pays any cold
/// stage-simulation cache misses unless the caller warmed the memo
/// cache (e.g. by serving the spec once before).
pub fn strong_scaling(spec: &ClusterSpec, shard_counts: &[usize]) -> Result<ServeReport> {
    if shard_counts.is_empty() {
        return Err(RtError("strong scaling: no shard counts given".into()));
    }
    let mut rows: Vec<ScalingRow> = Vec::with_capacity(shard_counts.len());
    let mut base: Option<ServeReport> = None;
    for &k in shard_counts {
        let mut s = spec.clone();
        s.shards = Some(k.max(1));
        let t0 = std::time::Instant::now();
        let r = serve(&s)?;
        rows.push(ScalingRow { shards: k.max(1), wall_s: t0.elapsed().as_secs_f64() });
        match &base {
            None => base = Some(r),
            Some(b) => {
                if *b != r {
                    return Err(RtError(format!(
                        "strong scaling: shards={k} diverged from shards={} — \
                         shard count must not change results",
                        shard_counts[0].max(1)
                    )));
                }
            }
        }
    }
    let mut report = base.expect("at least one shard count ran");
    report.strong_scaling = HostOnly(rows);
    Ok(report)
}

fn job_record_to_json(r: &JobRecord) -> Json {
    let c = &r.completion;
    Json::obj(vec![
        ("cell", Json::Num(r.cell as f64)),
        ("id", Json::Num(c.id as f64)),
        ("class", Json::Num(c.class as f64)),
        ("unit", Json::Num(c.unit as f64)),
        ("arrival_s", Json::Num(c.arrival_s)),
        ("start_s", Json::Num(c.start_s)),
        ("finish_s", Json::Num(c.finish_s)),
        ("stolen", Json::Bool(c.stolen)),
    ])
}

fn job_record_from_json(v: &Json) -> std::result::Result<JobRecord, String> {
    let err = |f: &str| format!("jobs_detail entry missing/invalid {f:?}");
    let num = |k: &str| v.get(k).and_then(Json::as_f64).ok_or_else(|| err(k));
    Ok(JobRecord {
        // Pre-metro artifacts carry no cell tag: everything is cell 0.
        cell: v.get("cell").and_then(Json::as_usize).unwrap_or(0),
        completion: Completion {
            id: v.get("id").and_then(Json::as_u64).ok_or_else(|| err("id"))?,
            class: v.get("class").and_then(Json::as_usize).ok_or_else(|| err("class"))?,
            unit: v.get("unit").and_then(Json::as_usize).ok_or_else(|| err("unit"))?,
            arrival_s: num("arrival_s")?,
            start_s: num("start_s")?,
            finish_s: num("finish_s")?,
            stolen: v.get("stolen").and_then(Json::as_bool).ok_or_else(|| err("stolen"))?,
        },
    })
}

fn slo_to_json_fields(slo: &SloDigest) -> Vec<(&'static str, Json)> {
    vec![
        ("latency_us", slo.latency_us.to_json()),
        ("queue_us", slo.queue_us.to_json()),
        ("service_us", slo.service_us.to_json()),
    ]
}

fn stage_us_to_json(slo: &SloDigest) -> Json {
    Json::Obj(
        STAGE_NAMES
            .iter()
            .zip(slo.stage_us.iter())
            .map(|(n, p)| (n.to_string(), p.to_json()))
            .collect(),
    )
}

fn slo_from_json(summary: &Json, stage_obj: &Json) -> std::result::Result<SloDigest, String> {
    let err = |f: &str| format!("BENCH_serve document missing/invalid {f:?}");
    let digest = |k: &str| -> std::result::Result<Pctls, String> {
        Pctls::from_json(summary.get(k).ok_or_else(|| err(k))?)
    };
    let mut stage_us = [Pctls::default(); 4];
    for (slot, name) in stage_us.iter_mut().zip(STAGE_NAMES) {
        *slot = Pctls::from_json(stage_obj.get(name).ok_or_else(|| err(name))?)?;
    }
    Ok(SloDigest {
        latency_us: digest("latency_us")?,
        queue_us: digest("queue_us")?,
        service_us: digest("service_us")?,
        stage_us,
    })
}

fn per_unit_to_json(per_unit: &[UnitReport]) -> Json {
    Json::Arr(
        per_unit
            .iter()
            .map(|u| {
                Json::obj(vec![
                    ("jobs", Json::Num(u.jobs as f64)),
                    ("busy_s", Json::Num(u.busy_s)),
                    ("utilization", Json::Num(u.utilization)),
                    ("stolen", Json::Num(u.stolen as f64)),
                ])
            })
            .collect(),
    )
}

fn per_unit_from_json(v: &Json) -> std::result::Result<Vec<UnitReport>, String> {
    let err = |f: &str| format!("per_unit entry missing/invalid {f:?}");
    v.as_arr()
        .ok_or_else(|| err("per_unit"))?
        .iter()
        .map(|u| {
            Ok(UnitReport {
                jobs: u.get("jobs").and_then(Json::as_usize).ok_or_else(|| err("jobs"))?,
                busy_s: u.get("busy_s").and_then(Json::as_f64).ok_or_else(|| err("busy_s"))?,
                utilization: u
                    .get("utilization")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| err("utilization"))?,
                stolen: u
                    .get("stolen")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("stolen"))?,
            })
        })
        .collect()
}

fn classes_to_json(classes: &[ClassReport]) -> Json {
    Json::Arr(
        classes
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("name", Json::Str(c.name.clone())),
                    ("weight", Json::Num(c.weight)),
                    ("completed", Json::Num(c.completed as f64)),
                    (
                        "stage_cycles",
                        match c.stage_cycles {
                            None => Json::Null,
                            Some(cy) => Json::Arr(
                                cy.iter().map(|&x| Json::Num(x as f64)).collect(),
                            ),
                        },
                    ),
                ])
            })
            .collect(),
    )
}

fn classes_from_json(v: &Json) -> std::result::Result<Vec<ClassReport>, String> {
    let err = |f: &str| format!("classes entry missing/invalid {f:?}");
    v.as_arr()
        .ok_or_else(|| err("classes"))?
        .iter()
        .map(|c| {
            let stage_cycles = match c.get("stage_cycles") {
                None | Some(Json::Null) => None,
                Some(Json::Arr(a)) if a.len() == 4 => {
                    let mut cy = [0u64; 4];
                    for (slot, e) in cy.iter_mut().zip(a) {
                        *slot = e.as_u64().ok_or_else(|| err("stage_cycles"))?;
                    }
                    Some(cy)
                }
                _ => return Err(err("stage_cycles")),
            };
            Ok(ClassReport {
                name: c
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err("name"))?
                    .to_string(),
                weight: c.get("weight").and_then(Json::as_f64).ok_or_else(|| err("weight"))?,
                completed: c
                    .get("completed")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("completed"))?,
                stage_cycles,
            })
        })
        .collect()
}

/// The aggregate counters shared by the metro summary and each
/// per-cell outcome block (identical key set at both levels).
struct OutcomeFields {
    completed: usize,
    dropped: usize,
    failed: usize,
    deadline_shed: usize,
    handoffs: usize,
    bus_wait_s: f64,
    retries: usize,
    crash_kills: usize,
    link_dropped: usize,
    link_delayed: usize,
    peak_admit_queue: usize,
    makespan_s: f64,
    throughput_per_s: f64,
}

fn outcome_to_json(o: &OutcomeFields, slo: &SloDigest) -> Vec<(&'static str, Json)> {
    let mut kv = vec![
        ("completed", Json::Num(o.completed as f64)),
        ("dropped", Json::Num(o.dropped as f64)),
        ("failed", Json::Num(o.failed as f64)),
        ("deadline_shed", Json::Num(o.deadline_shed as f64)),
        ("handoffs", Json::Num(o.handoffs as f64)),
        ("bus_wait_s", Json::Num(o.bus_wait_s)),
        ("retries", Json::Num(o.retries as f64)),
        ("crash_kills", Json::Num(o.crash_kills as f64)),
        ("link_dropped", Json::Num(o.link_dropped as f64)),
        ("link_delayed", Json::Num(o.link_delayed as f64)),
        ("peak_admit_queue", Json::Num(o.peak_admit_queue as f64)),
        ("makespan_s", Json::Num(o.makespan_s)),
        ("throughput_per_s", Json::Num(o.throughput_per_s)),
    ];
    kv.extend(slo_to_json_fields(slo));
    kv
}

fn outcome_from_json(v: &Json) -> std::result::Result<OutcomeFields, String> {
    let err = |f: &str| format!("outcome block missing/invalid {f:?}");
    let num = |k: &str| v.get(k).and_then(Json::as_usize).ok_or_else(|| err(k));
    Ok(OutcomeFields {
        completed: num("completed")?,
        dropped: num("dropped")?,
        failed: num("failed")?,
        // Pre-cosim artifacts carry none of these; default to the
        // replay engine's values.
        deadline_shed: v.get("deadline_shed").and_then(Json::as_usize).unwrap_or(0),
        handoffs: v.get("handoffs").and_then(Json::as_usize).unwrap_or(0),
        bus_wait_s: v.get("bus_wait_s").and_then(Json::as_f64).unwrap_or(0.0),
        // Fault counters arrived with schema v5; v1-v4 artifacts parse
        // with them zeroed (fault injection did not exist yet).
        retries: v.get("retries").and_then(Json::as_usize).unwrap_or(0),
        crash_kills: v.get("crash_kills").and_then(Json::as_usize).unwrap_or(0),
        link_dropped: v.get("link_dropped").and_then(Json::as_usize).unwrap_or(0),
        link_delayed: v.get("link_delayed").and_then(Json::as_usize).unwrap_or(0),
        peak_admit_queue: num("peak_admit_queue")?,
        makespan_s: v
            .get("makespan_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| err("makespan_s"))?,
        throughput_per_s: v
            .get("throughput_per_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| err("throughput_per_s"))?,
    })
}

impl ServeReport {
    /// Build the `BENCH_serve.json` document (schema version 5:
    /// multi-cell + cross-cell coupling + fault injection). Everything
    /// except the `host` block is deterministic in the serve spec.
    pub fn to_json(&self, host_wall_s: f64, host_workers: usize, host_shards: usize) -> Json {
        Json::obj(vec![
            ("schema", Json::Str("revel-bench-serve".into())),
            ("version", Json::Num(5.0)),
            ("freq_ghz", Json::Num(model::FREQ_GHZ)),
            (
                "config",
                Json::obj(vec![
                    ("seed", Json::Num(self.seed as f64)),
                    ("engine", Json::Str(self.engine.name().into())),
                    (
                        "slo_deadline_us",
                        match self.slo_deadline_us {
                            None => Json::Null,
                            Some(us) => Json::Num(us),
                        },
                    ),
                    (
                        "fronthaul_us",
                        match self.fronthaul_us {
                            None => Json::Null,
                            Some(us) => Json::Num(us),
                        },
                    ),
                    ("reroute", Json::Bool(self.reroute)),
                    (
                        "faults",
                        match &self.faults {
                            None => Json::Null,
                            Some(s) => Json::Str(s.clone()),
                        },
                    ),
                    ("jobs", Json::Num(self.jobs as f64)),
                    (
                        "cells",
                        Json::Arr(
                            self.cells
                                .iter()
                                .map(|c| {
                                    Json::obj(vec![
                                        ("units", Json::Num(c.units as f64)),
                                        ("queue_cap", Json::Num(c.queue_cap as f64)),
                                        ("admit_cap", Json::Num(c.admit_cap as f64)),
                                        ("jobs", Json::Num(c.jobs as f64)),
                                        ("arrival", c.arrival.to_json()),
                                        (
                                            "handover_frac",
                                            Json::Num(c.handover_frac),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "host",
                Json::obj(vec![
                    ("wall_s", Json::Num(host_wall_s)),
                    ("workers", Json::Num(host_workers as f64)),
                    ("shards", Json::Num(host_shards as f64)),
                    (
                        // Per-point host wall time of the batched stage
                        // pre-simulation (nondeterministic, so it lives
                        // in the host block readers drop).
                        "stage_wall_ns",
                        Json::Arr(
                            self.stage_wall
                                .0
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("kernel", Json::Str(s.kernel.clone())),
                                        ("n", Json::Num(s.n as f64)),
                                        ("mean", Json::Num(s.wall_ns_mean)),
                                        ("min", Json::Num(s.wall_ns_min)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        // Metro wall time per shard count (results are
                        // identical across rows; CI prints this as the
                        // informational strong-scaling table).
                        "strong_scaling",
                        Json::Arr(
                            self.strong_scaling
                                .0
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("shards", Json::Num(r.shards as f64)),
                                        ("wall_s", Json::Num(r.wall_s)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "summary",
                Json::obj({
                    let mut kv = outcome_to_json(
                        &OutcomeFields {
                            completed: self.completed,
                            dropped: self.dropped,
                            failed: self.failed,
                            deadline_shed: self.deadline_shed,
                            handoffs: self.handoffs,
                            bus_wait_s: self.bus_wait_s,
                            retries: self.retries,
                            crash_kills: self.crash_kills,
                            link_dropped: self.link_dropped,
                            link_delayed: self.link_delayed,
                            peak_admit_queue: self.peak_admit_queue,
                            makespan_s: self.makespan_s,
                            throughput_per_s: self.throughput_per_s,
                        },
                        &self.slo,
                    );
                    kv.push(("migrations", Json::Num(self.migrations as f64)));
                    kv.push(("reroutes", Json::Num(self.reroutes as f64)));
                    kv
                }),
            ),
            (
                // Keyed by pipeline *position* (STAGE_NAMES slot labels):
                // the "cholesky" slot aggregates every channel estimator
                // in the mix, including the LU classes.
                "stage_us",
                stage_us_to_json(&self.slo),
            ),
            (
                // Index-aligned with config.cells.
                "per_cell",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            let mut kv = outcome_to_json(
                                &OutcomeFields {
                                    completed: c.completed,
                                    dropped: c.dropped,
                                    failed: c.failed,
                                    deadline_shed: c.deadline_shed,
                                    handoffs: c.handoffs,
                                    bus_wait_s: c.bus_wait_s,
                                    retries: c.retries,
                                    crash_kills: c.crash_kills,
                                    link_dropped: c.link_dropped,
                                    link_delayed: c.link_delayed,
                                    peak_admit_queue: c.peak_admit_queue,
                                    makespan_s: c.makespan_s,
                                    throughput_per_s: c.throughput_per_s,
                                },
                                &c.slo,
                            );
                            kv.push((
                                "migrated_out",
                                Json::Num(c.migrated_out as f64),
                            ));
                            kv.push(("migrated_in", Json::Num(c.migrated_in as f64)));
                            kv.push((
                                "rerouted_out",
                                Json::Num(c.rerouted_out as f64),
                            ));
                            kv.push(("rerouted_in", Json::Num(c.rerouted_in as f64)));
                            kv.push(("stage_us", stage_us_to_json(&c.slo)));
                            kv.push(("per_unit", per_unit_to_json(&c.per_unit)));
                            kv.push(("classes", classes_to_json(&c.classes)));
                            Json::obj(kv)
                        })
                        .collect(),
                ),
            ),
            (
                "batching",
                Json::obj(vec![
                    ("distinct_points", Json::Num(self.batching.distinct_points as f64)),
                    ("stage_runs", Json::Num(self.batching.stage_runs as f64)),
                ]),
            ),
            (
                "stage_errors",
                Json::Arr(self.stage_errors.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            (
                "jobs_detail",
                Json::Arr(self.jobs_detail.iter().map(job_record_to_json).collect()),
            ),
        ])
    }

    /// Inverse of [`ServeReport::to_json`] (the `host` block is
    /// intentionally dropped — it is the only nondeterministic part of
    /// the artifact). Pre-metro artifacts (schema versions 1/2: flat
    /// `config.units`/`config.mode`, no `per_cell`) parse as a
    /// one-cell metro, pre-coupling v3 artifacts parse with the
    /// coupling counters zeroed, and pre-fault v4 artifacts parse with
    /// the fault counters zeroed, so every recorded `BENCH_serve.json`
    /// stays readable and replayable.
    pub fn from_json(v: &Json) -> std::result::Result<ServeReport, String> {
        let err = |f: &str| format!("BENCH_serve document missing/invalid {f:?}");
        let cfg = v.get("config").ok_or_else(|| err("config"))?;
        let summary = v.get("summary").ok_or_else(|| err("summary"))?;
        let seed = cfg.get("seed").and_then(Json::as_u64).ok_or_else(|| err("seed"))?;
        // Engine and SLO fields arrived with the co-sim engine; absent
        // (pre-cosim) artifacts parse as replay with no deadline.
        let engine = match cfg.get("engine").and_then(Json::as_str) {
            None | Some("replay") => EngineKind::Replay,
            Some("cosim") => EngineKind::Cosim,
            _ => return Err(err("engine")),
        };
        let slo_deadline_us = match cfg.get("slo_deadline_us") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| err("slo_deadline_us"))?),
        };
        // Coupling fields arrived with schema v4; older artifacts parse
        // as uncoupled.
        let fronthaul_us = match cfg.get("fronthaul_us") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| err("fronthaul_us"))?),
        };
        let reroute = cfg.get("reroute").and_then(Json::as_bool).unwrap_or(false);
        // The fault-spec echo arrived with schema v5; older artifacts
        // parse as fault-free.
        let faults = match cfg.get("faults") {
            None | Some(Json::Null) => None,
            Some(v) => {
                Some(v.as_str().ok_or_else(|| err("faults"))?.to_string())
            }
        };
        let jobs = cfg.get("jobs").and_then(Json::as_usize).ok_or_else(|| err("jobs"))?;
        let slo = slo_from_json(summary, v.get("stage_us").ok_or_else(|| err("stage_us"))?)?;
        let metro = outcome_from_json(summary)?;

        let cells: Vec<CellReport> = if let Some(cfg_cells) =
            cfg.get("cells").and_then(Json::as_arr)
        {
            // Schema v3: zip config.cells with the per_cell outcomes.
            let out_cells =
                v.get("per_cell").and_then(Json::as_arr).ok_or_else(|| err("per_cell"))?;
            if cfg_cells.len() != out_cells.len() {
                return Err(err("per_cell (length mismatch with config.cells)"));
            }
            cfg_cells
                .iter()
                .zip(out_cells)
                .map(|(cc, oc)| {
                    let cnum =
                        |k: &str| cc.get(k).and_then(Json::as_usize).ok_or_else(|| err(k));
                    let o = outcome_from_json(oc)?;
                    let cnt =
                        |k: &str| oc.get(k).and_then(Json::as_usize).unwrap_or(0);
                    Ok(CellReport {
                        units: cnum("units")?,
                        queue_cap: cnum("queue_cap")?,
                        admit_cap: cnum("admit_cap")?,
                        jobs: cnum("jobs")?,
                        arrival: ArrivalProcess::from_json(
                            cc.get("arrival").ok_or_else(|| err("arrival"))?,
                        )?,
                        handover_frac: cc
                            .get("handover_frac")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                        completed: o.completed,
                        dropped: o.dropped,
                        failed: o.failed,
                        deadline_shed: o.deadline_shed,
                        handoffs: o.handoffs,
                        bus_wait_s: o.bus_wait_s,
                        migrated_out: cnt("migrated_out"),
                        migrated_in: cnt("migrated_in"),
                        rerouted_out: cnt("rerouted_out"),
                        rerouted_in: cnt("rerouted_in"),
                        retries: o.retries,
                        crash_kills: o.crash_kills,
                        link_dropped: o.link_dropped,
                        link_delayed: o.link_delayed,
                        peak_admit_queue: o.peak_admit_queue,
                        makespan_s: o.makespan_s,
                        throughput_per_s: o.throughput_per_s,
                        slo: slo_from_json(
                            oc,
                            oc.get("stage_us").ok_or_else(|| err("stage_us"))?,
                        )?,
                        per_unit: per_unit_from_json(
                            oc.get("per_unit").ok_or_else(|| err("per_unit"))?,
                        )?,
                        classes: classes_from_json(
                            oc.get("classes").ok_or_else(|| err("classes"))?,
                        )?,
                    })
                })
                .collect::<std::result::Result<Vec<_>, String>>()?
        } else {
            // Legacy flat schema: the whole document is one cell whose
            // outcome equals the metro summary.
            let cnum = |k: &str| cfg.get(k).and_then(Json::as_usize).ok_or_else(|| err(k));
            let arrival = match cfg.get("mode").and_then(Json::as_str) {
                Some("open") => ArrivalProcess::Poisson {
                    lambda: cfg
                        .get("lambda")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| err("lambda"))?,
                },
                Some("closed") => ArrivalProcess::Closed { clients: cnum("clients")? },
                _ => return Err(err("mode")),
            };
            vec![CellReport {
                units: cnum("units")?,
                queue_cap: cnum("queue_cap")?,
                admit_cap: cnum("admit_cap")?,
                jobs,
                arrival,
                handover_frac: 0.0,
                completed: metro.completed,
                dropped: metro.dropped,
                failed: metro.failed,
                deadline_shed: metro.deadline_shed,
                handoffs: metro.handoffs,
                bus_wait_s: metro.bus_wait_s,
                migrated_out: 0,
                migrated_in: 0,
                rerouted_out: 0,
                rerouted_in: 0,
                retries: metro.retries,
                crash_kills: metro.crash_kills,
                link_dropped: metro.link_dropped,
                link_delayed: metro.link_delayed,
                peak_admit_queue: metro.peak_admit_queue,
                makespan_s: metro.makespan_s,
                throughput_per_s: metro.throughput_per_s,
                slo: slo.clone(),
                per_unit: per_unit_from_json(
                    v.get("per_unit").ok_or_else(|| err("per_unit"))?,
                )?,
                classes: classes_from_json(
                    v.get("classes").ok_or_else(|| err("classes"))?,
                )?,
            }]
        };

        let batching = v.get("batching").ok_or_else(|| err("batching"))?;
        let stage_errors = v
            .get("stage_errors")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("stage_errors"))?
            .iter()
            .map(|e| e.as_str().map(str::to_string).ok_or_else(|| err("stage_errors")))
            .collect::<std::result::Result<Vec<_>, String>>()?;
        let jobs_detail = v
            .get("jobs_detail")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("jobs_detail"))?
            .iter()
            .map(job_record_from_json)
            .collect::<std::result::Result<Vec<_>, String>>()?;
        Ok(ServeReport {
            seed,
            engine,
            slo_deadline_us,
            fronthaul_us,
            reroute,
            faults,
            jobs,
            cells,
            completed: metro.completed,
            dropped: metro.dropped,
            failed: metro.failed,
            deadline_shed: metro.deadline_shed,
            handoffs: metro.handoffs,
            bus_wait_s: metro.bus_wait_s,
            migrations: summary
                .get("migrations")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            reroutes: summary.get("reroutes").and_then(Json::as_usize).unwrap_or(0),
            retries: metro.retries,
            crash_kills: metro.crash_kills,
            link_dropped: metro.link_dropped,
            link_delayed: metro.link_delayed,
            peak_admit_queue: metro.peak_admit_queue,
            makespan_s: metro.makespan_s,
            throughput_per_s: metro.throughput_per_s,
            slo,
            batching: Batching {
                distinct_points: batching
                    .get("distinct_points")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("distinct_points"))?,
                stage_runs: batching
                    .get("stage_runs")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err("stage_runs"))?,
            },
            stage_errors,
            jobs_detail,
            // Host-block content is intentionally not round-tripped.
            stage_wall: HostOnly::default(),
            strong_scaling: HostOnly::default(),
        })
    }
}

/// Write the `BENCH_serve.json` artifact to `path`.
pub fn write_artifact(
    path: &str,
    report: &ServeReport,
    host_wall_s: f64,
    host_workers: usize,
    host_shards: usize,
) -> std::io::Result<()> {
    std::fs::write(path, report.to_json(host_wall_s, host_workers, host_shards).pretty())
}

/// Parse a serve artifact back (schema round-trip; accepts every
/// schema version this repo has ever written).
pub fn read_artifact(text: &str) -> std::result::Result<ServeReport, String> {
    let doc = json::parse(text)?;
    if doc.get("schema").and_then(Json::as_str) != Some("revel-bench-serve") {
        return Err("not a revel-bench-serve document".into());
    }
    ServeReport::from_json(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StageSpec;

    /// Cheap stage mixes (small solver/gemm/fir points shared with the
    /// harness tests) so serving tests stay fast.
    fn cheap_classes() -> Vec<JobClass> {
        vec![
            JobClass {
                name: "lite",
                stages: [
                    StageSpec { kernel: "solver", n: 8 },
                    StageSpec { kernel: "solver", n: 12 },
                    StageSpec { kernel: "gemm", n: 12 },
                    StageSpec { kernel: "fir", n: 12 },
                ],
                weight: 0.7,
            },
            JobClass {
                name: "heavy",
                stages: [
                    StageSpec { kernel: "solver", n: 16 },
                    StageSpec { kernel: "solver", n: 12 },
                    StageSpec { kernel: "gemm", n: 12 },
                    StageSpec { kernel: "fir", n: 12 },
                ],
                weight: 0.3,
            },
        ]
    }

    /// One flood cell on `units` units: the pre-metro default probe.
    fn spec(units: usize) -> ClusterSpec {
        ClusterSpec::new(7).workers(Some(2)).cell(
            CellSpec::new(units).jobs(24).job_mix(cheap_classes()),
        )
    }

    /// A small co-sim run (live machines make each job's stages real
    /// simulations, so the test traces stay short).
    fn cosim_spec(units: usize, jobs: usize) -> ClusterSpec {
        ClusterSpec::new(7).workers(Some(2)).engine(EngineKind::Cosim).cell(
            CellSpec::new(units).jobs(jobs).job_mix(cheap_classes()),
        )
    }

    #[test]
    fn deterministic_and_scales_with_units() {
        let a = serve(&spec(1)).unwrap();
        let b = serve(&spec(1)).unwrap();
        assert_eq!(a, b, "same spec, same seed => identical report");
        assert_eq!(a.completed, 24);
        assert_eq!(a.cells.len(), 1);
        assert_eq!(a.cells[0].completed, 24);
        assert!(a.slo.latency_us.p99 > 0.0);
        let c = serve(&spec(4)).unwrap();
        assert_eq!(c.completed, 24, "same trace, more units");
        assert!(
            c.throughput_per_s > a.throughput_per_s,
            "4 units beat 1 on the same flood trace ({} vs {})",
            c.throughput_per_s,
            a.throughput_per_s
        );
        assert!(c.makespan_s < a.makespan_s);
    }

    #[test]
    fn artifact_roundtrip_through_json() {
        let r = serve(&spec(2)).unwrap();
        let text = r.to_json(1.5, 8, 1).pretty();
        let back = read_artifact(&text).unwrap();
        assert_eq!(back, r, "host block drops; everything else round-trips");
        assert!(read_artifact("{\"schema\": \"other\"}").is_err());
        // Stage wall times and scaling rows ride in the (dropped) host
        // block only.
        let doc = json::parse(&text).unwrap();
        let walls = doc
            .get("host")
            .and_then(|h| h.get("stage_wall_ns"))
            .and_then(Json::as_arr)
            .expect("host.stage_wall_ns present");
        assert_eq!(walls.len(), r.stage_wall.0.len());
        assert!(back.stage_wall.0.is_empty(), "host block not round-tripped");
        assert!(back.strong_scaling.0.is_empty());
        assert_eq!(
            doc.get("version").and_then(Json::as_u64),
            Some(5),
            "multi-cell + coupling + faults schema version"
        );
    }

    #[test]
    fn closed_loop_and_paced_open_complete_everything() {
        let closed = ClusterSpec::new(7).workers(Some(2)).cell(
            CellSpec::new(2)
                .jobs(24)
                .arrival(ArrivalProcess::Closed { clients: 3 })
                .job_mix(cheap_classes()),
        );
        let r = serve(&closed).unwrap();
        assert_eq!(r.completed, 24);
        assert_eq!(r.dropped, 0, "closed loop self-limits");

        // Pace arrivals near half the flood capacity: queues stay short.
        let flood = serve(&spec(2)).unwrap();
        let mut paced = spec(2);
        paced.cells[0].arrival =
            ArrivalProcess::Poisson { lambda: flood.throughput_per_s * 0.5 };
        let p = serve(&paced).unwrap();
        assert_eq!(p.completed, 24);
        assert!(p.slo.queue_us.p99 <= flood.slo.queue_us.p99);
    }

    #[test]
    fn multi_cell_metro_aggregates_in_cell_order() {
        let metro = ClusterSpec::new(11)
            .workers(Some(2))
            .cell(CellSpec::new(1).jobs(8).job_mix(cheap_classes()))
            .cell(
                CellSpec::new(2)
                    .jobs(10)
                    .arrival(ArrivalProcess::Mmpp {
                        lambda_lo: 100.0,
                        lambda_hi: 10_000.0,
                        mean_dwell_s: 0.01,
                    })
                    .job_mix(cheap_classes()),
            )
            .cell(
                CellSpec::new(2)
                    .jobs(6)
                    .arrival(ArrivalProcess::Closed { clients: 2 })
                    .job_mix(cheap_classes()),
            );
        let a = serve(&metro).unwrap();
        let b = serve(&metro).unwrap();
        assert_eq!(a, b, "metro runs are deterministic per seed");
        assert_eq!(a.cells.len(), 3);
        assert_eq!(a.jobs, 24);
        assert_eq!(
            a.completed,
            a.cells.iter().map(|c| c.completed).sum::<usize>()
        );
        assert_eq!(
            a.makespan_s,
            a.cells.iter().map(|c| c.makespan_s).fold(0.0, f64::max)
        );
        // Every job record is tagged with a live cell index.
        assert_eq!(a.jobs_detail.len(), a.completed);
        assert!(a.jobs_detail.iter().all(|r| r.cell < 3));
        for cell in 0..3 {
            assert_eq!(
                a.jobs_detail.iter().filter(|r| r.cell == cell).count(),
                a.cells[cell].completed
            );
        }
        // Class totals fold the shared mix across cells.
        let totals = a.class_totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(
            totals.iter().map(|c| c.completed).sum::<usize>(),
            a.completed
        );
        // The metro artifact round-trips.
        let back = read_artifact(&a.to_json(0.5, 2, 2).pretty()).unwrap();
        assert_eq!(back, a);
        // Cells see different traffic (flood vs MMPP on independent
        // per-cell RNG streams), so their digests differ.
        assert_ne!(a.cells[0].slo.latency_us, a.cells[1].slo.latency_us);
    }

    #[test]
    fn single_cell_seed_matches_cell_zero_of_a_metro() {
        // cell_seed(seed, 0) == seed: the pre-metro single-cell trace
        // is exactly cell 0 of any metro with the same first cell.
        assert_eq!(cell_seed(7, 0), 7);
        assert_ne!(cell_seed(7, 1), 7);
        let solo = serve(&spec(2)).unwrap();
        let metro = ClusterSpec::new(7)
            .workers(Some(2))
            .cell(CellSpec::new(2).jobs(24).job_mix(cheap_classes()))
            .cell(CellSpec::new(1).jobs(4).job_mix(cheap_classes()));
        let m = serve(&metro).unwrap();
        assert_eq!(m.cells[0], solo.cells[0], "cell 0 unchanged by cell 1");
    }

    #[test]
    fn cosim_engine_is_deterministic_and_never_beats_replay_makespan() {
        let a = serve(&cosim_spec(1, 12)).unwrap();
        let b = serve(&cosim_spec(1, 12)).unwrap();
        assert_eq!(a, b, "cosim: same spec, same seed => identical report");
        assert_eq!(a.engine, EngineKind::Cosim);
        assert_eq!(a.completed, 12);
        assert!(a.handoffs > 0, "4-stage jobs hand off between stages");
        assert!(a.stage_errors.is_empty(), "{:?}", a.stage_errors);
        // Replay is the optimistic oracle: on one unit its flood
        // makespan equals the total compute — a lower bound for any
        // schedule that additionally pays inter-stage handoffs.
        let mut rspec = spec(1);
        rspec.cells[0].jobs = 12;
        let replay = serve(&rspec).unwrap();
        assert_eq!(replay.completed, 12);
        assert!(
            a.makespan_s >= replay.makespan_s,
            "cosim {} < replay {}",
            a.makespan_s,
            replay.makespan_s
        );
        assert_eq!(replay.handoffs, 0);
        assert_eq!(replay.bus_wait_s, 0.0);
    }

    #[test]
    fn slo_admission_sheds_through_the_serve_path() {
        // Far below one subframe's service demand: every arrival is
        // predicted late and shed at admission.
        let c = cosim_spec(1, 10).slo_deadline_us(Some(1.0));
        let r = serve(&c).unwrap();
        assert!(r.deadline_shed > 0, "flood must trip the deadline lookahead");
        assert_eq!(r.completed + r.deadline_shed + r.dropped + r.failed, 10);
        // Replay ignores the knob entirely.
        let mut rspec = spec(1).slo_deadline_us(Some(1.0));
        rspec.cells[0].jobs = 10;
        let rr = serve(&rspec).unwrap();
        assert_eq!(rr.deadline_shed, 0);
        assert_eq!(rr.completed, 10);
    }

    #[test]
    fn cosim_artifact_roundtrips() {
        let c = cosim_spec(2, 8).slo_deadline_us(Some(1e9)); // generous: nothing sheds
        let r = serve(&c).unwrap();
        assert_eq!(r.deadline_shed, 0);
        let text = r.to_json(0.5, 4, 1).pretty();
        let back = read_artifact(&text).unwrap();
        assert_eq!(back, r, "host block drops; everything else round-trips");
        assert_eq!(back.engine, EngineKind::Cosim);
        assert_eq!(back.slo_deadline_us, Some(1e9));
    }

    #[test]
    fn coupling_knobs_validate_and_roundtrip() {
        let cell = || CellSpec::new(1).jobs(5).job_mix(cheap_classes());
        // Coupling knobs under replay are an error, not a silent no-op.
        let replayed = ClusterSpec::new(7).reroute(true).cells(2, cell());
        assert!(serve(&replayed).is_err());
        // Migrants carry class indices: mixes must match across cells.
        let uneven = ClusterSpec::new(7)
            .workers(Some(2))
            .engine(EngineKind::Cosim)
            .cell(cell().handover_frac(0.5))
            .cell(cell().job_mix(vec![cheap_classes()[0]]));
        assert!(serve(&uneven).is_err());
        // handover_frac is a probability.
        let out_of_range = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .cells(2, cell().handover_frac(1.5));
        assert!(serve(&out_of_range).is_err());
        let bad_fronthaul = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .fronthaul_us(Some(-1.0))
            .cells(2, cell().handover_frac(0.5));
        assert!(serve(&bad_fronthaul).is_err());

        // A coupled metro serves, counts its cross-cell traffic, and
        // its v5 artifact round-trips bit-exactly.
        let coupled = ClusterSpec::new(7)
            .workers(Some(2))
            .engine(EngineKind::Cosim)
            .reroute(true)
            .cells(2, cell().handover_frac(1.0));
        let r = serve(&coupled).unwrap();
        assert!(r.migrations > 0, "handover_frac=1 migrates every boundary");
        assert_eq!(
            r.migrations,
            r.cells.iter().map(|c| c.migrated_in).sum::<usize>(),
            "every migrant lands somewhere"
        );
        // The resolved echo is the default link (well above the
        // lookahead floor), modulo the us <-> s unit round-trip.
        let fh = r.fronthaul_us.expect("coupled runs echo the fronthaul");
        assert!((fh - DEFAULT_FRONTHAUL_US).abs() < 1e-6, "{fh}");
        assert_eq!(
            r.completed + r.dropped + r.failed + r.deadline_shed,
            10,
            "coupling conserves jobs metro-wide"
        );
        let back = read_artifact(&r.to_json(0.5, 2, 1).pretty()).unwrap();
        assert_eq!(back, r);
        assert!(back.reroute);
        assert_eq!(back.cells[0].handover_frac, 1.0);
    }

    #[test]
    fn spec_validation_rejects_bad_knobs_with_typed_errors() {
        let cell = || CellSpec::new(1).jobs(5).job_mix(cheap_classes());
        // handover_frac outside [0, 1] is a typed error, not a clamp.
        let s = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .cells(2, cell().handover_frac(-0.1));
        assert!(s.validate().unwrap_err().0.contains("handover_frac"));
        // Non-finite fronthaul latency is rejected up front.
        let s = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .fronthaul_us(Some(f64::NAN))
            .cells(2, cell().handover_frac(0.5));
        assert!(s.validate().unwrap_err().0.contains("fronthaul"));
        // Fault injection needs the live-machine engine.
        let s = ClusterSpec::new(7)
            .faults(Some(FaultPlan::parse("p=0.1").unwrap()))
            .cell(cell());
        assert!(s.validate().unwrap_err().0.contains("cosim"));
        // Fault clauses must name cells/units that exist.
        let s = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .faults(Some(FaultPlan::parse("crash=2.0@100").unwrap()))
            .cells(2, cell());
        assert!(s.validate().unwrap_err().0.contains("cell 2"));
        let s = ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .faults(Some(FaultPlan::parse("degrade=0.3@2.0").unwrap()))
            .cells(2, cell());
        assert!(s.validate().unwrap_err().0.contains("unit 3"));
        // A well-formed faulted spec passes.
        ClusterSpec::new(7)
            .engine(EngineKind::Cosim)
            .faults(Some(FaultPlan::parse("crash=1.0@100..500; p=0.01").unwrap()))
            .cells(2, cell())
            .validate()
            .unwrap();
    }

    #[test]
    fn faulted_serve_is_deterministic_conserves_jobs_and_roundtrips() {
        let spec_str = "crash=0.0@0..400; p=0.05; retries=3; backoff=10";
        let plan = FaultPlan::parse(spec_str).unwrap();
        let c = cosim_spec(2, 10).faults(Some(plan));
        let a = serve(&c).unwrap();
        let b = serve(&c).unwrap();
        assert_eq!(a, b, "fault plans replay bit-identically");
        assert_eq!(
            a.completed + a.dropped + a.deadline_shed + a.failed,
            10,
            "conservation holds under faults"
        );
        assert!(
            a.crash_kills > 0 || a.retries > 0,
            "the crash schedule actually fired"
        );
        assert_eq!(a.faults.as_deref(), Some(spec_str));
        let back = read_artifact(&a.to_json(0.5, 2, 1).pretty()).unwrap();
        assert_eq!(back, a, "fault counters and spec echo round-trip");
        // The same spec without the plan completes everything: the
        // fault plane is the only difference.
        let clean = serve(&cosim_spec(2, 10)).unwrap();
        assert_eq!(clean.faults, None);
        assert_eq!(clean.crash_kills + clean.retries + clean.failed, 0);
    }

    /// Render `r` (a one-cell report) in the legacy flat schema the
    /// repo wrote before the multi-cell redesign — the compatibility
    /// corpus for [`ServeReport::from_json`]'s legacy path.
    fn legacy_v1_doc(r: &ServeReport) -> Json {
        let cell = &r.cells[0];
        let (mode, lambda, clients) = match &cell.arrival {
            ArrivalProcess::Poisson { lambda } => ("open", *lambda, 0usize),
            ArrivalProcess::Closed { clients } => ("closed", 0.0, *clients),
            other => panic!("legacy schema cannot express {other:?}"),
        };
        Json::obj(vec![
            ("schema", Json::Str("revel-bench-serve".into())),
            ("version", Json::Num(1.0)),
            ("freq_ghz", Json::Num(model::FREQ_GHZ)),
            (
                "config",
                Json::obj(vec![
                    ("units", Json::Num(cell.units as f64)),
                    ("jobs", Json::Num(r.jobs as f64)),
                    ("seed", Json::Num(r.seed as f64)),
                    ("mode", Json::Str(mode.into())),
                    ("engine", Json::Str(r.engine.name().into())),
                    (
                        "slo_deadline_us",
                        match r.slo_deadline_us {
                            None => Json::Null,
                            Some(us) => Json::Num(us),
                        },
                    ),
                    ("lambda", Json::Num(lambda)),
                    ("clients", Json::Num(clients as f64)),
                    ("queue_cap", Json::Num(cell.queue_cap as f64)),
                    ("admit_cap", Json::Num(cell.admit_cap as f64)),
                ]),
            ),
            ("host", Json::obj(vec![("wall_s", Json::Num(0.25))])),
            (
                "summary",
                Json::obj(outcome_to_json(
                    &OutcomeFields {
                        completed: r.completed,
                        dropped: r.dropped,
                        failed: r.failed,
                        deadline_shed: r.deadline_shed,
                        handoffs: r.handoffs,
                        bus_wait_s: r.bus_wait_s,
                        retries: r.retries,
                        crash_kills: r.crash_kills,
                        link_dropped: r.link_dropped,
                        link_delayed: r.link_delayed,
                        peak_admit_queue: r.peak_admit_queue,
                        makespan_s: r.makespan_s,
                        throughput_per_s: r.throughput_per_s,
                    },
                    &r.slo,
                )),
            ),
            ("stage_us", stage_us_to_json(&r.slo)),
            ("per_unit", per_unit_to_json(&cell.per_unit)),
            ("classes", classes_to_json(&cell.classes)),
            (
                "batching",
                Json::obj(vec![
                    ("distinct_points", Json::Num(r.batching.distinct_points as f64)),
                    ("stage_runs", Json::Num(r.batching.stage_runs as f64)),
                ]),
            ),
            ("stage_errors", Json::Arr(Vec::new())),
            (
                // Legacy rows carry no "cell" key.
                "jobs_detail",
                Json::Arr(
                    r.jobs_detail
                        .iter()
                        .map(|jr| {
                            let c = &jr.completion;
                            Json::obj(vec![
                                ("id", Json::Num(c.id as f64)),
                                ("class", Json::Num(c.class as f64)),
                                ("unit", Json::Num(c.unit as f64)),
                                ("arrival_s", Json::Num(c.arrival_s)),
                                ("start_s", Json::Num(c.start_s)),
                                ("finish_s", Json::Num(c.finish_s)),
                                ("stolen", Json::Bool(c.stolen)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn legacy_flat_artifacts_parse_as_a_one_cell_metro() {
        let r = serve(&spec(2)).unwrap();
        let old = read_artifact(&legacy_v1_doc(&r).pretty()).unwrap();
        assert_eq!(old, r, "legacy flat schema reconstructs the one-cell report");
        assert_eq!(old.cells.len(), 1);
        assert!(old.jobs_detail.iter().all(|jr| jr.cell == 0));
        // Pre-cosim documents additionally lack the engine/SLO keys;
        // drop them line-wise (keys sort alphabetically, so none is the
        // last entry of its object and the JSON stays valid).
        let precosim_keys = [
            "\"engine\"",
            "\"slo_deadline_us\"",
            "\"deadline_shed\"",
            "\"handoffs\"",
            "\"bus_wait_s\"",
        ];
        let old_text: String = legacy_v1_doc(&r)
            .pretty()
            .lines()
            .filter(|l| !precosim_keys.iter().any(|k| l.trim_start().starts_with(k)))
            .collect::<Vec<_>>()
            .join("\n");
        let pre = read_artifact(&old_text).unwrap();
        assert_eq!(pre.engine, EngineKind::Replay);
        assert_eq!(pre.slo_deadline_us, None);
        assert_eq!(pre.deadline_shed, 0);
        assert_eq!(pre, r, "defaults reconstruct the replay report");
    }

    #[test]
    fn trace_replay_roundtrip_is_bit_identical() {
        // Record a paced run (everything completes, so jobs_detail is
        // the full trace)...
        let flood = serve(&spec(2)).unwrap();
        let mut paced = spec(2);
        paced.cells[0].arrival =
            ArrivalProcess::Poisson { lambda: flood.throughput_per_s * 0.5 };
        let recorded = serve(&paced).unwrap();
        assert_eq!(recorded.completed, 24);
        let path = std::env::temp_dir().join("revel_serve_replay_roundtrip.json");
        let path = path.to_str().unwrap().to_string();
        write_artifact(&path, &recorded, 0.0, 1, 1).unwrap();
        // ...then replay it through ArrivalProcess::Replay: completions
        // are bit-identical (ids, classes, arrival/start/finish times).
        let mut replayed_spec = spec(2);
        replayed_spec.cells[0].arrival = ArrivalProcess::Replay { path: path.clone() };
        let replayed = serve(&replayed_spec).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(replayed.jobs, 24, "replay resolves its own trace length");
        assert_eq!(replayed.jobs_detail, recorded.jobs_detail);
        assert_eq!(replayed.slo, recorded.slo);
        assert_eq!(replayed.completed, recorded.completed);
        assert_eq!(
            replayed.cells[0].arrival,
            ArrivalProcess::Replay { path },
            "the report echoes the replay source"
        );
    }

    #[test]
    fn strong_scaling_rows_are_attached_and_reports_identical() {
        let metro = ClusterSpec::new(7)
            .workers(Some(2))
            .cells(4, CellSpec::new(1).jobs(4).job_mix(cheap_classes()))
            .engine(EngineKind::Cosim);
        let r = strong_scaling(&metro, &[1, 2]).unwrap();
        assert_eq!(r.strong_scaling.0.len(), 2);
        assert_eq!(r.strong_scaling.0[0].shards, 1);
        assert_eq!(r.strong_scaling.0[1].shards, 2);
        assert!(r.strong_scaling.0.iter().all(|row| row.wall_s >= 0.0));
        assert_eq!(r.completed, 16);
        // The attached report equals a plain serve of the same spec.
        let plain = serve(&metro).unwrap();
        assert_eq!(r, plain);
    }

    #[test]
    fn batching_amortizes_stage_sims() {
        let r = serve(&spec(2)).unwrap();
        // 2 classes share gemm/fir/solver-12 points: 5 distinct sims
        // behind 24 * 4 stage executions.
        assert_eq!(r.batching.distinct_points, 5);
        assert_eq!(r.batching.stage_runs, 96);
        assert!(r.stage_errors.is_empty());
        // One wall-time record per distinct stage point, all measured.
        assert_eq!(r.stage_wall.0.len(), 5);
        assert!(r.stage_wall.0.iter().all(|s| s.wall_ns_mean > 0.0));
    }
}
