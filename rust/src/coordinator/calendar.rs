//! Shared wake-time calendar for virtual-time discrete-event engines.
//!
//! Generalizes the two event cores that existed before it: the
//! per-machine wake-time scan inside [`crate::sim::Machine`] (which
//! fast-forwards one unit over quiescent spans) and the private binary
//! heap of [`super::cluster`] (which replays memoized service times).
//! Both the replay dispatcher and the multi-unit co-simulation engine
//! ([`super::cosim`]) now schedule against this one structure, so a
//! cluster run is a single totally ordered virtual timeline in which
//! unit progress, dispatch, work stealing, admission, and shared-bus
//! grants interleave deterministically. The tile-DAG scheduler
//! ([`super::cosim::run_dag`]) is the third client: its timeline is
//! denominated in cycles rather than seconds, but leans on the same
//! FIFO tie-break for its bit-deterministic task completions.
//!
//! Ordering: earliest timestamp first; ties break on insertion
//! sequence (FIFO), which is what makes runs bit-deterministic — two
//! events at the same virtual instant pop in the order the engine
//! created them, never in allocator or hash order.

use std::collections::BinaryHeap;

/// One scheduled event: a timestamp, a tie-breaking sequence number,
/// and the engine-specific payload.
struct Entry<E> {
    t: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.t.to_bits() == o.t.to_bits() && self.seq == o.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl<E> Ord for Entry<E> {
    // Reversed: BinaryHeap is a max-heap, we want the earliest event
    // (and, within a timestamp, the lowest sequence number) on top.
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        o.t.total_cmp(&self.t).then_with(|| o.seq.cmp(&self.seq))
    }
}

/// A deterministic virtual-time event calendar.
pub struct Calendar<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for Calendar<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Calendar<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `ev` at virtual time `t` (seconds). Events at equal
    /// times pop in push order.
    pub fn push(&mut self, t: f64, ev: E) {
        self.heap.push(Entry { t, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Remove and return the earliest event with its timestamp.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.t, e.ev))
    }

    /// Timestamp of the earliest pending event, if any. Co-simulation
    /// drivers use this as the lookahead signal: with no pending event
    /// a unit may run its stage out in one go, since nothing can
    /// interact with it earlier; otherwise it advances one bounded
    /// chunk and yields the timeline back.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.t)
    }

    /// Remove and return the earliest event if it is scheduled strictly
    /// before `horizon` — the primitive of conservative sharded
    /// co-simulation: a shard drains its calendar up to the agreed
    /// horizon and stops, leaving at-or-after events (and their FIFO
    /// order) intact for the next window.
    pub fn pop_before(&mut self, horizon: f64) -> Option<(f64, E)> {
        match self.peek_time() {
            Some(t) if t < horizon => self.pop(),
            _ => None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_insertion_order() {
        let mut c = Calendar::new();
        c.push(2.0, "late");
        c.push(1.0, "a");
        c.push(1.0, "b");
        c.push(0.5, "first");
        assert_eq!(c.peek_time(), Some(0.5));
        let order: Vec<&str> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "a", "b", "late"]);
        assert!(c.is_empty());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn negative_zero_and_identical_times_stay_fifo() {
        let mut c = Calendar::new();
        c.push(0.0, 1);
        c.push(-0.0, 2);
        c.push(0.0, 3);
        // total_cmp orders -0.0 before +0.0; within a bit-identical
        // timestamp, insertion order decides.
        let order: Vec<i32> = std::iter::from_fn(|| c.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![2, 1, 3]);
    }

    #[test]
    fn same_timestamp_burst_of_10k_pops_in_push_order() {
        // The fault plane leans on this hard: a crash kills and
        // re-dispatches many stages at one virtual instant, so FIFO
        // under a large same-timestamp burst is the invariant that
        // keeps faulted runs bit-deterministic. Interleave a few other
        // timestamps so the burst shares the heap with neighbors.
        let mut c = Calendar::new();
        c.push(0.5, usize::MAX); // before the burst
        for i in 0..10_000usize {
            c.push(1.0, i);
        }
        c.push(2.0, usize::MAX - 1); // after the burst
        assert_eq!(c.len(), 10_002);
        assert_eq!(c.pop(), Some((0.5, usize::MAX)));
        for want in 0..10_000usize {
            let (t, got) = c.pop().expect("burst event present");
            assert_eq!(t, 1.0);
            assert_eq!(got, want, "tie-break must be push order, not heap order");
        }
        assert_eq!(c.pop(), Some((2.0, usize::MAX - 1)));
        assert!(c.is_empty());
    }

    #[test]
    fn pop_before_respects_the_horizon_and_fifo() {
        let mut c = Calendar::new();
        c.push(1.0, "a");
        c.push(1.0, "b");
        c.push(2.0, "later");
        assert_eq!(c.pop_before(1.0), None, "horizon is exclusive");
        assert_eq!(c.pop_before(1.5), Some((1.0, "a")));
        assert_eq!(c.pop_before(1.5), Some((1.0, "b")));
        assert_eq!(c.pop_before(1.5), None);
        assert_eq!(c.len(), 1, "at-or-after events stay queued");
        assert_eq!(c.pop_before(f64::INFINITY), Some((2.0, "later")));
    }
}
