//! Online latency/SLO accounting for the serving cluster: per-job
//! latency, queueing, and service samples are folded into percentile
//! digests (p50/p95/p99/mean/max) overall and per pipeline stage.
//!
//! All figures are in *virtual* microseconds — simulated cycles at the
//! REVEL clock ([`crate::model::FREQ_GHZ`]) — so the digests are
//! bit-deterministic for a fixed trace and independent of host load.

use crate::harness::json::Json;
use crate::util::stats::{mean, percentile};

/// A percentile digest over one latency population (microseconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Pctls {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl Pctls {
    /// Digest a sample; an empty sample digests to all zeros (never
    /// NaN, which JSON cannot represent).
    pub fn of(xs: &[f64]) -> Pctls {
        if xs.is_empty() {
            return Pctls::default();
        }
        Pctls {
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            mean: mean(xs),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
            ("mean", Json::Num(self.mean)),
            ("max", Json::Num(self.max)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Pctls, String> {
        let f = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("percentile digest missing {k:?}"))
        };
        Ok(Pctls {
            p50: f("p50")?,
            p95: f("p95")?,
            p99: f("p99")?,
            mean: f("mean")?,
            max: f("max")?,
        })
    }
}

/// The digests a serve run reports (all in virtual microseconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloDigest {
    /// End-to-end subframe latency (arrival to pipeline exit).
    pub latency_us: Pctls,
    /// Time spent waiting for a unit (arrival to service start).
    pub queue_us: Pctls,
    /// Pure service time (all four stages back to back).
    pub service_us: Pctls,
    /// Per-pipeline-stage service time, in
    /// [`super::STAGE_NAMES`] order.
    pub stage_us: [Pctls; 4],
}

/// Accumulates per-job samples and digests them on demand.
#[derive(Clone, Debug, Default)]
pub struct SloAccountant {
    latency_us: Vec<f64>,
    queue_us: Vec<f64>,
    service_us: Vec<f64>,
    stage_us: [Vec<f64>; 4],
}

impl SloAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed job (all samples in microseconds; `stages`
    /// in pipeline order).
    pub fn record(&mut self, latency: f64, queue: f64, service: f64, stages: [f64; 4]) {
        self.latency_us.push(latency);
        self.queue_us.push(queue);
        self.service_us.push(service);
        for (acc, s) in self.stage_us.iter_mut().zip(stages) {
            acc.push(s);
        }
    }

    /// Fold another accountant's samples into this one. The multi-cell
    /// serve path digests each cell independently, then absorbs the
    /// cells in fixed cell order into the metro-wide digest. The fixed
    /// order matters bitwise: percentiles sort internally, but `mean`
    /// sums in sample order, so only a shard-mapping-independent absorb
    /// order keeps the digest bit-identical across shard counts.
    pub fn absorb(&mut self, other: &SloAccountant) {
        self.latency_us.extend_from_slice(&other.latency_us);
        self.queue_us.extend_from_slice(&other.queue_us);
        self.service_us.extend_from_slice(&other.service_us);
        for (acc, s) in self.stage_us.iter_mut().zip(&other.stage_us) {
            acc.extend_from_slice(s);
        }
    }

    pub fn jobs(&self) -> usize {
        self.latency_us.len()
    }

    pub fn digest(&self) -> SloDigest {
        SloDigest {
            latency_us: Pctls::of(&self.latency_us),
            queue_us: Pctls::of(&self.queue_us),
            service_us: Pctls::of(&self.service_us),
            stage_us: [
                Pctls::of(&self.stage_us[0]),
                Pctls::of(&self.stage_us[1]),
                Pctls::of(&self.stage_us[2]),
                Pctls::of(&self.stage_us[3]),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::json;

    #[test]
    fn digest_orders_percentiles() {
        let mut acc = SloAccountant::new();
        for i in 0..100 {
            let x = (i + 1) as f64;
            acc.record(x, x / 2.0, x / 2.0, [x / 8.0; 4]);
        }
        let d = acc.digest();
        assert!(d.latency_us.p50 <= d.latency_us.p95);
        assert!(d.latency_us.p95 <= d.latency_us.p99);
        assert!(d.latency_us.p99 <= d.latency_us.max);
        assert_eq!(d.latency_us.max, 100.0);
        assert_eq!(acc.jobs(), 100);
    }

    #[test]
    fn empty_digest_is_zero_not_nan() {
        let d = SloAccountant::new().digest();
        assert_eq!(d.latency_us, Pctls::default());
        assert!(!d.latency_us.p99.is_nan());
    }

    #[test]
    fn absorb_equals_recording_in_one_accountant() {
        // Two "cells" absorbed in cell order must digest bit-identically
        // to one accountant fed the same samples in the same order.
        let mut all = SloAccountant::new();
        let mut parts = [SloAccountant::new(), SloAccountant::new()];
        for part in 0..2 {
            for i in 0..20 {
                let x = ((part * 20 + i) * 7 % 13) as f64 + 0.5;
                all.record(x, x / 2.0, x / 3.0, [x; 4]);
                parts[part].record(x, x / 2.0, x / 3.0, [x; 4]);
            }
        }
        let mut merged = SloAccountant::new();
        merged.absorb(&parts[0]);
        merged.absorb(&parts[1]);
        assert_eq!(merged.digest(), all.digest());
        assert_eq!(merged.jobs(), 40);
    }

    #[test]
    fn pctls_json_roundtrip() {
        let p = Pctls { p50: 1.5, p95: 2.25, p99: 3.125, mean: 1.75, max: 4.0 };
        let back = Pctls::from_json(&json::parse(&p.to_json().pretty()).unwrap());
        assert_eq!(back.unwrap(), p);
    }
}
