//! Typed arrival processes for the multi-cell serving cluster.
//!
//! Each [`super::serve::CellSpec`] names one [`ArrivalProcess`]; the
//! serve layer synthesizes a per-cell arrival trace from it with a
//! per-cell RNG, so cells are independent traffic domains and the
//! whole metro run stays bit-deterministic per seed:
//!
//! * `Poisson` — open-loop homogeneous Poisson at `lambda` jobs/s;
//!   `lambda <= 0` degenerates to a flood (every job at `t = 0`), the
//!   peak-capacity probe.
//! * `Mmpp` — a 2-state Markov-modulated Poisson process (the classic
//!   bursty-traffic model): the cell alternates between a low-rate and
//!   a high-rate state with exponentially distributed dwell times.
//! * `Diurnal` — a non-homogeneous Poisson process whose rate swings
//!   sinusoidally around `lambda` (period `period_s`, relative
//!   amplitude `depth`), sampled exactly by Lewis–Shedler thinning.
//! * `Replay` — the recorded `jobs_detail` arrivals of an earlier
//!   serve artifact, replayed verbatim (loaded by the serve layer,
//!   which owns artifact parsing).
//! * `Closed` — not a trace at all: `clients` zero-think-time
//!   submitters, each issuing its next job on completion.

use crate::harness::json::Json;
use crate::util::Rng;

use super::cluster::Arrival;

/// How jobs arrive at one cell. See the module docs for the semantics
/// of each variant.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson at `lambda` jobs/s (`lambda <= 0`: flood).
    Poisson { lambda: f64 },
    /// Bursty 2-state MMPP: rate `lambda_lo` or `lambda_hi`, state
    /// dwell times exponential with mean `mean_dwell_s` seconds.
    Mmpp { lambda_lo: f64, lambda_hi: f64, mean_dwell_s: f64 },
    /// Diurnally modulated Poisson: rate(t) = `lambda` * (1 + `depth` *
    /// sin(2πt / `period_s`)), with `0 <= depth <= 1`.
    Diurnal { lambda: f64, period_s: f64, depth: f64 },
    /// Replay the arrivals recorded in the `jobs_detail` of the serve
    /// artifact at `path` (rows of this cell's index).
    Replay { path: String },
    /// Closed loop with `clients` zero-think-time submitters.
    Closed { clients: usize },
}

impl Default for ArrivalProcess {
    fn default() -> Self {
        ArrivalProcess::Poisson { lambda: 0.0 }
    }
}

impl ArrivalProcess {
    /// Short kind tag used in artifacts and CLI output.
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Mmpp { .. } => "mmpp",
            ArrivalProcess::Diurnal { .. } => "diurnal",
            ArrivalProcess::Replay { .. } => "replay",
            ArrivalProcess::Closed { .. } => "closed",
        }
    }

    /// Reject parameterizations with no sensible sampling semantics.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            ArrivalProcess::Poisson { lambda } => {
                if !lambda.is_finite() {
                    return Err(format!("poisson lambda must be finite, got {lambda}"));
                }
            }
            ArrivalProcess::Mmpp { lambda_lo, lambda_hi, mean_dwell_s } => {
                if !(*lambda_lo > 0.0 && *lambda_hi > 0.0 && *mean_dwell_s > 0.0) {
                    return Err(format!(
                        "mmpp needs lambda_lo/lambda_hi/mean_dwell_s > 0, got \
                         {lambda_lo}/{lambda_hi}/{mean_dwell_s}"
                    ));
                }
            }
            ArrivalProcess::Diurnal { lambda, period_s, depth } => {
                if !(*lambda > 0.0 && *period_s > 0.0) {
                    return Err(format!(
                        "diurnal needs lambda/period_s > 0, got {lambda}/{period_s}"
                    ));
                }
                if !(0.0..=1.0).contains(depth) {
                    return Err(format!("diurnal depth must be in [0, 1], got {depth}"));
                }
            }
            ArrivalProcess::Replay { path } => {
                if path.is_empty() {
                    return Err("replay needs a non-empty artifact path".into());
                }
            }
            ArrivalProcess::Closed { clients } => {
                if *clients == 0 {
                    return Err("closed loop needs clients > 0".into());
                }
            }
        }
        Ok(())
    }

    /// Synthesize this cell's arrival trace: `jobs` arrivals, class of
    /// each drawn by `pick` (interleaved with the time draws on the
    /// same RNG, exactly one pick per arrival). Returns `None` for the
    /// variants that are not open-loop traces (`Closed` runs a
    /// client loop in the engine; `Replay` is loaded from its artifact
    /// by the serve layer).
    pub fn synthesize(
        &self,
        jobs: usize,
        rng: &mut Rng,
        mut pick: impl FnMut(&mut Rng) -> usize,
    ) -> Option<Vec<Arrival>> {
        let mut trace = Vec::with_capacity(jobs);
        let mut t = 0.0f64;
        match *self {
            ArrivalProcess::Poisson { lambda } => {
                for id in 0..jobs as u64 {
                    if lambda > 0.0 {
                        t += rng.exp(lambda);
                    }
                    trace.push(Arrival { id, class: pick(rng), t_s: t });
                }
            }
            ArrivalProcess::Mmpp { lambda_lo, lambda_hi, mean_dwell_s } => {
                let mut hi = false;
                let mut next_switch = rng.exp(1.0 / mean_dwell_s);
                for id in 0..jobs as u64 {
                    loop {
                        let lam = if hi { lambda_hi } else { lambda_lo };
                        let dt = rng.exp(lam);
                        if t + dt <= next_switch {
                            t += dt;
                            break;
                        }
                        // The Poisson clock is memoryless: jump to the
                        // state switch and redraw at the new rate.
                        t = next_switch;
                        hi = !hi;
                        next_switch += rng.exp(1.0 / mean_dwell_s);
                    }
                    trace.push(Arrival { id, class: pick(rng), t_s: t });
                }
            }
            ArrivalProcess::Diurnal { lambda, period_s, depth } => {
                // Lewis–Shedler thinning against the envelope rate.
                let l_max = lambda * (1.0 + depth);
                let rate = |t: f64| {
                    lambda
                        * (1.0 + depth * (std::f64::consts::TAU * t / period_s).sin())
                };
                for id in 0..jobs as u64 {
                    loop {
                        t += rng.exp(l_max);
                        if rng.f64() * l_max <= rate(t) {
                            break;
                        }
                    }
                    trace.push(Arrival { id, class: pick(rng), t_s: t });
                }
            }
            ArrivalProcess::Replay { .. } | ArrivalProcess::Closed { .. } => {
                return None;
            }
        }
        Some(trace)
    }

    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = vec![("kind", Json::Str(self.kind().into()))];
        match self {
            ArrivalProcess::Poisson { lambda } => {
                kv.push(("lambda", Json::Num(*lambda)));
            }
            ArrivalProcess::Mmpp { lambda_lo, lambda_hi, mean_dwell_s } => {
                kv.push(("lambda_lo", Json::Num(*lambda_lo)));
                kv.push(("lambda_hi", Json::Num(*lambda_hi)));
                kv.push(("mean_dwell_s", Json::Num(*mean_dwell_s)));
            }
            ArrivalProcess::Diurnal { lambda, period_s, depth } => {
                kv.push(("lambda", Json::Num(*lambda)));
                kv.push(("period_s", Json::Num(*period_s)));
                kv.push(("depth", Json::Num(*depth)));
            }
            ArrivalProcess::Replay { path } => {
                kv.push(("path", Json::Str(path.clone())));
            }
            ArrivalProcess::Closed { clients } => {
                kv.push(("clients", Json::Num(*clients as f64)));
            }
        }
        Json::obj(kv)
    }

    pub fn from_json(v: &Json) -> Result<ArrivalProcess, String> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("arrival process missing \"kind\"")?;
        let num = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("arrival process {kind:?} missing {k:?}"))
        };
        let p = match kind {
            "poisson" => ArrivalProcess::Poisson { lambda: num("lambda")? },
            "mmpp" => ArrivalProcess::Mmpp {
                lambda_lo: num("lambda_lo")?,
                lambda_hi: num("lambda_hi")?,
                mean_dwell_s: num("mean_dwell_s")?,
            },
            "diurnal" => ArrivalProcess::Diurnal {
                lambda: num("lambda")?,
                period_s: num("period_s")?,
                depth: num("depth")?,
            },
            "replay" => ArrivalProcess::Replay {
                path: v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("replay arrival missing \"path\"")?
                    .to_string(),
            },
            "closed" => ArrivalProcess::Closed { clients: num("clients")? as usize },
            other => return Err(format!("unknown arrival process kind {other:?}")),
        };
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::json;

    fn times(p: &ArrivalProcess, jobs: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        p.synthesize(jobs, &mut rng, |r| r.below(2))
            .expect("open-loop trace")
            .iter()
            .map(|a| a.t_s)
            .collect()
    }

    #[test]
    fn synthesis_is_deterministic_and_monotone() {
        let procs = [
            ArrivalProcess::Poisson { lambda: 1000.0 },
            ArrivalProcess::Mmpp {
                lambda_lo: 200.0,
                lambda_hi: 5000.0,
                mean_dwell_s: 0.01,
            },
            ArrivalProcess::Diurnal { lambda: 1000.0, period_s: 0.1, depth: 0.9 },
        ];
        for p in &procs {
            p.validate().unwrap();
            let a = times(p, 200, 7);
            let b = times(p, 200, 7);
            assert_eq!(a, b, "{}: same seed, same trace", p.kind());
            assert!(
                a.windows(2).all(|w| w[1] >= w[0]),
                "{}: arrival times are nondecreasing",
                p.kind()
            );
            assert_ne!(a, times(p, 200, 8), "{}: seeds decorrelate", p.kind());
        }
    }

    #[test]
    fn poisson_zero_lambda_floods_at_t0() {
        let t = times(&ArrivalProcess::Poisson { lambda: 0.0 }, 16, 7);
        assert!(t.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mmpp_is_burstier_than_poisson_at_equal_mean_load() {
        // Squared coefficient of variation of inter-arrival gaps:
        // exactly 1 for Poisson in expectation, > 1 for a 2-state MMPP
        // with well-separated rates. Use the empirical Poisson value as
        // the baseline so the test is about the process, not the RNG.
        let cv2 = |t: &[f64]| {
            let gaps: Vec<f64> = t.windows(2).map(|w| w[1] - w[0]).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>()
                / gaps.len() as f64;
            var / (m * m)
        };
        let poisson = times(&ArrivalProcess::Poisson { lambda: 1000.0 }, 2000, 23);
        let mmpp = times(
            &ArrivalProcess::Mmpp {
                lambda_lo: 100.0,
                lambda_hi: 10_000.0,
                mean_dwell_s: 0.05,
            },
            2000,
            23,
        );
        assert!(
            cv2(&mmpp) > 2.0 * cv2(&poisson),
            "mmpp cv2 {} vs poisson cv2 {}",
            cv2(&mmpp),
            cv2(&poisson)
        );
    }

    #[test]
    fn diurnal_modulates_arrival_density() {
        // With depth near 1, the half-periods where sin > 0 must hold
        // clearly more arrivals than the half-periods where sin < 0.
        let period = 0.1;
        let t = times(
            &ArrivalProcess::Diurnal { lambda: 2000.0, period_s: period, depth: 0.95 },
            4000,
            7,
        );
        let mut peak = 0usize;
        let mut trough = 0usize;
        for &x in &t {
            let phase = (x / period).fract();
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn validation_rejects_degenerate_parameters() {
        assert!(ArrivalProcess::Mmpp {
            lambda_lo: 0.0,
            lambda_hi: 1.0,
            mean_dwell_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Diurnal { lambda: 1.0, period_s: 1.0, depth: 1.5 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Replay { path: String::new() }.validate().is_err());
        assert!(ArrivalProcess::Closed { clients: 0 }.validate().is_err());
        assert!(ArrivalProcess::Poisson { lambda: 0.0 }.validate().is_ok());
    }

    #[test]
    fn json_roundtrip_every_variant() {
        let procs = [
            ArrivalProcess::Poisson { lambda: 1234.5 },
            ArrivalProcess::Mmpp {
                lambda_lo: 10.0,
                lambda_hi: 900.0,
                mean_dwell_s: 0.25,
            },
            ArrivalProcess::Diurnal { lambda: 55.0, period_s: 2.0, depth: 0.4 },
            ArrivalProcess::Replay { path: "BENCH_serve.json".into() },
            ArrivalProcess::Closed { clients: 8 },
        ];
        for p in &procs {
            let back = ArrivalProcess::from_json(
                &json::parse(&p.to_json().pretty()).unwrap(),
            )
            .unwrap();
            assert_eq!(&back, p);
        }
    }
}
