//! Paper-reproduction reports: one function per figure/table of the
//! evaluation (§10). Each returns rendered text; the `revel` CLI and
//! the bench harnesses are thin wrappers around these.
//!
//! Every figure *declares* its workload runs as [`harness::SweepPoint`]s
//! and renders from the harness's results: the points dispatch across
//! the worker pool and memoize in the process-wide cache, so `report
//! all` simulates each distinct (kernel, n, features, goal, fabric)
//! combination exactly once — in parallel — and the rendered text is
//! identical to the old serial path (outcomes are deterministic).

use std::sync::Arc;

use crate::analysis::{kernels, streams};
use crate::baselines::{self, cpu, taskpar, CpuKind};
use crate::coordinator;
use crate::compiler::FabricSpec;
use crate::harness::{self, SweepOutcome, SweepPoint};
use crate::isa::Capability;
use crate::model;
use crate::sim::Bucket;
use crate::util::geomean;
use crate::util::stats::{cdf, cdf_at, fx, Table};
use crate::workloads::{self, Features, Goal};

/// Run a figure's declared points (parallel + cached); reports keep the
/// old panic-on-failure contract.
fn sweep(points: &[SweepPoint]) -> Vec<Arc<SweepOutcome>> {
    harness::run_all(points).expect("workload must verify")
}

fn pt(kernel: &str, n: usize, feats: Features, goal: Goal) -> SweepPoint {
    SweepPoint::new(kernel, n, feats, goal)
}

/// The (kernel, size) rows of Fig 16/17 and the headline: each kernel
/// at its smallest and largest paper size.
fn small_large_rows() -> Vec<(&'static str, usize, usize)> {
    let mut rows = Vec::new();
    for k in workloads::NAMES {
        let sizes = workloads::sizes(k);
        for (si, &n) in [sizes[0], *sizes.last().unwrap()].iter().enumerate() {
            rows.push((k, n, si));
        }
    }
    rows
}

/// Fig 1: percent of peak performance of CPU and DSP per kernel.
pub fn fig1() -> String {
    let mut t = Table::new(&["kernel", "CPU %peak", "DSP %peak"]);
    for k in workloads::NAMES {
        let n = workloads::sizes(k)[2];
        t.row(vec![
            k.into(),
            format!("{:.0}%", 100.0 * cpu::utilization(CpuKind::Ooo, k, n)),
            format!("{:.0}%", 100.0 * cpu::utilization(CpuKind::Dsp, k, n)),
        ]);
    }
    format!("Fig 1: percent peak performance (calibrated model)\n{}", t.render())
}

/// The serving cluster's stage points (shared with the sweep cache so
/// `report all` prewarms them alongside the other figures).
fn pipeline_points() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for c in &coordinator::CLASSES {
        for s in &c.stages {
            v.push(pt(s.kernel, s.n, Features::ALL, Goal::Latency));
        }
    }
    v
}

/// Fig 4: the 5G receiver pipeline as a served workload — per-class
/// stage latencies, and throughput scaling of the serving cluster on
/// one deterministic flood trace.
pub fn pipeline() -> String {
    use crate::coordinator::{CellSpec, ClusterSpec};
    let rs = sweep(&pipeline_points());
    let mut t = Table::new(&["class", "stage", "kernel", "n", "cycles", "us"]);
    let mut i = 0;
    for c in &coordinator::CLASSES {
        for (si, s) in c.stages.iter().enumerate() {
            t.row(vec![
                if si == 0 { c.name.into() } else { String::new() },
                coordinator::STAGE_ROLES[si].into(),
                s.kernel.into(),
                s.n.to_string(),
                rs[i].cycles.to_string(),
                format!("{:.2}", rs[i].us()),
            ]);
            i += 1;
        }
    }
    let mut sc = Table::new(&[
        "units", "subframes/s", "p50 us", "p99 us", "util", "stolen", "dropped",
    ]);
    for units in [1usize, 2, 4, 8] {
        let spec = ClusterSpec::new(7).cell(CellSpec::new(units).jobs(64));
        let r = coordinator::serve(&spec).expect("serve must run");
        let cell = &r.cells[0];
        let util = cell.per_unit.iter().map(|u| u.utilization).sum::<f64>()
            / cell.per_unit.len().max(1) as f64;
        let stolen: usize = cell.per_unit.iter().map(|u| u.stolen).sum();
        sc.row(vec![
            units.to_string(),
            format!("{:.0}", r.throughput_per_s),
            format!("{:.1}", r.slo.latency_us.p50),
            format!("{:.1}", r.slo.latency_us.p99),
            format!("{:.0}%", 100.0 * util),
            stolen.to_string(),
            r.dropped.to_string(),
        ]);
    }
    format!(
        "Fig 4: 5G receiver pipeline on a REVEL serving cluster\n{}\n\
         cluster scaling, same 64-subframe flood trace (seed 7):\n{}",
        t.render(),
        sc.render()
    )
}

/// Fig 7: FGOP prevalence — one row per kernel and size.
pub fn fig7() -> String {
    let mut t = Table::new(&[
        "kernel", "n", "med dist", "d<=1000", "ordered", "inductive", "imbal",
    ]);
    let all: Vec<&str> =
        kernels::DSP.iter().chain(kernels::POLYBENCH.iter()).copied().collect();
    for k in all {
        for n in [16usize, 32, 128] {
            // Keep the biggest SVD/QR traces tractable.
            if n == 128 && matches!(k, "svd") {
                continue;
            }
            let s = kernels::trace(k, n);
            let pts = cdf(&s.dep_distances.iter().map(|&d| d as f64).collect::<Vec<_>>());
            t.row(vec![
                k.into(),
                n.to_string(),
                s.median_distance().to_string(),
                if s.dep_distances.is_empty() {
                    "-".into()
                } else {
                    format!("{:.0}%", 100.0 * cdf_at(&pts, 1000.0))
                },
                format!("{:.0}%", 100.0 * s.ordered_fraction),
                format!("{:.0}%", 100.0 * s.inductive_fraction),
                format!("{:.1}x", s.region_imbalance.min(999.0)),
            ]);
        }
    }
    format!("Fig 7: FGOP prevalence (DSP suite, then PolyBench)\n{}", t.render())
}

/// Fig 8: task-parallel blocked Cholesky speedup over sequential.
pub fn fig8() -> String {
    let mut t = Table::new(&["n", "2 thr", "4 thr", "8 thr"]);
    for n in [64usize, 128, 256, 512, 1024] {
        let mut row = vec![n.to_string()];
        for thr in [2usize, 4, 8] {
            row.push(format!("{:.2}x", taskpar::speedup(n, 32, thr, 2)));
        }
        t.row(row);
    }
    format!(
        "Fig 8: task-parallel Cholesky speedup vs 1 thread (host threads)\n{}",
        t.render()
    )
}

fn fig16_points() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for (k, n, _) in small_large_rows() {
        v.push(pt(k, n, Features::ALL, Goal::Latency));
        v.push(pt(k, n, Features::NONE, Goal::Latency));
    }
    v
}

/// Fig 16: latency-optimized speedups over the DSP (small and large).
pub fn fig16() -> String {
    let rs = sweep(&fig16_points());
    let mut t = Table::new(&[
        "kernel", "n", "DSP us", "REVEL us", "no-FGOP us", "speedup", "FGOP gain",
    ]);
    let mut small = Vec::new();
    let mut large = Vec::new();
    for (i, (k, n, si)) in small_large_rows().into_iter().enumerate() {
        let dsp = cpu::dsp_time_us(k, n);
        let rv = rs[2 * i].us();
        let nf = rs[2 * i + 1].us();
        let sp = dsp / rv;
        if si == 0 {
            small.push(sp);
        } else {
            large.push(sp);
        }
        t.row(vec![
            k.into(),
            n.to_string(),
            format!("{dsp:.2}"),
            format!("{rv:.2}"),
            format!("{nf:.2}"),
            fx(sp),
            fx(nf / rv),
        ]);
    }
    format!(
        "Fig 16: latency-optimized speedup vs DSP\n{}\ngeomean: small {} large {}\n",
        t.render(),
        fx(geomean(&small)),
        fx(geomean(&large)),
    )
}

fn fig17_points() -> Vec<SweepPoint> {
    small_large_rows()
        .into_iter()
        .map(|(k, n, _)| pt(k, n, Features::ALL, Goal::Throughput))
        .collect()
}

/// Fig 17: throughput-optimized speedups (8 problems / makespan).
pub fn fig17() -> String {
    let rs = sweep(&fig17_points());
    let mut t = Table::new(&["kernel", "n", "DSP us", "REVEL us", "speedup"]);
    let mut sp_all = Vec::new();
    for (i, (k, n, _)) in small_large_rows().into_iter().enumerate() {
        let dsp = cpu::throughput_time_us(CpuKind::Dsp, k, n);
        let rv = rs[i].us();
        let sp = dsp / rv;
        sp_all.push(sp);
        t.row(vec![
            k.into(),
            n.to_string(),
            format!("{dsp:.2}"),
            format!("{rv:.2}"),
            fx(sp),
        ]);
    }
    format!(
        "Fig 17: throughput-optimized speedup vs DSP (8 problems)\n{}\ngeomean {}\n",
        t.render(),
        fx(geomean(&sp_all)),
    )
}

/// Fig 18/19 rows: every kernel at its middle size, throughput then
/// latency goal (tagged as the paper does).
fn mid_rows(tags: [&'static str; 2]) -> Vec<(&'static str, usize, &'static str, Goal)> {
    let mut rows = Vec::new();
    for k in workloads::NAMES {
        let n = workloads::sizes(k)[1];
        for (tag, goal) in [(tags[0], Goal::Throughput), (tags[1], Goal::Latency)] {
            rows.push((k, n, tag, goal));
        }
    }
    rows
}

fn fig18_points() -> Vec<SweepPoint> {
    mid_rows(["thr", "multi"])
        .into_iter()
        .map(|(k, n, _, goal)| pt(k, n, Features::ALL, goal))
        .collect()
}

/// Fig 18: cycle-level breakdown per workload.
pub fn fig18() -> String {
    let rs = sweep(&fig18_points());
    let hdr: Vec<String> = std::iter::once("kernel/goal".to_string())
        .chain(
            crate::sim::BUCKETS
                .iter()
                .filter(|&&b| b != Bucket::Done)
                .map(|b| b.name().to_string()),
        )
        .collect();
    let mut t = Table::new(&hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for (i, (k, _, tag, _)) in mid_rows(["thr", "multi"]).into_iter().enumerate() {
        let mut row = vec![format!("{k}-{tag}")];
        for (_, f) in rs[i].stats.fractions() {
            row.push(format!("{:.0}%", 100.0 * f));
        }
        t.row(row);
    }
    format!("Fig 18: cycle-level breakdown (fractions of active lane-cycles)\n{}", t.render())
}

fn fig19_points() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for (k, n, _, goal) in mid_rows(["", "-lat"]) {
        v.push(pt(k, n, Features::NONE, goal));
        for (_, f) in Features::ladder() {
            v.push(pt(k, n, f, goal));
        }
    }
    v
}

/// Fig 19: incremental speedup of the five mechanism versions.
pub fn fig19() -> String {
    let rs = sweep(&fig19_points());
    let names: Vec<&str> = Features::ladder().iter().map(|(n, _)| *n).collect();
    let hdr: Vec<&str> =
        std::iter::once("kernel").chain(names.iter().copied()).collect();
    let mut t = Table::new(&hdr);
    let per_row = 1 + Features::ladder().len();
    for (i, (k, _, tag, _)) in mid_rows(["", "-lat"]).into_iter().enumerate() {
        let mut row = vec![format!("{k}{tag}")];
        let base = rs[per_row * i].cycles;
        for j in 0..Features::ladder().len() {
            let c = rs[per_row * i + 1 + j].cycles;
            row.push(fx(base as f64 / c as f64));
        }
        t.row(row);
    }
    format!("Fig 19: cumulative speedup per mechanism (vs base version)\n{}", t.render())
}

/// Fig 20 configuration: the kernels and temporal-region sizes swept.
const FIG20_KERNELS: [&str; 4] = ["svd", "qr", "cholesky", "solver"];
const FIG20_SIZES: [(usize, usize); 4] = [(1, 1), (2, 1), (2, 2), (4, 2)];

fn fig20_points() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for k in FIG20_KERNELS {
        v.push(pt(k, 12, Features::ALL, Goal::Latency)); // default-fabric base
    }
    for (w, h) in FIG20_SIZES {
        for k in FIG20_KERNELS {
            v.push(pt(k, 12, Features::ALL, Goal::Latency).with_fabric(w, h));
        }
    }
    v
}

/// Fig 20: temporal-region size sensitivity (performance + area).
pub fn fig20() -> String {
    let rs = sweep(&fig20_points());
    let mut t = Table::new(&["region", "fabric mm^2", "svd", "qr", "cholesky", "solver"]);
    let base: Vec<u64> =
        (0..FIG20_KERNELS.len()).map(|i| rs[i].cycles).collect();
    for (si, (w, h)) in FIG20_SIZES.into_iter().enumerate() {
        let mut row = vec![
            format!("{w}x{h}"),
            format!("{:.3}", model::fabric_area_mm2(&FabricSpec::revel(w, h))),
        ];
        for i in 0..FIG20_KERNELS.len() {
            let c = rs[FIG20_KERNELS.len() * (1 + si) + i].cycles;
            row.push(format!("{:.2}", base[i] as f64 / c as f64));
        }
        t.row(row);
    }
    format!(
        "Fig 20: temporal-region sensitivity (perf relative to 2x1 default)\n{}",
        t.render()
    )
}

/// Figs 21 + 22: stream length and control overhead per capability.
pub fn fig21_22() -> String {
    let caps = streams::capabilities();
    let hdr: Vec<String> = std::iter::once("kernel".to_string())
        .chain(caps.iter().map(|c| c.to_string()))
        .collect();
    let mut t21 = Table::new(&hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut t22 = Table::new(&hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut t22b = Table::new(&["kernel", "RI", "RI no-reuse"]);
    for k in workloads::NAMES {
        let n = *workloads::sizes(k).last().unwrap();
        let ks = streams::kernel_streams(k, n);
        let mut r21 = vec![k.to_string()];
        let mut r22 = vec![k.to_string()];
        for &c in &caps {
            r21.push(format!("{:.1}", streams::avg_stream_length(&ks, c)));
            r22.push(format!("{:.2}", streams::insts_per_iter(&ks, c, true)));
        }
        t21.row(r21);
        t22.row(r22);
        t22b.row(vec![
            k.into(),
            format!("{:.2}", streams::insts_per_iter(&ks, Capability::RI, true)),
            format!("{:.2}", streams::insts_per_iter(&ks, Capability::RI, false)),
        ]);
    }
    format!(
        "Fig 21: average stream length per capability\n{}\n\
         Fig 22: control insts per inner iteration\n{}\n\
         Fig 22 (stacked): stream-reuse disabled\n{}",
        t21.render(),
        t22.render(),
        t22b.render()
    )
}

fn table6_points() -> Vec<SweepPoint> {
    workloads::NAMES
        .iter()
        .map(|&k| pt(k, workloads::sizes(k)[1], Features::ALL, Goal::Latency))
        .collect()
}

/// Table 6 (top): area/power breakdown; (bottom): ASIC overheads.
pub fn table6() -> String {
    let rs = sweep(&table6_points());
    let mut t = Table::new(&["block", "area mm^2", "power mW"]);
    for b in model::LANE_BLOCKS {
        t.row(vec![
            b.name.into(),
            format!("{:.2}", b.area_mm2),
            format!("{:.2}", b.power_mw),
        ]);
    }
    t.row(vec![
        "1 vector lane".into(),
        format!("{:.2}", model::lane_area_mm2()),
        format!("{:.2}", model::lane_power_mw()),
    ]);
    t.row(vec![
        model::CTRL_CORE.name.into(),
        format!("{:.2}", model::CTRL_CORE.area_mm2),
        format!("{:.2}", model::CTRL_CORE.power_mw),
    ]);
    t.row(vec![
        "REVEL (8 lanes)".into(),
        format!("{:.2}", model::revel_area_mm2()),
        format!("{:.1}", model::revel_power_mw()),
    ]);
    let mut b = Table::new(&["kernel", "power ovhd", "ASIC cycles", "REVEL cycles"]);
    for (i, k) in workloads::NAMES.iter().enumerate() {
        let n = workloads::sizes(k)[1];
        b.row(vec![
            (*k).into(),
            format!("{:.1}x", model::power_overhead(k)),
            baselines::asic_cycles(k, n).to_string(),
            rs[i].cycles.to_string(),
        ]);
    }
    let mean_p: f64 = workloads::NAMES
        .iter()
        .map(|k| model::power_overhead(k))
        .sum::<f64>()
        / workloads::NAMES.len() as f64;
    format!(
        "Table 6: area and power breakdown (28nm)\n{}\n\
         Table 6 (bottom): overheads vs ideal iso-perf ASIC\n{}\n\
         mean power overhead {:.1}x; combined-ASIC area ratio {:.2}\n",
        t.render(),
        b.render(),
        mean_p,
        model::revel_area_mm2() / model::asic_area_mm2(workloads::NAMES.len()),
    )
}

fn headline_points() -> Vec<SweepPoint> {
    small_large_rows()
        .into_iter()
        .map(|(k, n, _)| pt(k, n, Features::ALL, Goal::Latency))
        .collect()
}

/// Headline numbers (abstract / Q2 / Q7).
pub fn headline() -> String {
    let rs = sweep(&headline_points());
    let mut lat_small = Vec::new();
    let mut lat_large = Vec::new();
    let mut vs_ooo = Vec::new();
    let mut max_sp: f64 = 0.0;
    for (i, (k, n, si)) in small_large_rows().into_iter().enumerate() {
        let rv = rs[i].us();
        let sp = cpu::dsp_time_us(k, n) / rv;
        max_sp = max_sp.max(sp);
        if si == 0 {
            lat_small.push(sp);
        } else {
            lat_large.push(sp);
        }
        vs_ooo.push(cpu::ooo_time_us(k, n) / rv);
    }
    let gm_small = geomean(&lat_small);
    let gm_large = geomean(&lat_large);
    let gm_ooo = geomean(&vs_ooo);
    let (het, all_ded, all_temp) = model::q9_homogeneous_alternatives();
    format!(
        "Headline reproduction\n\
         - latency speedup vs DSP: geomean small {} / large {} (paper: 10x/17x), max {} (paper: up to 37x)\n\
         - speedup vs OOO+MKL: geomean {} (paper: 9.6x)\n\
         - perf/mm^2 vs DSP: {} (paper: 8.3x)\n\
         - perf/mm^2 vs OOO: {} (paper: 1308x)\n\
         - Q9 fabric-area alternatives: het {:.3} mm^2, all-dedicated {:.2}x, all-temporal {:.2}x (paper: 2.75x / 2.5x)\n",
        fx(gm_small),
        fx(gm_large),
        fx(max_sp),
        fx(gm_ooo),
        fx(model::perf_per_mm2_advantage(geomean(&[gm_small, gm_large]), model::DSP_AREA_MM2)),
        fx(model::perf_per_mm2_advantage(gm_ooo, model::OOO_AREA_MM2)),
        het,
        all_ded / het,
        all_temp / het,
    )
}

/// Every sweep point any report needs — `all()` prewarms the cache with
/// one maximally parallel pass before rendering.
pub fn all_points() -> Vec<SweepPoint> {
    let mut v = Vec::new();
    v.extend(pipeline_points());
    v.extend(fig16_points());
    v.extend(fig17_points());
    v.extend(fig18_points());
    v.extend(fig19_points());
    v.extend(fig20_points());
    v.extend(table6_points());
    v.extend(headline_points());
    v
}

/// Every report, in paper order.
pub fn all() -> String {
    sweep(&all_points()); // one parallel pass over every distinct point
    [
        fig1(),
        pipeline(),
        fig7(),
        fig8(),
        fig16(),
        fig17(),
        fig18(),
        fig19(),
        fig20(),
        fig21_22(),
        table6(),
        headline(),
    ]
    .join("\n")
}

/// Render one tile-DAG run ([`coordinator::run_dag`]) as the `revel
/// dag` console summary: headline counters plus the per-unit occupancy
/// table.
pub fn dag_summary(
    cfg: &coordinator::DagConfig,
    run: &coordinator::DagRun,
) -> String {
    let mut out = format!(
        "dag[{}]: n={} tile={} over {} units: {} tasks, makespan {} cycles \
         ({:.2} us), critical path {} cycles, {:.2}x vs serial compute\n\
         interconnect: {} handoffs / {} words, bus busy {} wait {} cycles; \
         residency: {} hits, {} evictions; factor digest {:016x}\n",
        cfg.kernel.name(),
        cfg.n,
        cfg.tile,
        cfg.units,
        run.tasks,
        run.makespan_cycles,
        model::cycles_to_us(run.makespan_cycles),
        run.critical_path_cycles,
        run.total_compute_cycles as f64 / run.makespan_cycles.max(1) as f64,
        run.handoffs,
        run.handoff_words,
        run.bus_busy_cycles,
        run.bus_wait_cycles,
        run.resident_hits,
        run.evictions,
        run.factor_digest,
    );
    if run.unit_crashes > 0 || run.tasks_rescheduled > 0 {
        out.push_str(&format!(
            "faults: {} unit crashes, {} tasks re-executed on survivors \
             (digest pinned to the fault-free run)\n",
            run.unit_crashes, run.tasks_rescheduled,
        ));
    }
    let mut t = Table::new(&["unit", "tasks", "busy cycles", "occupancy"]);
    for u in &run.per_unit {
        t.row(vec![
            u.unit.to_string(),
            u.tasks.to_string(),
            u.busy_cycles.to_string(),
            format!(
                "{:.1}%",
                100.0 * u.busy_cycles as f64 / run.makespan_cycles.max(1) as f64
            ),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_reports_render() {
        for s in [fig1(), fig21_22(), table6()] {
            assert!(s.len() > 100);
        }
    }

    #[test]
    fn dag_summary_renders() {
        let cfg = coordinator::DagConfig {
            kernel: crate::taskgraph::DagKernel::Cholesky,
            n: 16,
            tile: 8,
            units: 2,
        };
        let run = coordinator::run_dag(&cfg).unwrap();
        let s = dag_summary(&cfg, &run);
        assert!(s.contains("dag[cholesky]"), "{s}");
        assert!(s.contains("occupancy"), "{s}");
        assert!(!s.contains("faults:"), "clean runs omit the fault line: {s}");
        // A faulted run surfaces its counters in the summary.
        let plan = coordinator::DagFaultPlan::parse("crash=0@1").unwrap();
        let faulted = coordinator::run_dag_faulted(&cfg, &plan).unwrap();
        let s = dag_summary(&cfg, &faulted);
        assert!(s.contains("faults: 1 unit crashes"), "{s}");
        assert_eq!(faulted.factor_digest, run.factor_digest);
    }

    #[test]
    fn fig16_shape_holds() {
        // The paper's core claim: REVEL beats the DSP on every FGOP
        // kernel, most at the large sizes.
        let out = fig16();
        assert!(out.contains("geomean"));
    }

    #[test]
    fn declared_points_cover_every_figure_row() {
        // 2 points per (kernel, small/large) row in fig16; one each in
        // fig17/headline; fig19 = base + 5 ladder steps per row.
        let rows = small_large_rows().len();
        assert_eq!(fig16_points().len(), 2 * rows);
        assert_eq!(fig17_points().len(), rows);
        assert_eq!(headline_points().len(), rows);
        assert_eq!(fig19_points().len(), 6 * 2 * workloads::NAMES.len());
        assert_eq!(
            fig20_points().len(),
            FIG20_KERNELS.len() * (1 + FIG20_SIZES.len())
        );
        assert_eq!(pipeline_points().len(), 4 * coordinator::CLASSES.len());
        assert!(!all_points().is_empty());
    }
}
