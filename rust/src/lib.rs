//! REVEL reproduction library root.
//!
//! Layering (see `docs/ARCHITECTURE.md` for the full map): `isa`/
//! `dataflow` define the architecture's IR, `vsc` is the typed
//! kernel-builder API workloads program it through, `compiler` places
//! it on the fabric, `sim` executes it cycle-accurately, `workloads`
//! express the paper's kernel suite (plus LU), `baselines`/`model`
//! hold the comparison and
//! area/power models, `analysis` the FGOP characterization, `harness`
//! the parallel sweep engine behind `report`, `runtime` the PJRT golden
//! path, `taskgraph` the tiled task-graph factorizations scheduled
//! across persistent units (`revel dag`), and `coordinator` the 5G
//! serving cluster (`revel serve`).
//! `docs/PAPER_MAP.md` maps every paper figure/table to the module and
//! `revel report` subcommand that reproduces it.

// The simulator favors explicit index arithmetic that mirrors the
// hardware's address/length registers; keep clippy focused on real
// defects rather than restyling it.
#![allow(
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::should_implement_trait
)]

pub mod analysis;
pub mod baselines;
pub mod compiler;
pub mod coordinator;
pub mod dataflow;
pub mod harness;
pub mod isa;
pub mod model;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod taskgraph;
pub mod util;
pub mod vsc;
pub mod workloads;
