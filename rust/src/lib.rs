//! REVEL reproduction library root.
pub mod compiler;
pub mod coordinator;
pub mod dataflow;
pub mod isa;
pub mod model;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod analysis;
pub mod baselines;
pub mod workloads;
