//! `revel` — command-line driver for the REVEL reproduction.
//!
//! Usage:
//!   revel report <fig1|fig7|fig8|fig16|fig17|fig18|fig19|fig20|fig21|fig22|table6|headline|all>
//!   revel run <kernel> <n> [--throughput] [--features base|+inductive|+fine-grain|+hetero|all]
//!   revel trace <kernel> <n>
//!   revel sweep [--out FILE] [--workers N] [kernel ...]
//!   revel pipeline [jobs] [workers]
//!   revel list

use revel::analysis::kernels;
use revel::harness;
use revel::model;
use revel::report;
use revel::workloads::{self, Features, Goal};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("report") => {
            let what = args.get(1).map(|s| s.as_str()).unwrap_or("headline");
            let out = match what {
                "fig1" => report::fig1(),
                "fig7" => report::fig7(),
                "fig8" => report::fig8(),
                "fig16" => report::fig16(),
                "fig17" => report::fig17(),
                "fig18" => report::fig18(),
                "fig19" => report::fig19(),
                "fig20" => report::fig20(),
                "fig21" | "fig22" | "fig21_22" => report::fig21_22(),
                "table6" => report::table6(),
                "headline" => report::headline(),
                "all" => report::all(),
                other => {
                    eprintln!("unknown report {other}");
                    std::process::exit(2);
                }
            };
            println!("{out}");
        }
        Some("run") => {
            let kernel = args.get(1).expect("kernel name").clone();
            let n: usize = args.get(2).expect("size").parse().expect("size");
            let goal = if args.iter().any(|a| a == "--throughput") {
                Goal::Throughput
            } else {
                Goal::Latency
            };
            let feats = match args
                .iter()
                .position(|a| a == "--features")
                .and_then(|i| args.get(i + 1))
                .map(|s| s.as_str())
            {
                None | Some("all") => Features::ALL,
                Some(name) => {
                    Features::ladder()
                        .iter()
                        .find(|(n, _)| *n == name)
                        .unwrap_or_else(|| panic!("unknown feature set {name}"))
                        .1
                }
            };
            let r = workloads::prepare(&kernel, n, feats, goal)
                .expect("prepare")
                .execute()
                .expect("run+verify");
            println!(
                "{kernel} n={n} {goal:?}: {} cycles ({:.2} us @1.25GHz), \
                 {} problems, max |err| {:.2e}, {:.2} flops/cycle",
                r.cycles,
                model::cycles_to_us(r.cycles),
                r.problems,
                r.max_err,
                r.flops_per_cycle()
            );
            for (b, f) in r.stats.fractions() {
                if f > 0.005 {
                    println!("  {:>12}: {:5.1}%", b.name(), 100.0 * f);
                }
            }
        }
        Some("trace") => {
            let kernel = args.get(1).expect("kernel").clone();
            let n: usize = args.get(2).expect("size").parse().expect("size");
            let s = kernels::trace(&kernel, n);
            println!(
                "{kernel} n={n}: {} inter-region deps (median distance {}), \
                 ordered {:.0}%, inductive {:.0}%, imbalance {:.1}x over {} regions",
                s.dep_distances.len(),
                s.median_distance(),
                100.0 * s.ordered_fraction,
                100.0 * s.inductive_fraction,
                s.region_imbalance,
                s.regions
            );
        }
        Some("sweep") => {
            let out_path = args
                .iter()
                .position(|a| a == "--out")
                .and_then(|i| args.get(i + 1))
                .cloned()
                .unwrap_or_else(|| "BENCH_sweep.json".to_string());
            let workers = args
                .iter()
                .position(|a| a == "--workers")
                .and_then(|i| args.get(i + 1))
                .and_then(|s| s.parse::<usize>().ok());
            // Positional args (excluding flag values) select kernels.
            let mut skip = std::collections::HashSet::new();
            for flag in ["--out", "--workers"] {
                if let Some(i) = args.iter().position(|a| a == flag) {
                    skip.insert(i);
                    skip.insert(i + 1);
                }
            }
            let kernels: Vec<&str> = args
                .iter()
                .enumerate()
                .skip(1)
                .filter(|(i, a)| !skip.contains(i) && !a.starts_with("--"))
                .map(|(_, a)| a.as_str())
                .collect();
            let kernels: Vec<&str> = if kernels.is_empty() {
                workloads::NAMES.to_vec()
            } else {
                for k in &kernels {
                    assert!(
                        workloads::NAMES.contains(k),
                        "unknown kernel {k}; see `revel list`"
                    );
                }
                kernels
            };
            let points = harness::full_sweep_points(&kernels);
            let n_workers = workers.unwrap_or_else(harness::pool::default_workers);
            eprintln!(
                "sweeping {} points over {} workers...",
                points.len(),
                n_workers
            );
            let t0 = std::time::Instant::now();
            let opts = harness::Options { workers, use_cache: true };
            let outcomes =
                harness::run_all_opts(&points, &opts).expect("sweep must verify");
            let wall_s = t0.elapsed().as_secs_f64();
            let mut t = revel::util::stats::Table::new(&[
                "kernel", "n", "goal", "cycles", "us", "flops/cyc",
            ]);
            for o in &outcomes {
                t.row(vec![
                    o.point.kernel.clone(),
                    o.point.n.to_string(),
                    format!("{:?}", o.point.goal),
                    o.cycles.to_string(),
                    format!("{:.2}", o.us()),
                    format!("{:.2}", o.flops_per_cycle()),
                ]);
            }
            println!("{}", t.render());
            println!(
                "{} points in {wall_s:.2}s wall ({:.1} points/s) over {n_workers} workers",
                outcomes.len(),
                outcomes.len() as f64 / wall_s.max(1e-9),
            );
            harness::write_artifact(&out_path, &outcomes, wall_s, n_workers)
                .expect("write sweep artifact");
            println!("wrote {out_path}");
        }
        Some("pipeline") => {
            let jobs: usize =
                args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
            let workers: usize =
                args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
            match revel::coordinator::golden_check() {
                Ok(()) => println!("PJRT golden check: ok"),
                Err(e) => println!("PJRT golden check skipped: {e}"),
            }
            let s = revel::coordinator::serve(jobs, workers, 0.0, 42);
            println!(
                "{} jobs / {} workers: {:.2} s wall ({:.1} jobs/s), sim latency p50 {:.1} us p99 {:.1} us",
                s.jobs,
                workers,
                s.wall_s,
                s.jobs_per_s,
                s.sim_latency_p50_us,
                s.sim_latency_p99_us
            );
        }
        Some("list") => {
            for k in workloads::NAMES {
                println!("{k}: sizes {:?}", workloads::sizes(k));
            }
        }
        _ => {
            eprintln!(
                "usage: revel <report|run|trace|sweep|pipeline|list> ...\n\
                   revel report all\n\
                   revel run cholesky 16 [--throughput] [--features base]\n\
                   revel trace qr 32\n\
                   revel sweep --out BENCH_sweep.json [--workers 8] [cholesky solver ...]"
            );
            std::process::exit(2);
        }
    }
}
