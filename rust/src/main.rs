//! `revel` — command-line driver for the REVEL reproduction.
//!
//! ```text
//! revel report <fig1|pipeline|fig7|fig8|fig16|...|table6|headline|all>
//! revel run <kernel> <n> [--throughput] [--features base|+inductive|...|all]
//! revel trace <kernel> <n>
//! revel place [kernel ...] [--strategy greedy|negotiated] [--n N] [--report]
//! revel sweep [--out FILE] [--workers N] [kernel ...]
//! revel sweep-diff <BASELINE.json> <CURRENT.json> [--tolerance PCT]
//! revel serve [--engine replay|cosim] [--cells N] [--units U] [--jobs M]
//!             [--seed S] [--shards K] [--scaling 1,2,8]
//!             [--handover-frac F] [--fronthaul-us T] [--reroute]
//!             [--arrival poisson|mmpp|diurnal|replay|closed]
//!             [--lambda R] [--lambda-lo R] [--lambda-hi R] [--dwell-s T]
//!             [--period-s T] [--depth D] [--trace FILE] [--clients C]
//!             [--queue-cap Q] [--admit-cap A] [--slo-deadline-us D]
//!             [--faults SPEC] [--workers W] [--out FILE]
//! revel dag [--kernel cholesky|lu] [--n N] [--tile B] [--units U]
//!           [--faults SPEC] [--out BENCH_dag.json]
//! revel pipeline [jobs] [units]
//! revel list
//! ```

use revel::analysis::kernels;
use revel::compiler::PlaceStrategy;
use revel::coordinator::{
    ArrivalProcess, CellSpec, ClusterSpec, DagFaultPlan, EngineKind, FaultPlan,
    ServeReport,
};
use revel::harness;
use revel::model;
use revel::report;
use revel::workloads::{self, Features, Goal};

/// Render one serve report to stdout (shared by `serve` and the
/// `pipeline` alias).
fn print_serve(report: &ServeReport, wall_s: f64) {
    let units: usize = report.cells.iter().map(|c| c.units).sum();
    println!(
        "serve[{}]: {} cells / {} units, {} jobs (seed {}): {} completed, \
         {} dropped, {} failed, {} deadline-shed",
        report.engine.name(),
        report.cells.len(),
        units,
        report.jobs,
        report.seed,
        report.completed,
        report.dropped,
        report.failed,
        report.deadline_shed
    );
    if report.handoffs > 0 {
        println!(
            "  shared interconnect: {} handoffs, {:.1} us spent waiting \
             (contention replay cannot see)",
            report.handoffs,
            report.bus_wait_s * 1e6
        );
    }
    if let Some(fh) = report.fronthaul_us {
        println!(
            "  fronthaul ({fh:.1} us/hop): {} handovers, {} shed re-routes{}",
            report.migrations,
            report.reroutes,
            if report.reroute { "" } else { " (reroute off)" }
        );
    }
    if report.crash_kills + report.retries + report.link_dropped + report.link_delayed
        > 0
        || report.faults.is_some()
    {
        println!(
            "  faults [{}]: {} crash-killed stages, {} retries, \
             {} fronthaul msgs dropped, {} delayed",
            report.faults.as_deref().unwrap_or("none"),
            report.crash_kills,
            report.retries,
            report.link_dropped,
            report.link_delayed
        );
    }
    println!(
        "  virtual makespan {:.3} ms -> {:.0} subframes/s @ {} GHz",
        report.makespan_s * 1e3,
        report.throughput_per_s,
        model::FREQ_GHZ
    );
    println!(
        "  latency p50/p95/p99 {:.1}/{:.1}/{:.1} us (queue p99 {:.1} us)",
        report.slo.latency_us.p50,
        report.slo.latency_us.p95,
        report.slo.latency_us.p99,
        report.slo.queue_us.p99
    );
    for (i, c) in report.cells.iter().enumerate() {
        let jobs: Vec<usize> = c.per_unit.iter().map(|u| u.jobs).collect();
        let stolen: usize = c.per_unit.iter().map(|u| u.stolen).sum();
        println!(
            "  cell {i} [{}]: {} jobs -> {} completed, makespan {:.3} ms, \
             p99 {:.1} us, per-unit {jobs:?} ({stolen} stolen)",
            c.arrival.kind(),
            c.jobs,
            c.completed,
            c.makespan_s * 1e3,
            c.slo.latency_us.p99
        );
    }
    println!(
        "  batching: {} distinct stage sims amortized over {} stage executions",
        report.batching.distinct_points, report.batching.stage_runs
    );
    if !report.stage_errors.is_empty() {
        println!("  degraded stages: {:?}", report.stage_errors);
    }
    if !report.strong_scaling.0.is_empty() {
        println!("  strong scaling (host wall; identical results per row):");
        for row in &report.strong_scaling.0 {
            println!("    shards {:>2}: {:.2} s", row.shards, row.wall_s);
        }
    }
    println!("  host wall {wall_s:.2} s");
}

fn main() {
    // Environment handling is CLI-only: the library's SimConfig::default
    // is deterministic, and the CLI opts back into REVEL_MAX_CYCLES here.
    if std::env::var_os("REVEL_MAX_CYCLES").is_some() {
        revel::sim::set_max_cycles_budget(revel::sim::SimConfig::from_env().max_cycles);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("report") => {
            let what = args.get(1).map(|s| s.as_str()).unwrap_or("headline");
            let out = match what {
                "fig1" => report::fig1(),
                "fig7" => report::fig7(),
                "fig8" => report::fig8(),
                "fig16" => report::fig16(),
                "fig17" => report::fig17(),
                "fig18" => report::fig18(),
                "fig19" => report::fig19(),
                "fig20" => report::fig20(),
                "fig21" | "fig22" | "fig21_22" => report::fig21_22(),
                "pipeline" | "fig4" => report::pipeline(),
                "table6" => report::table6(),
                "headline" => report::headline(),
                "all" => report::all(),
                other => {
                    eprintln!("unknown report {other}");
                    std::process::exit(2);
                }
            };
            println!("{out}");
        }
        Some("run") => {
            let kernel = args.get(1).expect("kernel name").clone();
            let n: usize = args.get(2).expect("size").parse().expect("size");
            let goal = if args.iter().any(|a| a == "--throughput") {
                Goal::Throughput
            } else {
                Goal::Latency
            };
            let feats = match args
                .iter()
                .position(|a| a == "--features")
                .and_then(|i| args.get(i + 1))
                .map(|s| s.as_str())
            {
                None | Some("all") => Features::ALL,
                Some(name) => {
                    Features::ladder()
                        .iter()
                        .find(|(n, _)| *n == name)
                        .unwrap_or_else(|| panic!("unknown feature set {name}"))
                        .1
                }
            };
            let r = workloads::prepare(&kernel, n, feats, goal)
                .expect("prepare")
                .execute()
                .expect("run+verify");
            println!(
                "{kernel} n={n} {goal:?}: {} cycles ({:.2} us @1.25GHz), \
                 {} problems, max |err| {:.2e}, {:.2} flops/cycle",
                r.cycles,
                model::cycles_to_us(r.cycles),
                r.problems,
                r.max_err,
                r.flops_per_cycle()
            );
            for (b, f) in r.stats.fractions() {
                if f > 0.005 {
                    println!("  {:>12}: {:5.1}%", b.name(), 100.0 * f);
                }
            }
        }
        Some("trace") => {
            let kernel = args.get(1).expect("kernel").clone();
            let n: usize = args.get(2).expect("size").parse().expect("size");
            let s = kernels::trace(&kernel, n);
            println!(
                "{kernel} n={n}: {} inter-region deps (median distance {}), \
                 ordered {:.0}%, inductive {:.0}%, imbalance {:.1}x over {} regions",
                s.dep_distances.len(),
                s.median_distance(),
                100.0 * s.ordered_fraction,
                100.0 * s.inductive_fraction,
                s.region_imbalance,
                s.regions
            );
        }
        Some("place") => {
            // Placement inspector: compile each kernel's configs under a
            // chosen strategy and report the physical placement metrics
            // the sweep artifact records (wirelength, overuse, tiles).
            let flag = |name: &str| {
                args.iter().position(|a| a == name).and_then(|i| args.get(i + 1))
            };
            let strategy = match flag("--strategy").map(|s| s.as_str()) {
                None | Some("negotiated") => PlaceStrategy::Negotiated,
                Some("greedy") => PlaceStrategy::Greedy,
                Some(other) => {
                    eprintln!(
                        "unknown strategy {other} (expected greedy|negotiated)"
                    );
                    std::process::exit(2);
                }
            };
            let n_override: Option<usize> =
                flag("--n").and_then(|s| s.parse().ok());
            let with_report = args.iter().any(|a| a == "--report");
            let mut skip = std::collections::HashSet::new();
            for f in ["--strategy", "--n"] {
                if let Some(i) = args.iter().position(|a| a == f) {
                    skip.insert(i);
                    skip.insert(i + 1);
                }
            }
            let kernels: Vec<&str> = args
                .iter()
                .enumerate()
                .skip(1)
                .filter(|(i, a)| !skip.contains(i) && !a.starts_with("--"))
                .map(|(_, a)| a.as_str())
                .collect();
            let kernels: Vec<&str> = if kernels.is_empty() {
                workloads::NAMES.to_vec()
            } else {
                for k in &kernels {
                    assert!(
                        workloads::NAMES.contains(k),
                        "unknown kernel {k}; see `revel list`"
                    );
                }
                kernels
            };
            workloads::set_place_strategy(Some(strategy));
            let mut t = revel::util::stats::Table::new(&[
                "kernel", "n", "strategy", "winner", "wirelength", "overuse",
                "tiles", "nets", "rounds",
            ]);
            let mut reports = Vec::new();
            for k in &kernels {
                let n = n_override.unwrap_or_else(|| workloads::sizes(k)[0]);
                let prep = workloads::prepare(k, n, Features::ALL, Goal::Latency)
                    .unwrap_or_else(|e| panic!("prepare {k} n={n}: {e}"));
                let cfg = workloads::peek_config(k, Features::ALL)
                    .expect("prepare caches the compiled config");
                let p = &cfg.placement;
                t.row(vec![
                    k.to_string(),
                    n.to_string(),
                    format!("{strategy:?}").to_lowercase(),
                    if p.negotiated { "negotiated" } else { "greedy" }.into(),
                    p.wirelength.to_string(),
                    p.overuse.to_string(),
                    p.tiles_used.to_string(),
                    p.nets.to_string(),
                    p.rounds.to_string(),
                ]);
                if with_report {
                    let mut lines = vec![format!(
                        "{k}: {} dfgs, {} temporal insts",
                        p.timing.len(),
                        p.temporal_insts
                    )];
                    for (i, dt) in p.timing.iter().enumerate() {
                        lines.push(format!(
                            "  dfg {i}: ii {}, depth {}, {} ({} insts)",
                            dt.ii,
                            dt.depth,
                            if dt.temporal { "temporal" } else { "dedicated" },
                            dt.insts
                        ));
                    }
                    let chk =
                        revel::vsc::check_program(&prep.prog, &prep.machine.cfg);
                    for tr in &chk.traffic {
                        lines.push(format!(
                            "  traffic [{}]: {} loads, {} words, {} line \
                             fetches ({} hits, {} missed-reuse), {} store lines",
                            tr.config,
                            tr.loads,
                            tr.accesses,
                            tr.fetches,
                            tr.hits,
                            tr.missed_reuse,
                            tr.store_lines
                        ));
                    }
                    reports.push(lines.join("\n"));
                }
            }
            workloads::set_place_strategy(None);
            println!("{}", t.render());
            for r in &reports {
                println!("{r}");
            }
        }
        Some("sweep") => {
            let out_path = args
                .iter()
                .position(|a| a == "--out")
                .and_then(|i| args.get(i + 1))
                .cloned()
                .unwrap_or_else(|| "BENCH_sweep.json".to_string());
            let workers = args
                .iter()
                .position(|a| a == "--workers")
                .and_then(|i| args.get(i + 1))
                .and_then(|s| s.parse::<usize>().ok());
            // Positional args (excluding flag values) select kernels.
            let mut skip = std::collections::HashSet::new();
            for flag in ["--out", "--workers"] {
                if let Some(i) = args.iter().position(|a| a == flag) {
                    skip.insert(i);
                    skip.insert(i + 1);
                }
            }
            let kernels: Vec<&str> = args
                .iter()
                .enumerate()
                .skip(1)
                .filter(|(i, a)| !skip.contains(i) && !a.starts_with("--"))
                .map(|(_, a)| a.as_str())
                .collect();
            let kernels: Vec<&str> = if kernels.is_empty() {
                workloads::NAMES.to_vec()
            } else {
                for k in &kernels {
                    assert!(
                        workloads::NAMES.contains(k),
                        "unknown kernel {k}; see `revel list`"
                    );
                }
                kernels
            };
            let points = harness::full_sweep_points(&kernels);
            let n_workers = workers.unwrap_or_else(harness::pool::default_workers);
            eprintln!(
                "sweeping {} points over {} workers...",
                points.len(),
                n_workers
            );
            let t0 = std::time::Instant::now();
            let opts = harness::Options { workers, use_cache: true };
            let outcomes =
                harness::run_all_opts(&points, &opts).expect("sweep must verify");
            let wall_s = t0.elapsed().as_secs_f64();
            let mut t = revel::util::stats::Table::new(&[
                "kernel", "n", "goal", "cycles", "us", "flops/cyc",
            ]);
            for o in &outcomes {
                t.row(vec![
                    o.point.kernel.clone(),
                    o.point.n.to_string(),
                    format!("{:?}", o.point.goal),
                    o.cycles.to_string(),
                    format!("{:.2}", o.us()),
                    format!("{:.2}", o.flops_per_cycle()),
                ]);
            }
            println!("{}", t.render());
            println!(
                "{} points in {wall_s:.2}s wall ({:.1} points/s) over {n_workers} workers",
                outcomes.len(),
                outcomes.len() as f64 / wall_s.max(1e-9),
            );
            harness::write_artifact(&out_path, &outcomes, wall_s, n_workers)
                .expect("write sweep artifact");
            println!("wrote {out_path}");
        }
        Some("sweep-diff") => {
            // Perf-neutrality gate: compare an archived BENCH_sweep.json
            // against the current run; any matched point slower than
            // baseline (beyond --tolerance percent) fails the command.
            let base_path = args.get(1).expect("baseline BENCH_sweep.json path");
            let cur_path = args.get(2).expect("current BENCH_sweep.json path");
            let tol: f64 = args
                .iter()
                .position(|a| a == "--tolerance")
                .and_then(|i| args.get(i + 1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(0.0);
            let read = |path: &str| -> Vec<harness::SweepOutcome> {
                let text = std::fs::read_to_string(path)
                    .unwrap_or_else(|e| panic!("read {path}: {e}"));
                harness::read_artifact(&text)
                    .unwrap_or_else(|e| panic!("parse {path}: {e}"))
            };
            let base = read(base_path);
            let cur = read(cur_path);
            let d = harness::diff_outcomes(&base, &cur, tol);
            let mut t = revel::util::stats::Table::new(&[
                "point", "baseline", "current", "delta",
            ]);
            for row in d.regressions.iter().chain(d.improvements.iter()) {
                t.row(vec![
                    row.key.clone(),
                    row.base.to_string(),
                    row.cur.to_string(),
                    format!(
                        "{:+.2}%",
                        100.0 * (row.cur as f64 - row.base as f64)
                            / row.base as f64
                    ),
                ]);
            }
            println!("{}", t.render());
            println!(
                "{} unchanged, {} improved, {} regressed, {} added, {} missing \
                 (tolerance {tol}%)",
                d.unchanged,
                d.improvements.len(),
                d.regressions.len(),
                d.added.len(),
                d.missing.len()
            );
            // Host wall-time before/after (informational only — the
            // exit code below depends exclusively on simulated cycles).
            // diff_outcomes owns the point matching; this just renders.
            if !d.walls.is_empty() {
                let mut wt = revel::util::stats::Table::new(&[
                    "point",
                    "base ms",
                    "cur ms",
                    "speedup",
                ]);
                for w in &d.walls {
                    wt.row(vec![
                        w.key.clone(),
                        format!("{:.2}", w.base_ns / 1e6),
                        format!("{:.2}", w.cur_ns / 1e6),
                        format!("{:.2}x", w.base_ns / w.cur_ns.max(1.0)),
                    ]);
                }
                println!("host wall time per point (informational):");
                println!("{}", wt.render());
                let base_ns: f64 = d.walls.iter().map(|w| w.base_ns).sum();
                let cur_ns: f64 = d.walls.iter().map(|w| w.cur_ns).sum();
                println!(
                    "host wall total over {} matched points: {:.1} ms -> {:.1} ms ({:.2}x)",
                    d.walls.len(),
                    base_ns / 1e6,
                    cur_ns / 1e6,
                    base_ns / cur_ns.max(1.0),
                );
            } else {
                println!(
                    "host wall time: baseline artifact carries no per-point wall \
                     data (pre-v2 schema); skipping the informational table"
                );
            }
            // Placement and reuse deltas (informational only — wirelength
            // and overuse feed no gate; simulated cycles above decide).
            if !d.places.is_empty() {
                let mut pt = revel::util::stats::Table::new(&[
                    "point",
                    "wirelength",
                    "overuse",
                    "line fetches",
                    "missed-reuse",
                ]);
                for p in &d.places {
                    pt.row(vec![
                        p.key.clone(),
                        format!("{} -> {}", p.base_wl, p.cur_wl),
                        format!("{} -> {}", p.base_ou, p.cur_ou),
                        format!("{} -> {}", p.base_fetches, p.cur_fetches),
                        format!("{} -> {}", p.base_missed, p.cur_missed),
                    ]);
                }
                println!("placement / reuse deltas (informational):");
                println!("{}", pt.render());
            } else {
                println!(
                    "placement data: no matched point carries placement \
                     metrics (pre-v3 schema baseline); skipping the \
                     informational table"
                );
            }
            // Lost coverage fails too: if baseline points stop matching
            // (kernel removed, point identity changed), the gate would
            // otherwise "pass" while comparing nothing.
            if !d.missing.is_empty() {
                eprintln!(
                    "FAIL: {} baseline point(s) missing from the current run: {:?}",
                    d.missing.len(),
                    d.missing
                );
                std::process::exit(1);
            }
            if !d.regressions.is_empty() {
                eprintln!(
                    "FAIL: {} point(s) regressed beyond {tol}%",
                    d.regressions.len()
                );
                std::process::exit(1);
            }
        }
        Some("serve") => {
            let flag = |name: &str| {
                args.iter().position(|a| a == name).and_then(|i| args.get(i + 1))
            };
            let cells_n: usize =
                flag("--cells").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
            let units: usize =
                flag("--units").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
            let jobs: usize = flag("--jobs").and_then(|s| s.parse().ok()).unwrap_or(200);
            let seed: u64 = flag("--seed").and_then(|s| s.parse().ok()).unwrap_or(7);
            let lambda: f64 =
                flag("--lambda").and_then(|s| s.parse().ok()).unwrap_or(0.0);
            let clients: usize =
                flag("--clients").and_then(|s| s.parse().ok()).unwrap_or(2 * units);
            // --arrival names the per-cell process; the pre-metro
            // --mode open|closed stays as an alias.
            let kind = flag("--arrival")
                .map(|s| s.as_str())
                .or_else(|| match flag("--mode").map(|s| s.as_str()) {
                    Some("open") => Some("poisson"),
                    other => other,
                });
            let arrival = match kind {
                None | Some("poisson") => ArrivalProcess::Poisson { lambda },
                Some("mmpp") => ArrivalProcess::Mmpp {
                    lambda_lo: flag("--lambda-lo")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(500.0),
                    lambda_hi: flag("--lambda-hi")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(5000.0),
                    mean_dwell_s: flag("--dwell-s")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0.01),
                },
                Some("diurnal") => ArrivalProcess::Diurnal {
                    lambda,
                    period_s: flag("--period-s")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0.05),
                    depth: flag("--depth").and_then(|s| s.parse().ok()).unwrap_or(0.5),
                },
                Some("replay") => ArrivalProcess::Replay {
                    path: flag("--trace").cloned().unwrap_or_else(|| {
                        eprintln!("--arrival replay needs --trace FILE");
                        std::process::exit(2);
                    }),
                },
                Some("closed") => ArrivalProcess::Closed { clients },
                Some(other) => {
                    eprintln!(
                        "unknown arrival process {other} \
                         (expected poisson|mmpp|diurnal|replay|closed)"
                    );
                    std::process::exit(2);
                }
            };
            let engine = match flag("--engine").map(|s| s.as_str()) {
                None | Some("replay") => EngineKind::Replay,
                Some("cosim") => EngineKind::Cosim,
                Some(other) => {
                    eprintln!("unknown engine {other} (expected replay|cosim)");
                    std::process::exit(2);
                }
            };
            let proto = CellSpec::new(units)
                .jobs(jobs)
                .arrival(arrival)
                .queue_cap(flag("--queue-cap").and_then(|s| s.parse().ok()).unwrap_or(8))
                .admit_cap(
                    flag("--admit-cap").and_then(|s| s.parse().ok()).unwrap_or(1024),
                )
                .handover_frac(
                    flag("--handover-frac").and_then(|s| s.parse().ok()).unwrap_or(0.0),
                );
            let faults = flag("--faults").map(|s| {
                FaultPlan::parse(s).unwrap_or_else(|e| {
                    eprintln!("bad --faults spec: {e}");
                    std::process::exit(2);
                })
            });
            let mut spec = ClusterSpec::new(seed)
                .engine(engine)
                .slo_deadline_us(
                    flag("--slo-deadline-us").and_then(|s| s.parse::<f64>().ok()),
                )
                .workers(flag("--workers").and_then(|s| s.parse::<usize>().ok()))
                .fronthaul_us(flag("--fronthaul-us").and_then(|s| s.parse::<f64>().ok()))
                .reroute(args.iter().any(|a| a == "--reroute"))
                .faults(faults)
                .cells(cells_n, proto);
            if let Some(s) = flag("--shards").and_then(|s| s.parse::<usize>().ok()) {
                spec = spec.shards(s);
            }
            // --scaling 1,2,8 re-serves the spec per shard count and
            // records the informational wall-time rows in the artifact.
            let scaling: Vec<usize> = flag("--scaling")
                .map(|s| {
                    s.split(',').filter_map(|t| t.trim().parse::<usize>().ok()).collect()
                })
                .unwrap_or_default();
            let out_path = flag("--out")
                .cloned()
                .unwrap_or_else(|| "BENCH_serve.json".to_string());
            let t0 = std::time::Instant::now();
            let result = if scaling.is_empty() {
                revel::coordinator::serve(&spec)
            } else {
                revel::coordinator::strong_scaling(&spec, &scaling)
            };
            let report = result.unwrap_or_else(|e| {
                eprintln!("serve failed: {e}");
                std::process::exit(1);
            });
            let wall_s = t0.elapsed().as_secs_f64();
            print_serve(&report, wall_s);
            let host_workers =
                spec.workers.unwrap_or_else(harness::pool::default_workers);
            revel::coordinator::write_artifact(
                &out_path,
                &report,
                wall_s,
                host_workers,
                spec.effective_shards(),
            )
            .expect("write serve artifact");
            println!("wrote {out_path}");
        }
        Some("dag") => {
            // Tiled task-graph factorization across persistent units
            // (library: coordinator::run_dag over taskgraph::TileDag).
            use revel::harness::json::Json;
            let flag = |name: &str| {
                args.iter().position(|a| a == name).and_then(|i| args.get(i + 1))
            };
            let kernel = match flag("--kernel").map(|s| s.as_str()) {
                None => revel::taskgraph::DagKernel::Cholesky,
                Some(name) => revel::taskgraph::DagKernel::parse(name)
                    .unwrap_or_else(|| {
                        eprintln!("unknown kernel {name} (expected cholesky|lu)");
                        std::process::exit(2);
                    }),
            };
            let n: usize = flag("--n").and_then(|s| s.parse().ok()).unwrap_or(64);
            let tile: usize =
                flag("--tile").and_then(|s| s.parse().ok()).unwrap_or(16);
            let units: usize =
                flag("--units").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
            let out_path = flag("--out")
                .cloned()
                .unwrap_or_else(|| "BENCH_dag.json".to_string());
            let faults = flag("--faults").map(|s| {
                DagFaultPlan::parse(s).unwrap_or_else(|e| {
                    eprintln!("bad --faults spec: {e}");
                    std::process::exit(2);
                })
            });
            let cfg = revel::coordinator::DagConfig { kernel, n, tile, units };
            let t0 = std::time::Instant::now();
            let run = match &faults {
                Some(plan) => revel::coordinator::run_dag_faulted(&cfg, plan),
                None => revel::coordinator::run_dag(&cfg),
            }
            .unwrap_or_else(|e| {
                eprintln!("dag failed: {e}");
                std::process::exit(1);
            });
            let wall_s = t0.elapsed().as_secs_f64();
            println!("{}", report::dag_summary(&cfg, &run));
            let doc = Json::obj(vec![
                ("schema", Json::Str("revel-bench-dag".into())),
                ("version", Json::Num(1.0)),
                (
                    "config",
                    Json::obj(vec![
                        ("kernel", Json::Str(kernel.name().into())),
                        ("n", Json::Num(n as f64)),
                        ("tile", Json::Num(tile as f64)),
                        ("units", Json::Num(units as f64)),
                        (
                            "faults",
                            match flag("--faults") {
                                Some(s) => Json::Str(s.clone()),
                                None => Json::Null,
                            },
                        ),
                    ]),
                ),
                ("summary", run.to_json()),
                (
                    "host",
                    Json::obj(vec![("wall_s", Json::Num(wall_s))]),
                ),
            ]);
            std::fs::write(&out_path, doc.pretty()).unwrap_or_else(|e| {
                eprintln!("write {out_path}: {e}");
                std::process::exit(1);
            });
            println!("wrote {out_path}");
        }
        Some("pipeline") => {
            // Back-compat alias: a default open-loop serve run plus the
            // PJRT golden cross-check, no artifact.
            let jobs: usize =
                args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
            let units: usize =
                args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
            match revel::coordinator::golden_check() {
                Ok(()) => println!("PJRT golden check: ok"),
                Err(e) => println!("PJRT golden check skipped: {e}"),
            }
            let spec = ClusterSpec::new(7).cell(CellSpec::new(units).jobs(jobs));
            let t0 = std::time::Instant::now();
            let report = revel::coordinator::serve(&spec).unwrap_or_else(|e| {
                eprintln!("pipeline failed: {e}");
                std::process::exit(1);
            });
            print_serve(&report, t0.elapsed().as_secs_f64());
        }
        Some("list") => {
            for k in workloads::NAMES {
                println!("{k}: sizes {:?}", workloads::sizes(k));
            }
        }
        _ => {
            eprintln!(
                "usage: revel <report|run|trace|place|sweep|sweep-diff|serve|dag|pipeline|list> ...\n\
                   revel report all\n\
                   revel run cholesky 16 [--throughput] [--features base]\n\
                   revel trace qr 32\n\
                   revel place [cholesky lu ...] [--strategy greedy|negotiated]\n\
                               [--n N] [--report]\n\
                   revel sweep --out BENCH_sweep.json [--workers 8] [cholesky solver ...]\n\
                   revel sweep-diff baseline.json BENCH_sweep.json [--tolerance 0]\n\
                   revel serve --cells 4 --units 4 --jobs 200 --seed 7\n\
                              [--engine replay|cosim] [--shards K] [--scaling 1,2,8]\n\
                              [--handover-frac F] [--fronthaul-us T] [--reroute]\n\
                              [--arrival poisson|mmpp|diurnal|replay|closed]\n\
                              [--lambda R] [--lambda-lo R] [--lambda-hi R] [--dwell-s T]\n\
                              [--period-s T] [--depth D] [--trace FILE] [--clients C]\n\
                              [--queue-cap 8] [--admit-cap 1024] [--slo-deadline-us D]\n\
                              [--faults 'crash=C.U@D..R; degrade=C.U@M; drop=A..B;\n\
                               delay=A..B@E; p=P; retries=N; backoff=US']\n\
                              [--workers W] [--out BENCH_serve.json]\n\
                   revel dag [--kernel cholesky|lu] [--n 64] [--tile 16] [--units 4]\n\
                             [--faults 'crash=UNIT@CYCLE'] [--out BENCH_dag.json]\n\
                   revel pipeline [jobs] [units]   (golden check + default serve run)"
            );
            std::process::exit(2);
        }
    }
}
