//! Lowering tile tasks onto the typed `vsc` tile-kernel builders.
//!
//! A [`Lowerer`] compiles the per-kernel tile plan **once** (one
//! dataflow configuration per kernel, reused by every task) and then
//! stamps out relocated control programs per task: the same compiled
//! kernel, re-targeted at whichever scratchpad slot regions the
//! scheduler assigned. This is the task-graph face of the paper's
//! "configure once, stream many" economy — reconfiguration cost is
//! paid once per unit, not once per tile.
//!
//! It also measures per-class cycle costs on a scratch machine
//! ([`Lowerer::class_costs`]), which the scheduler uses for
//! critical-path priorities before any unit has run anything.

use std::collections::BTreeMap;

use super::dag::{DagKernel, TileOp};
use crate::isa::{LaneMask, Program};
use crate::sim::SimConfig;
use crate::vsc::{Region, SpadAlloc};
use crate::workloads::{self, cholesky, lu, WlError};

/// The compiled tile plan for one kernel family.
pub enum TilePlans {
    /// Cholesky tile kernels (POTRF / TRSM / SYRK / GEMM share one plan).
    Chol(cholesky::Plan),
    /// LU tile kernels (GETRF / TRSM-col / TRSM-row / GEMM share one plan).
    Lu(lu::Plan),
}

/// Compile-once, relocate-per-task program factory for tile tasks.
pub struct Lowerer {
    kernel: DagKernel,
    b: usize,
    plans: TilePlans,
    mask: LaneMask,
}

impl Lowerer {
    /// Compile the tile plan for `kernel` at tile size `b`.
    pub fn new(kernel: DagKernel, b: usize) -> Result<Self, WlError> {
        let plans = match kernel {
            DagKernel::Cholesky => TilePlans::Chol(cholesky::tile_plan(b)?),
            DagKernel::Lu => TilePlans::Lu(lu::tile_plan(b)?),
        };
        Ok(Self { kernel, b, plans, mask: LaneMask::one(0) })
    }

    /// Tile size the plan was compiled for.
    pub fn b(&self) -> usize {
        self.b
    }

    /// Whether this kernel's tile programs consume the `b`-word
    /// transient scratch region (Cholesky round-trips `inva` through
    /// the scratchpad; LU forwards the reciprocal over XFER).
    pub fn needs_tmp(&self) -> bool {
        self.kernel == DagKernel::Cholesky
    }

    /// Emit the control program for `op` against the assigned slot
    /// regions. `operands` follow [`TileOp::operands`] order; `target`
    /// holds the tile being written; `tmp` is the transient scratch
    /// (ignored by LU programs). Panics if `op` belongs to the other
    /// kernel family — the scheduler only feeds ops from its own DAG.
    pub fn program(
        &self,
        op: &TileOp,
        operands: &[Region],
        target: Region,
        tmp: Region,
    ) -> Program {
        let b = self.b;
        match (&self.plans, op) {
            (TilePlans::Chol(p), TileOp::Potrf { .. }) => {
                cholesky::tile_potrf_program(p, b, target, tmp, self.mask)
            }
            (TilePlans::Chol(p), TileOp::Trsm { .. }) => {
                cholesky::tile_trsm_program(p, b, operands[0], target, tmp, self.mask)
            }
            (TilePlans::Chol(p), TileOp::Syrk { .. }) => {
                cholesky::tile_gemm_program(p, b, operands[0], operands[0], target, self.mask)
            }
            (TilePlans::Chol(p), TileOp::Gemm { .. }) => {
                cholesky::tile_gemm_program(p, b, operands[0], operands[1], target, self.mask)
            }
            (TilePlans::Lu(p), TileOp::Getrf { .. }) => {
                lu::tile_getrf_program(p, b, target, self.mask)
            }
            (TilePlans::Lu(p), TileOp::TrsmCol { .. }) => {
                lu::tile_trsm_col_program(p, b, operands[0], target, self.mask)
            }
            (TilePlans::Lu(p), TileOp::TrsmRow { .. }) => {
                lu::tile_trsm_row_program(p, b, operands[0], target, self.mask)
            }
            (TilePlans::Lu(p), TileOp::LuGemm { .. }) => {
                lu::tile_gemm_program(p, b, operands[0], operands[1], target, self.mask)
            }
            _ => panic!("tile op {op:?} does not belong to kernel {:?}", self.kernel),
        }
    }

    /// Representative ops, one per task class of this kernel.
    fn class_reps(&self) -> Vec<TileOp> {
        match self.kernel {
            DagKernel::Cholesky => vec![
                TileOp::Potrf { k: 0 },
                TileOp::Trsm { i: 1, k: 0 },
                TileOp::Syrk { i: 1, k: 0 },
                TileOp::Gemm { i: 2, j: 1, k: 0 },
            ],
            DagKernel::Lu => vec![
                TileOp::Getrf { k: 0 },
                TileOp::TrsmCol { i: 1, k: 0 },
                TileOp::TrsmRow { k: 0, j: 1 },
                TileOp::LuGemm { i: 2, j: 1, k: 0 },
            ],
        }
    }

    /// Measure each task class once on a scratch single-lane machine
    /// and return `class name -> cycles`. Tile-program cycle counts are
    /// data-independent, so one representative per class suffices; the
    /// scheduler uses these for longest-path-to-sink priorities.
    pub fn class_costs(&self) -> Result<BTreeMap<&'static str, u64>, String> {
        let b = self.b;
        let mut al = SpadAlloc::with_capacity(SimConfig::default().lane_spad_words);
        let bb = (b * b) as i64;
        let s0 = al.region("cost.s0", bb).map_err(|e| e.to_string())?;
        let s1 = al.region("cost.s1", bb).map_err(|e| e.to_string())?;
        let s2 = al.region("cost.s2", bb).map_err(|e| e.to_string())?;
        let tmp = al.region("cost.tmp", b as i64).map_err(|e| e.to_string())?;
        let seed = crate::util::linalg::Mat::spd(b, 0.6);
        let mut costs = BTreeMap::new();
        for op in self.class_reps() {
            let n_ops = op.operands().len();
            let prog = self.program(&op, &[s1, s2][..n_ops], s0, tmp);
            let mut m = workloads::machine(1);
            // Plausible tile data everywhere (values cannot change the
            // cycle count, but keep the arithmetic finite regardless).
            for slot in [s0, s1, s2] {
                for j in 0..b {
                    for i in 0..b {
                        m.lanes[0]
                            .spad
                            .write(slot.addr((j * b + i) as i64), seed[(i, j)]);
                    }
                }
            }
            m.run(prog).map_err(|e| format!("{}: {e}", op.class()))?;
            costs.insert(op.class(), m.now());
        }
        Ok(costs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsc::check_program;

    #[test]
    fn lowered_programs_pass_the_vsc_check_for_both_kernels() {
        for kernel in [DagKernel::Cholesky, DagKernel::Lu] {
            let lw = Lowerer::new(kernel, 8).unwrap();
            let mut al = SpadAlloc::with_capacity(2048);
            let s0 = al.region("t.s0", 64).unwrap();
            let s1 = al.region("t.s1", 64).unwrap();
            let s2 = al.region("t.s2", 64).unwrap();
            let tmp = al.region("t.tmp", 8).unwrap();
            for op in lw.class_reps() {
                let n_ops = op.operands().len();
                let prog = lw.program(&op, &[s1, s2][..n_ops], s0, tmp);
                let rep = check_program(&prog, &SimConfig::default());
                assert!(
                    rep.errors().is_empty(),
                    "{kernel:?} {}:\n{rep}",
                    op.class()
                );
            }
        }
    }

    #[test]
    fn class_costs_cover_every_class_and_are_positive() {
        for (kernel, classes) in [
            (DagKernel::Cholesky, vec!["potrf", "trsm", "syrk", "gemm"]),
            (DagKernel::Lu, vec!["getrf", "trsm_col", "trsm_row", "lu_gemm"]),
        ] {
            let lw = Lowerer::new(kernel, 8).unwrap();
            let costs = lw.class_costs().unwrap();
            for c in classes {
                assert!(costs.get(c).copied().unwrap_or(0) > 0, "{kernel:?} {c}");
            }
        }
    }

    #[test]
    fn wrong_family_op_panics() {
        let lw = Lowerer::new(DagKernel::Cholesky, 8).unwrap();
        let mut al = SpadAlloc::with_capacity(2048);
        let s0 = al.region("t.s0", 64).unwrap();
        let tmp = al.region("t.tmp", 8).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            lw.program(&TileOp::Getrf { k: 0 }, &[], s0, tmp)
        }));
        assert!(r.is_err());
    }
}
