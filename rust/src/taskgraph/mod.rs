//! `taskgraph` — tiled task-graph factorizations over persistent-state
//! units.
//!
//! The paper's single-unit story ends at one kernel occupying one
//! REVEL unit. This subsystem scales the two panel factorizations
//! (Cholesky, LU without pivoting) *across* units by decomposing an
//! `n x n` problem into a DAG of `b x b` tile tasks — the classic
//! POTRF/TRSM/SYRK/GEMM (resp. GETRF/TRSM-col/TRSM-row/GEMM)
//! tile-algorithm shape — and scheduling the DAG over a pool of
//! persistent `sim::Machine` units that keep their scratchpads warm
//! between tasks.
//!
//! Three layers:
//!
//! * [`dag`] — [`TileDag::build`] emits the task list in a
//!   deterministic topological id order, with two edge families:
//!   **operand finality** (a task reads only finished tiles) and
//!   **accumulation order** (writers of the same target tile form a
//!   chain in ascending panel index). Together they make the replayed
//!   result schedule-invariant down to the bit.
//! * [`exec`] — host-side replay of each task as the untiled
//!   `util::linalg` loop restricted to the tile's index ranges: the
//!   numerics of record, bit-identical to the untiled reference.
//! * [`lower`] — [`Lowerer`] compiles each kernel's tile plan once and
//!   stamps relocated `vsc` control programs per task for whichever
//!   scratchpad slots the scheduler assigned; also measures per-class
//!   cycle costs for critical-path priorities.
//!
//! The DAG-aware scheduler itself lives in
//! [`crate::coordinator::cosim`] (`run_dag`), next to the calendar
//! engine it shares with the serving co-simulator; `revel dag` is the
//! CLI entry point and `BENCH_dag.json` the artifact.

pub mod dag;
pub mod exec;
pub mod lower;

pub use dag::{DagKernel, Task, TileDag, TileOp};
pub use lower::{Lowerer, TilePlans};
