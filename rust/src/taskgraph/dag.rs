//! Tile-DAG builder: decompose an `n x n` Cholesky or LU factorization
//! into POTRF/TRSM/SYRK/GEMM-class tile tasks over a block size `b`
//! (Buttari–Langou–Kurzak–Dongarra, arXiv:0709.1272), with explicit
//! dependence edges.
//!
//! Edges encode two obligations at once:
//!
//! * **operand finality** — a task reads only tiles whose producing
//!   tasks have retired (panel factorizations before the updates that
//!   consume them);
//! * **accumulation order** — tasks that update the *same* target tile
//!   are chained in ascending panel index `K`, so every matrix element
//!   receives its subtraction sequence in exactly the order the untiled
//!   reference loop applies it. This chain is what makes the tiled
//!   replay ([`super::exec`]) bit-identical to `util::linalg` for
//!   *every* dependence-respecting schedule.
//!
//! Task ids are assigned in a deterministic topological order (panel
//! rounds ascending), so iterating tasks by id is always a valid
//! execution order.

use std::collections::BTreeMap;

/// Which factorization a DAG decomposes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DagKernel {
    /// Symmetric positive-definite Cholesky (`A = L L^T`).
    Cholesky,
    /// Doolittle LU without pivoting (`A = L U`, unit-diagonal L).
    Lu,
}

impl DagKernel {
    /// Parse a CLI kernel name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cholesky" => Some(DagKernel::Cholesky),
            "lu" => Some(DagKernel::Lu),
            _ => None,
        }
    }

    /// The workload-registry name (also the interconnect model key for
    /// [`crate::model::handoff_words`]).
    pub fn name(self) -> &'static str {
        match self {
            DagKernel::Cholesky => "cholesky",
            DagKernel::Lu => "lu",
        }
    }
}

/// One tile task. Tile coordinates index `b x b` blocks: tile `(i, j)`
/// covers rows `i*b..(i+1)*b` and columns `j*b..(j+1)*b`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TileOp {
    /// Cholesky: factor diagonal tile `(k, k)` in place.
    Potrf {
        /// Panel index.
        k: usize,
    },
    /// Cholesky: scale panel tile `(i, k)` by the factored `(k, k)`.
    Trsm {
        /// Target tile row.
        i: usize,
        /// Panel index.
        k: usize,
    },
    /// Cholesky: symmetric update of diagonal tile `(i, i)` from panel
    /// `k` (billed as a full square; see
    /// [`crate::workloads::cholesky::tile_gemm_program`]).
    Syrk {
        /// Target tile row (and column).
        i: usize,
        /// Panel index.
        k: usize,
    },
    /// Cholesky: update tile `(i, j)` from panel `k` (`k < j < i`).
    Gemm {
        /// Target tile row.
        i: usize,
        /// Target tile column.
        j: usize,
        /// Panel index.
        k: usize,
    },
    /// LU: factor diagonal tile `(k, k)` in place.
    Getrf {
        /// Panel index.
        k: usize,
    },
    /// LU: scale column-panel tile `(i, k)` (`i > k`).
    TrsmCol {
        /// Target tile row.
        i: usize,
        /// Panel index.
        k: usize,
    },
    /// LU: eliminate inside row-panel tile `(k, j)` (`j > k`).
    TrsmRow {
        /// Panel index.
        k: usize,
        /// Target tile column.
        j: usize,
    },
    /// LU: update tile `(i, j)` from panel `k` (`i > k`, `j > k`).
    LuGemm {
        /// Target tile row.
        i: usize,
        /// Target tile column.
        j: usize,
        /// Panel index.
        k: usize,
    },
}

impl TileOp {
    /// Task-class name (cost-model and reporting key).
    pub fn class(&self) -> &'static str {
        match self {
            TileOp::Potrf { .. } => "potrf",
            TileOp::Trsm { .. } => "trsm",
            TileOp::Syrk { .. } => "syrk",
            TileOp::Gemm { .. } => "gemm",
            TileOp::Getrf { .. } => "getrf",
            TileOp::TrsmCol { .. } => "trsm_col",
            TileOp::TrsmRow { .. } => "trsm_row",
            TileOp::LuGemm { .. } => "lu_gemm",
        }
    }

    /// The tile this task updates in place (read-modify-write).
    pub fn target(&self) -> (usize, usize) {
        match *self {
            TileOp::Potrf { k } | TileOp::Getrf { k } => (k, k),
            TileOp::Trsm { i, k } | TileOp::TrsmCol { i, k } => (i, k),
            TileOp::Syrk { i, .. } => (i, i),
            TileOp::TrsmRow { k, j } => (k, j),
            TileOp::Gemm { i, j, .. } | TileOp::LuGemm { i, j, .. } => (i, j),
        }
    }

    /// Tiles this task reads besides its target, in the operand order
    /// the lowering ([`super::Lowerer`]) expects.
    pub fn operands(&self) -> Vec<(usize, usize)> {
        match *self {
            TileOp::Potrf { .. } | TileOp::Getrf { .. } => vec![],
            TileOp::Trsm { k, .. }
            | TileOp::TrsmCol { k, .. }
            | TileOp::TrsmRow { k, .. } => vec![(k, k)],
            TileOp::Syrk { i, k } => vec![(i, k)],
            TileOp::Gemm { i, j, k } => vec![(i, k), (j, k)],
            TileOp::LuGemm { i, j, k } => vec![(i, k), (k, j)],
        }
    }
}

/// One node of the tile DAG.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    /// Dense id; ids ascend in a valid topological order.
    pub id: usize,
    /// The tile operation.
    pub op: TileOp,
    /// Ids of tasks that must retire before this one may start.
    pub deps: Vec<usize>,
}

/// A tile task DAG over an `n x n` factorization with `b x b` tiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TileDag {
    /// Which factorization.
    pub kernel: DagKernel,
    /// Matrix dimension.
    pub n: usize,
    /// Tile (block) dimension; `n % b == 0`.
    pub b: usize,
    /// Tasks, id-indexed, in deterministic topological order.
    pub tasks: Vec<Task>,
}

impl TileDag {
    /// Decompose `kernel` at size `n` with tile size `b`.
    pub fn build(kernel: DagKernel, n: usize, b: usize) -> Result<TileDag, String> {
        if n == 0 || b == 0 {
            return Err(format!("degenerate problem: n={n}, tile={b}"));
        }
        if n % b != 0 {
            return Err(format!("tile size {b} does not divide n={n}"));
        }
        let t = n / b;
        let mut tasks: Vec<Task> = Vec::new();
        let mut ids: BTreeMap<TileOp, usize> = BTreeMap::new();
        let mut push = |tasks: &mut Vec<Task>,
                        ids: &mut BTreeMap<TileOp, usize>,
                        op: TileOp,
                        deps: Vec<Option<usize>>| {
            let id = tasks.len();
            let deps: Vec<usize> = deps.into_iter().flatten().collect();
            ids.insert(op, id);
            tasks.push(Task { id, op, deps });
        };
        match kernel {
            DagKernel::Cholesky => {
                for k in 0..t {
                    let prev =
                        |op: TileOp, ids: &BTreeMap<TileOp, usize>| ids.get(&op).copied();
                    let p_dep = if k > 0 {
                        prev(TileOp::Syrk { i: k, k: k - 1 }, &ids)
                    } else {
                        None
                    };
                    push(&mut tasks, &mut ids, TileOp::Potrf { k }, vec![p_dep]);
                    for i in k + 1..t {
                        // Panel tile (i, k)'s prior writer is always
                        // GEMM(i, k, k-1) when k > 0 (k > k-1 and i > k).
                        let chain = if k > 0 {
                            prev(TileOp::Gemm { i, j: k, k: k - 1 }, &ids)
                        } else {
                            None
                        };
                        push(
                            &mut tasks,
                            &mut ids,
                            TileOp::Trsm { i, k },
                            vec![prev(TileOp::Potrf { k }, &ids), chain],
                        );
                    }
                    for i in k + 1..t {
                        let chain = if k > 0 {
                            prev(TileOp::Syrk { i, k: k - 1 }, &ids)
                        } else {
                            None
                        };
                        push(
                            &mut tasks,
                            &mut ids,
                            TileOp::Syrk { i, k },
                            vec![prev(TileOp::Trsm { i, k }, &ids), chain],
                        );
                    }
                    for j in k + 1..t {
                        for i in j + 1..t {
                            let chain = if k > 0 {
                                prev(TileOp::Gemm { i, j, k: k - 1 }, &ids)
                            } else {
                                None
                            };
                            push(
                                &mut tasks,
                                &mut ids,
                                TileOp::Gemm { i, j, k },
                                vec![
                                    prev(TileOp::Trsm { i, k }, &ids),
                                    prev(TileOp::Trsm { i: j, k }, &ids),
                                    chain,
                                ],
                            );
                        }
                    }
                }
            }
            DagKernel::Lu => {
                for k in 0..t {
                    let prev =
                        |op: TileOp, ids: &BTreeMap<TileOp, usize>| ids.get(&op).copied();
                    let chain_of = |i: usize, j: usize, ids: &BTreeMap<TileOp, usize>| {
                        if k > 0 {
                            prev(TileOp::LuGemm { i, j, k: k - 1 }, ids)
                        } else {
                            None
                        }
                    };
                    push(
                        &mut tasks,
                        &mut ids,
                        TileOp::Getrf { k },
                        vec![chain_of(k, k, &ids)],
                    );
                    for i in k + 1..t {
                        push(
                            &mut tasks,
                            &mut ids,
                            TileOp::TrsmCol { i, k },
                            vec![prev(TileOp::Getrf { k }, &ids), chain_of(i, k, &ids)],
                        );
                    }
                    for j in k + 1..t {
                        push(
                            &mut tasks,
                            &mut ids,
                            TileOp::TrsmRow { k, j },
                            vec![prev(TileOp::Getrf { k }, &ids), chain_of(k, j, &ids)],
                        );
                    }
                    for j in k + 1..t {
                        for i in k + 1..t {
                            push(
                                &mut tasks,
                                &mut ids,
                                TileOp::LuGemm { i, j, k },
                                vec![
                                    prev(TileOp::TrsmCol { i, k }, &ids),
                                    prev(TileOp::TrsmRow { k, j }, &ids),
                                    chain_of(i, j, &ids),
                                ],
                            );
                        }
                    }
                }
            }
        }
        Ok(TileDag { kernel, n, b, tasks })
    }

    /// Tiles per side (`n / b`).
    pub fn tiles(&self) -> usize {
        self.n / self.b
    }

    /// Longest path through the DAG under a per-task cost model — the
    /// schedule-independent lower bound the `BENCH_dag.json` artifact
    /// reports next to the achieved makespan.
    pub fn critical_path(&self, cost: impl Fn(&TileOp) -> u64) -> u64 {
        let mut dist = vec![0u64; self.tasks.len()];
        let mut best = 0u64;
        for task in &self.tasks {
            let pred = task.deps.iter().map(|&d| dist[d]).max().unwrap_or(0);
            dist[task.id] = pred + cost(&task.op);
            best = best.max(dist[task.id]);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(dag: &TileDag) -> BTreeMap<&'static str, usize> {
        let mut c = BTreeMap::new();
        for t in &dag.tasks {
            *c.entry(t.op.class()).or_insert(0) += 1;
        }
        c
    }

    #[test]
    fn cholesky_task_counts_match_closed_forms() {
        let dag = TileDag::build(DagKernel::Cholesky, 64, 16).unwrap();
        let t = 4usize;
        let c = counts(&dag);
        assert_eq!(c["potrf"], t);
        assert_eq!(c["trsm"], t * (t - 1) / 2);
        assert_eq!(c["syrk"], t * (t - 1) / 2);
        assert_eq!(c["gemm"], t * (t - 1) * (t - 2) / 6);
        assert_eq!(dag.tasks.len(), c.values().sum::<usize>());
    }

    #[test]
    fn lu_task_counts_match_closed_forms() {
        let dag = TileDag::build(DagKernel::Lu, 64, 16).unwrap();
        let t = 4usize;
        let c = counts(&dag);
        assert_eq!(c["getrf"], t);
        assert_eq!(c["trsm_col"], t * (t - 1) / 2);
        assert_eq!(c["trsm_row"], t * (t - 1) / 2);
        let gemms: usize = (1..t).map(|r| r * r).sum();
        assert_eq!(c["lu_gemm"], gemms);
    }

    #[test]
    fn ids_ascend_in_topological_order() {
        for kernel in [DagKernel::Cholesky, DagKernel::Lu] {
            let dag = TileDag::build(kernel, 48, 8).unwrap();
            for task in &dag.tasks {
                for &d in &task.deps {
                    assert!(d < task.id, "{:?} dep {d} >= id {}", task.op, task.id);
                }
            }
        }
    }

    #[test]
    fn same_target_writers_are_chained() {
        // Any two tasks writing one tile must be ordered by a dependence
        // path — the accumulation-order guarantee behind bit-identity.
        for kernel in [DagKernel::Cholesky, DagKernel::Lu] {
            let dag = TileDag::build(kernel, 48, 8).unwrap();
            let n = dag.tasks.len();
            // reach[i] = set of ancestors, as a bitset over ids.
            let mut reach: Vec<Vec<bool>> = vec![vec![false; n]; n];
            for task in &dag.tasks {
                for &d in &task.deps {
                    reach[task.id][d] = true;
                    let (a, b) = {
                        let (lo, hi) = reach.split_at_mut(task.id);
                        (&mut hi[0], &lo[d])
                    };
                    for (x, y) in a.iter_mut().zip(b.iter()) {
                        *x |= *y;
                    }
                }
            }
            for x in &dag.tasks {
                for y in &dag.tasks {
                    if x.id < y.id && x.op.target() == y.op.target() {
                        assert!(
                            reach[y.id][x.id],
                            "{kernel:?}: writers {:?} and {:?} unordered",
                            x.op,
                            y.op
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_rejected() {
        assert!(TileDag::build(DagKernel::Cholesky, 48, 7).is_err());
        assert!(TileDag::build(DagKernel::Lu, 0, 8).is_err());
        // Single tile is fine: one diagonal factorization, no edges.
        let dag = TileDag::build(DagKernel::Cholesky, 16, 16).unwrap();
        assert_eq!(dag.tasks.len(), 1);
        assert!(dag.tasks[0].deps.is_empty());
    }

    #[test]
    fn critical_path_is_the_panel_chain() {
        // Unit costs: the Cholesky critical path alternates
        // POTRF -> TRSM -> SYRK -> POTRF ... = 3 tasks per panel round
        // except the last (POTRF only).
        let dag = TileDag::build(DagKernel::Cholesky, 64, 16).unwrap();
        assert_eq!(dag.critical_path(|_| 1), 3 * 3 + 1);
    }
}
