//! Host-side tile-task replay — the **numerics of record** for the
//! task-graph subsystem.
//!
//! Each [`TileOp`] replays the untiled `util::linalg` reference loop
//! *restricted* to the task's tile ranges, in the same statement form.
//! Because (a) every matrix element receives its floating-point
//! operations in exactly the untiled order (the DAG's accumulation
//! chains force ascending panel index per target tile, and each task's
//! internal loops ascend), and (b) every operand a task reads is final
//! when the task runs (operand-finality edges), the tiled result is
//! **bit-identical** to [`crate::util::linalg::cholesky`] /
//! [`crate::util::linalg::lu`] under *every* dependence-respecting
//! schedule — the property the digest invariance across `--units`
//! counts pins in CI.
//!
//! The simulated tile kernels supply *timing only*: their point
//! dataflows compute `Rsqrt`/reciprocal approximations that can never
//! bit-match `sqrt`/divide, which is exactly why the record lives here.

use super::dag::{DagKernel, TileDag, TileOp};
use crate::util::linalg::Mat;

/// Apply one tile task to the shared `n x n` matrix, in place.
/// `b` is the tile dimension of the owning [`TileDag`].
pub fn apply(op: &TileOp, b: usize, m: &mut Mat) {
    match *op {
        TileOp::Potrf { k } => {
            let lo = k * b;
            for kk in lo..lo + b {
                let d = m[(kk, kk)].sqrt();
                assert!(d.is_finite() && d > 0.0, "matrix not SPD at pivot {kk}");
                m[(kk, kk)] = d;
                for i in kk + 1..lo + b {
                    m[(i, kk)] /= d;
                }
                for j in kk + 1..lo + b {
                    let ljk = m[(j, kk)];
                    for i in j..lo + b {
                        let v = m[(i, kk)] * ljk;
                        m[(i, j)] -= v;
                    }
                }
            }
        }
        TileOp::Trsm { i, k } => {
            let (rb, cb) = (i * b, k * b);
            for kk in cb..cb + b {
                let d = m[(kk, kk)];
                for r in rb..rb + b {
                    m[(r, kk)] /= d;
                }
                for j in kk + 1..cb + b {
                    let ljk = m[(j, kk)];
                    for r in rb..rb + b {
                        let v = m[(r, kk)] * ljk;
                        m[(r, j)] -= v;
                    }
                }
            }
        }
        TileOp::Syrk { i, k } => {
            let (tb, cb) = (i * b, k * b);
            for kk in cb..cb + b {
                for j in tb..tb + b {
                    let ljk = m[(j, kk)];
                    for r in j..tb + b {
                        let v = m[(r, kk)] * ljk;
                        m[(r, j)] -= v;
                    }
                }
            }
        }
        TileOp::Gemm { i, j, k } => {
            let (rb, jb, cb) = (i * b, j * b, k * b);
            for kk in cb..cb + b {
                for c in jb..jb + b {
                    let ljk = m[(c, kk)];
                    for r in rb..rb + b {
                        let v = m[(r, kk)] * ljk;
                        m[(r, c)] -= v;
                    }
                }
            }
        }
        TileOp::Getrf { k } => {
            let lo = k * b;
            for kk in lo..lo + b {
                let piv = m[(kk, kk)];
                assert!(piv.abs() > 1e-300, "zero pivot at {kk}");
                for i in kk + 1..lo + b {
                    m[(i, kk)] /= piv;
                }
                for j in kk + 1..lo + b {
                    let akj = m[(kk, j)];
                    for i in kk + 1..lo + b {
                        let l = m[(i, kk)];
                        m[(i, j)] -= l * akj;
                    }
                }
            }
        }
        TileOp::TrsmCol { i, k } => {
            let (rb, cb) = (i * b, k * b);
            for kk in cb..cb + b {
                let piv = m[(kk, kk)];
                for r in rb..rb + b {
                    m[(r, kk)] /= piv;
                }
                for j in kk + 1..cb + b {
                    let akj = m[(kk, j)];
                    for r in rb..rb + b {
                        let l = m[(r, kk)];
                        m[(r, j)] -= l * akj;
                    }
                }
            }
        }
        TileOp::TrsmRow { k, j } => {
            let (cb, jb) = (k * b, j * b);
            for kk in cb..cb + b {
                for c in jb..jb + b {
                    let akj = m[(kk, c)];
                    for i in kk + 1..cb + b {
                        let l = m[(i, kk)];
                        m[(i, c)] -= l * akj;
                    }
                }
            }
        }
        TileOp::LuGemm { i, j, k } => {
            let (rb, jb, cb) = (i * b, j * b, k * b);
            for kk in cb..cb + b {
                for c in jb..jb + b {
                    let akj = m[(kk, c)];
                    for r in rb..rb + b {
                        let l = m[(r, kk)];
                        m[(r, c)] -= l * akj;
                    }
                }
            }
        }
    }
}

/// Post-factorization cleanup, matching the untiled reference exactly:
/// Cholesky zeroes the strict upper triangle; LU leaves `L\U` packed.
pub fn finalize(kernel: DagKernel, m: &mut Mat) {
    if kernel == DagKernel::Cholesky {
        let n = m.rows;
        for i in 0..n {
            for j in i + 1..n {
                m[(i, j)] = 0.0;
            }
        }
    }
}

/// Replay the whole DAG in id order (a valid topological order) and
/// finalize — the oracle the scheduler's factor digest must match.
pub fn replay(dag: &TileDag, a: &Mat) -> Mat {
    let mut m = a.clone();
    for task in &dag.tasks {
        apply(&task.op, dag.b, &mut m);
    }
    finalize(dag.kernel, &mut m);
    m
}

/// FNV-1a digest over the factor's f64 bit patterns in row-major order.
/// Schedule-independent because the replay itself is; `BENCH_dag.json`
/// pins it across `--units` counts.
pub fn digest(m: &Mat) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in &m.data {
        for byte in x.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::{cholesky as chol_ref, lu as lu_ref};

    fn assert_bit_identical(got: &Mat, want: &Mat, ctx: &str) {
        assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{ctx}");
        for i in 0..got.rows {
            for j in 0..got.cols {
                assert_eq!(
                    got[(i, j)].to_bits(),
                    want[(i, j)].to_bits(),
                    "{ctx}: [{i}][{j}] got {} want {}",
                    got[(i, j)],
                    want[(i, j)],
                );
            }
        }
    }

    #[test]
    fn tiled_cholesky_is_bit_identical_to_untiled() {
        for n in [16usize, 48, 64] {
            for b in [8usize, 16] {
                let a = Mat::spd(n, 1.0);
                let want = chol_ref(&a);
                let dag = TileDag::build(DagKernel::Cholesky, n, b).unwrap();
                let got = replay(&dag, &a);
                assert_bit_identical(&got, &want, &format!("cholesky n={n} b={b}"));
            }
        }
    }

    #[test]
    fn tiled_lu_is_bit_identical_to_untiled() {
        for n in [16usize, 48, 64] {
            for b in [8usize, 16] {
                let a = Mat::spd(n, 0.7);
                let want = lu_ref(&a);
                let dag = TileDag::build(DagKernel::Lu, n, b).unwrap();
                let got = replay(&dag, &a);
                assert_bit_identical(&got, &want, &format!("lu n={n} b={b}"));
            }
        }
    }

    #[test]
    fn any_dependence_respecting_order_gives_identical_bits() {
        // Greedy LIFO list schedule (deliberately different from id
        // order): the digest must not move — the accumulation chains
        // are doing their job.
        for (kernel, seed) in [(DagKernel::Cholesky, 0.3), (DagKernel::Lu, 0.9)] {
            let (n, b) = (48usize, 8usize);
            let a = Mat::spd(n, seed);
            let dag = TileDag::build(kernel, n, b).unwrap();
            let want = replay(&dag, &a);

            let mut indeg: Vec<usize> =
                dag.tasks.iter().map(|t| t.deps.len()).collect();
            let mut dependents: Vec<Vec<usize>> = vec![vec![]; dag.tasks.len()];
            for t in &dag.tasks {
                for &d in &t.deps {
                    dependents[d].push(t.id);
                }
            }
            let mut ready: Vec<usize> = dag
                .tasks
                .iter()
                .filter(|t| t.deps.is_empty())
                .map(|t| t.id)
                .collect();
            let mut m = a.clone();
            let mut done = 0usize;
            while let Some(id) = ready.pop() {
                apply(&dag.tasks[id].op, b, &mut m);
                done += 1;
                for &s in &dependents[id] {
                    indeg[s] -= 1;
                    if indeg[s] == 0 {
                        ready.push(s);
                    }
                }
            }
            assert_eq!(done, dag.tasks.len(), "schedule covered every task");
            finalize(kernel, &mut m);
            assert_eq!(digest(&m), digest(&want), "{kernel:?}: digest moved");
        }
    }

    #[test]
    fn digest_distinguishes_different_factors() {
        let a = Mat::spd(16, 1.0);
        let c = chol_ref(&a);
        let l = lu_ref(&a);
        assert_ne!(digest(&c), digest(&l));
        assert_eq!(digest(&c), digest(&c.clone()));
    }
}
