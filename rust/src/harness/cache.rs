//! Memoized sweep-result cache. Workload execution is deterministic in
//! the sweep point (kernel, n, features, goal, fabric), so every report
//! and bench shares one process-wide cache: `report all` renders eleven
//! figures from a single pass over the distinct points. Tests use
//! private [`SweepCache`] instances to stay isolated.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{SweepOutcome, SweepPoint};

/// Cache key: the sweep point in hashable form.
pub type Key = (String, usize, u8, u8, Option<(usize, usize)>);

pub fn key(p: &SweepPoint) -> Key {
    (
        p.kernel.clone(),
        p.n,
        p.feature_bits(),
        match p.goal {
            crate::workloads::Goal::Latency => 0,
            crate::workloads::Goal::Throughput => 1,
        },
        p.fabric,
    )
}

/// A memo table keyed on sweep points, with hit/miss accounting.
#[derive(Default)]
pub struct SweepCache {
    map: Mutex<HashMap<Key, Arc<SweepOutcome>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SweepCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up a point, counting a hit or miss.
    pub fn get(&self, k: &Key) -> Option<Arc<SweepOutcome>> {
        let hit = self.map.lock().unwrap().get(k).cloned();
        match &hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    /// Look up without touching the counters.
    pub fn peek(&self, k: &Key) -> Option<Arc<SweepOutcome>> {
        self.map.lock().unwrap().get(k).cloned()
    }

    pub fn insert(&self, k: Key, v: Arc<SweepOutcome>) {
        self.map.lock().unwrap().insert(k, v);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    /// (hits, misses) recorded by [`get`](Self::get).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

/// The process-wide cache shared by reports, the CLI, and benches.
pub fn global() -> &'static SweepCache {
    static GLOBAL: OnceLock<SweepCache> = OnceLock::new();
    GLOBAL.get_or_init(SweepCache::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{Features, Goal};

    #[test]
    fn keys_distinguish_every_point_dimension() {
        let base = SweepPoint::new("solver", 12, Features::ALL, Goal::Latency);
        let mut others = vec![
            SweepPoint::new("qr", 12, Features::ALL, Goal::Latency),
            SweepPoint::new("solver", 16, Features::ALL, Goal::Latency),
            SweepPoint::new("solver", 12, Features::NONE, Goal::Latency),
            SweepPoint::new("solver", 12, Features::ALL, Goal::Throughput),
        ];
        others.push(base.clone().with_fabric(2, 2));
        for o in &others {
            assert_ne!(key(&base), key(o), "{o:?}");
        }
        assert_eq!(key(&base), key(&base.clone()));
    }
}
