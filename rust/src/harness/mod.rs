//! Parallel sweep harness: the evaluation engine behind `revel report`,
//! `revel sweep`, and the benches.
//!
//! A report/bench declares its workload runs as [`SweepPoint`]s
//! (kernel, size, feature set, goal, optional fabric override); the
//! harness dispatches the distinct points over a [`pool`] of worker
//! threads (each point simulates one REVEL unit — embarrassingly
//! parallel, like independent kernel instances across cores in the
//! 5G-PUSCH parallelization or independent tiles in tiled linear
//! algebra), memoizes results in a process-wide [`cache`], and can emit
//! the results as a `BENCH_sweep.json` artifact via [`json`].
//!
//! Determinism: a point's outcome depends only on the point (instance
//! seeds are fixed per lane, the spatial compiler anneals from a fixed
//! seed), so results are identical for any worker count — `report all`
//! renders byte-identical text to the serial path.

pub mod cache;
pub mod json;
pub mod pool;

use std::sync::Arc;

use crate::compiler::FabricSpec;
use crate::model;
use crate::sim::{Stats, BUCKETS};
use crate::workloads::{self, Features, Goal, WlError};
use self::json::Json;

/// One workload run of a sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepPoint {
    pub kernel: String,
    pub n: usize,
    pub feats: Features,
    pub goal: Goal,
    /// Temporal-region override (w, h) compiled via
    /// [`FabricSpec::revel`]; None = the Table 3 default fabric.
    pub fabric: Option<(usize, usize)>,
}

impl SweepPoint {
    pub fn new(kernel: &str, n: usize, feats: Features, goal: Goal) -> Self {
        Self { kernel: kernel.to_string(), n, feats, goal, fabric: None }
    }

    pub fn with_fabric(mut self, w: usize, h: usize) -> Self {
        self.fabric = Some((w, h));
        self
    }

    /// Feature switches packed into 4 bits (cache/JSON identity).
    pub fn feature_bits(&self) -> u8 {
        (self.feats.inductive as u8)
            | (self.feats.fine_grain as u8) << 1
            | (self.feats.heterogeneous as u8) << 2
            | (self.feats.masking as u8) << 3
    }

    /// Human-readable feature-set name (the Fig 19 ladder names, else a
    /// bit string).
    pub fn feature_name(&self) -> String {
        for (name, f) in Features::ladder() {
            if f == self.feats {
                return if f == Features::ALL { "all".into() } else { name.into() };
            }
        }
        format!("bits{:04b}", self.feature_bits())
    }
}

/// Result of executing one sweep point (the JSON-able subset of
/// [`crate::workloads::RunOutcome`] plus its point).
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub point: SweepPoint,
    pub cycles: u64,
    pub max_err: f64,
    pub flops: f64,
    pub problems: usize,
    pub stats: Stats,
    /// Host wall time of this point's prepare+simulate+verify pass, in
    /// nanoseconds (mean over repetitions). A single execution records
    /// mean == min; benches that re-run the mix (`perf_hotpath`)
    /// aggregate across reps before emitting the artifact. Zero when
    /// parsed from a pre-wall-time artifact. Informational only — the
    /// CI regression gate reads simulated cycles, never wall time.
    pub wall_ns_mean: f64,
    /// Fastest observed execution of this point, nanoseconds.
    pub wall_ns_min: f64,
    /// Routed wirelength (hops over deduplicated physical nets) of the
    /// kernel's compiled placement. Zero when parsed from a pre-v3
    /// artifact or when no placement was compiled. Informational —
    /// never gates the cycle diff.
    pub wirelength: u64,
    /// Residual link overuse of the placement (0 = fully legalized).
    pub overuse: u64,
    /// Predicted scratchpad line fetches of the control program
    /// (vsc reuse-accounting model), summed over configuration eras.
    pub line_fetches: u64,
    /// Predicted avoidable re-fetches ([`crate::vsc::TrafficReport`]
    /// missed-reuse), summed over configuration eras.
    pub missed_reuse: u64,
}

impl SweepOutcome {
    /// Simulated time in microseconds at the REVEL clock.
    pub fn us(&self) -> f64 {
        model::cycles_to_us(self.cycles)
    }

    pub fn flops_per_cycle(&self) -> f64 {
        self.flops / self.cycles.max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let p = &self.point;
        Json::obj(vec![
            ("kernel", Json::Str(p.kernel.clone())),
            ("n", Json::Num(p.n as f64)),
            (
                "features",
                Json::obj(vec![
                    ("inductive", Json::Bool(p.feats.inductive)),
                    ("fine_grain", Json::Bool(p.feats.fine_grain)),
                    ("heterogeneous", Json::Bool(p.feats.heterogeneous)),
                    ("masking", Json::Bool(p.feats.masking)),
                ]),
            ),
            ("feature_set", Json::Str(p.feature_name())),
            (
                "goal",
                Json::Str(
                    match p.goal {
                        Goal::Latency => "latency",
                        Goal::Throughput => "throughput",
                    }
                    .into(),
                ),
            ),
            (
                "fabric",
                match p.fabric {
                    None => Json::Null,
                    Some((w, h)) => {
                        Json::Arr(vec![Json::Num(w as f64), Json::Num(h as f64)])
                    }
                },
            ),
            ("cycles", Json::Num(self.cycles as f64)),
            ("us", Json::Num(self.us())),
            ("problems", Json::Num(self.problems as f64)),
            ("max_err", Json::Num(self.max_err)),
            ("flops", Json::Num(self.flops)),
            ("flops_per_cycle", Json::Num(self.flops_per_cycle())),
            ("wall_ns_mean", Json::Num(self.wall_ns_mean)),
            ("wall_ns_min", Json::Num(self.wall_ns_min)),
            ("wirelength", Json::Num(self.wirelength as f64)),
            ("overuse", Json::Num(self.overuse as f64)),
            ("line_fetches", Json::Num(self.line_fetches as f64)),
            ("missed_reuse", Json::Num(self.missed_reuse as f64)),
            (
                "lane_cycles",
                Json::Arr(
                    self.stats
                        .lane_cycles
                        .iter()
                        .map(|&c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
            (
                "buckets",
                Json::Obj(
                    self.stats
                        .fractions()
                        .into_iter()
                        .map(|(b, f)| (b.name().to_string(), Json::Num(f)))
                        .collect(),
                ),
            ),
            ("commands", Json::Num(self.stats.commands as f64)),
            ("ctrl_core_cycles", Json::Num(self.stats.ctrl_core_cycles as f64)),
            ("spad_words", Json::Num(self.stats.spad_words as f64)),
            ("xfer_elems", Json::Num(self.stats.xfer_elems as f64)),
        ])
    }

    /// Inverse of [`to_json`] (schema round-trip; `buckets`/`us` are
    /// derived fields and recomputed).
    pub fn from_json(v: &Json) -> Result<SweepOutcome, String> {
        let err = |f: &str| format!("BENCH_sweep result missing/invalid {f:?}");
        let feats = v.get("features").ok_or_else(|| err("features"))?;
        let fb = |k: &str| {
            feats.get(k).and_then(Json::as_bool).ok_or_else(|| err(k))
        };
        let point = SweepPoint {
            kernel: v
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or_else(|| err("kernel"))?
                .to_string(),
            n: v.get("n").and_then(Json::as_usize).ok_or_else(|| err("n"))?,
            feats: Features {
                inductive: fb("inductive")?,
                fine_grain: fb("fine_grain")?,
                heterogeneous: fb("heterogeneous")?,
                masking: fb("masking")?,
            },
            goal: match v.get("goal").and_then(Json::as_str) {
                Some("latency") => Goal::Latency,
                Some("throughput") => Goal::Throughput,
                _ => return Err(err("goal")),
            },
            fabric: match v.get("fabric") {
                None | Some(Json::Null) => None,
                Some(Json::Arr(a)) if a.len() == 2 => Some((
                    a[0].as_usize().ok_or_else(|| err("fabric"))?,
                    a[1].as_usize().ok_or_else(|| err("fabric"))?,
                )),
                _ => return Err(err("fabric")),
            },
        };
        let mut stats = Stats {
            cycles: v
                .get("cycles")
                .and_then(Json::as_u64)
                .ok_or_else(|| err("cycles"))?,
            ..Stats::default()
        };
        if let Some(arr) = v.get("lane_cycles").and_then(Json::as_arr) {
            if arr.len() != BUCKETS.len() {
                return Err(err("lane_cycles"));
            }
            for (slot, e) in stats.lane_cycles.iter_mut().zip(arr) {
                *slot = e.as_u64().ok_or_else(|| err("lane_cycles"))?;
            }
        }
        for (field, slot) in [
            ("commands", &mut stats.commands),
            ("ctrl_core_cycles", &mut stats.ctrl_core_cycles),
            ("spad_words", &mut stats.spad_words),
            ("xfer_elems", &mut stats.xfer_elems),
        ] {
            if let Some(x) = v.get(field).and_then(Json::as_u64) {
                *slot = x;
            }
        }
        Ok(SweepOutcome {
            cycles: stats.cycles,
            max_err: v
                .get("max_err")
                .and_then(Json::as_f64)
                .ok_or_else(|| err("max_err"))?,
            flops: v
                .get("flops")
                .and_then(Json::as_f64)
                .ok_or_else(|| err("flops"))?,
            problems: v
                .get("problems")
                .and_then(Json::as_usize)
                .ok_or_else(|| err("problems"))?,
            point,
            stats,
            // Wall-time fields arrived with artifact version 2; older
            // baselines parse as 0 (meaning "unknown") so the wall-time
            // delta report degrades instead of failing.
            wall_ns_mean: v
                .get("wall_ns_mean")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            wall_ns_min: v.get("wall_ns_min").and_then(Json::as_f64).unwrap_or(0.0),
            // Placement/reuse fields arrived with artifact version 3;
            // older baselines parse as 0 ("unknown") so the placement
            // delta report degrades instead of failing.
            wirelength: v.get("wirelength").and_then(Json::as_u64).unwrap_or(0),
            overuse: v.get("overuse").and_then(Json::as_u64).unwrap_or(0),
            line_fetches: v.get("line_fetches").and_then(Json::as_u64).unwrap_or(0),
            missed_reuse: v.get("missed_reuse").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// Harness run options.
#[derive(Clone, Debug)]
pub struct Options {
    /// Worker threads; None = `REVEL_WORKERS` / available parallelism.
    pub workers: Option<usize>,
    /// Consult + fill the process-wide memo cache.
    pub use_cache: bool,
}

impl Default for Options {
    fn default() -> Self {
        Self { workers: None, use_cache: true }
    }
}

/// Reports legitimately run very long programs (e.g. the no-FGOP SVD at
/// n=32 exceeds the default sim watchdog); raise the process-wide
/// budget once, before any worker threads exist. An explicit budget —
/// set programmatically or by the CLI from `REVEL_MAX_CYCLES` — wins.
pub fn ensure_budget() {
    crate::sim::set_max_cycles_budget_if_unset(80_000_000);
}

/// Execute one sweep point on the current thread (fabric override is
/// installed thread-locally for the duration of the run). The point's
/// host wall time (prepare + simulate + verify) is captured into the
/// outcome so every bench artifact can track the simulator's real
/// speed alongside its simulated cycles.
pub fn execute_point(p: &SweepPoint) -> Result<SweepOutcome, WlError> {
    let t0 = std::time::Instant::now();
    if let Some((w, h)) = p.fabric {
        workloads::set_fabric(Some(FabricSpec::revel(w, h)));
    }
    let r = workloads::prepare(&p.kernel, p.n, p.feats, p.goal).and_then(|prep| {
        // Predicted scratchpad traffic of the control program (the vsc
        // reuse-accounting model) — captured pre-execution, since
        // `execute` consumes the prepared run.
        let chk = crate::vsc::check_program(&prep.prog, &prep.machine.cfg);
        let (fetches, missed) = chk
            .traffic
            .iter()
            .fold((0u64, 0u64), |(f, m), t| (f + t.fetches, m + t.missed_reuse));
        prep.execute().map(|o| (o, fetches, missed))
    });
    // Placement metrics: `prepare` populated the config cache under the
    // (still installed) fabric override; peek, never recompile.
    let place = workloads::peek_config(&p.kernel, p.feats);
    if p.fabric.is_some() {
        workloads::set_fabric(None);
    }
    let (r, line_fetches, missed_reuse) = r?;
    let (wirelength, overuse) = place
        .map(|c| (c.placement.wirelength as u64, c.placement.overuse as u64))
        .unwrap_or((0, 0));
    let wall_ns = t0.elapsed().as_nanos() as f64;
    Ok(SweepOutcome {
        point: p.clone(),
        cycles: r.cycles,
        max_err: r.max_err,
        flops: r.flops,
        problems: r.problems,
        stats: r.stats,
        wall_ns_mean: wall_ns,
        wall_ns_min: wall_ns,
        wirelength,
        overuse,
        line_fetches,
        missed_reuse,
    })
}

/// Run every point (deduplicated, memoized in the process-wide cache,
/// parallel) and return the outcomes aligned with `points`. The first
/// workload error aborts the sweep.
pub fn run_all(points: &[SweepPoint]) -> Result<Vec<Arc<SweepOutcome>>, WlError> {
    run_all_opts(points, &Options::default())
}

pub fn run_all_opts(
    points: &[SweepPoint],
    opts: &Options,
) -> Result<Vec<Arc<SweepOutcome>>, WlError> {
    run_all_in(points, opts, opts.use_cache.then(cache::global))
}

/// Like [`run_all_opts`] but against an explicit cache (tests use
/// private instances; `None` disables memoization).
pub fn run_all_in(
    points: &[SweepPoint],
    opts: &Options,
    memo: Option<&cache::SweepCache>,
) -> Result<Vec<Arc<SweepOutcome>>, WlError> {
    ensure_budget();
    // Partition into distinct points that still need execution. Cache
    // consultation happens once per distinct point (hit/miss counted).
    let mut local: std::collections::HashMap<cache::Key, Arc<SweepOutcome>> =
        std::collections::HashMap::new();
    let mut todo: Vec<SweepPoint> = Vec::new();
    let mut todo_keys: Vec<cache::Key> = Vec::new();
    for p in points {
        let k = cache::key(p);
        if todo_keys.contains(&k) || local.contains_key(&k) {
            continue;
        }
        if let Some(hit) = memo.and_then(|c| c.get(&k)) {
            local.insert(k, hit);
            continue;
        }
        todo.push(p.clone());
        todo_keys.push(k);
    }
    let workers = opts.workers.unwrap_or_else(pool::default_workers);
    let fresh: Vec<Result<SweepOutcome, WlError>> =
        pool::run_parallel(&todo, workers, execute_point);
    for (k, r) in todo_keys.into_iter().zip(fresh) {
        let out = Arc::new(r?);
        if let Some(c) = memo {
            c.insert(k.clone(), out.clone());
        }
        local.insert(k, out);
    }
    Ok(points
        .iter()
        .map(|p| local[&cache::key(p)].clone())
        .collect())
}

/// Convenience: cached cycles of a single point.
pub fn cycles(
    kernel: &str,
    n: usize,
    feats: Features,
    goal: Goal,
) -> Result<u64, WlError> {
    let out = run_all(&[SweepPoint::new(kernel, n, feats, goal)])?;
    Ok(out[0].cycles)
}

/// The default full sweep: every kernel at every paper size, both
/// goals, all FGOP features.
pub fn full_sweep_points(kernels: &[&str]) -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for &k in kernels {
        for &n in workloads::sizes(k).iter() {
            for goal in [Goal::Latency, Goal::Throughput] {
                v.push(SweepPoint::new(k, n, Features::ALL, goal));
            }
        }
    }
    v
}

/// One point's cycle comparison in a sweep diff.
#[derive(Clone, Debug)]
pub struct DiffRow {
    /// Point identity (kernel/n/features/goal/fabric).
    pub key: String,
    /// Baseline cycles.
    pub base: u64,
    /// Current cycles.
    pub cur: u64,
}

/// Result of [`diff_outcomes`]: the perf-neutrality gate CI applies to
/// archived `BENCH_sweep.json` artifacts across commits.
#[derive(Clone, Debug, Default)]
pub struct SweepDiff {
    /// Points whose cycle count grew beyond the tolerance.
    pub regressions: Vec<DiffRow>,
    /// Points that got faster.
    pub improvements: Vec<DiffRow>,
    /// Points with identical (within tolerance) cycles.
    pub unchanged: usize,
    /// Baseline points absent from the current run (coverage loss).
    pub missing: Vec<String>,
    /// Current points absent from the baseline (new coverage).
    pub added: Vec<String>,
    /// Matched points carrying wall-time data on both sides, paired for
    /// the informational before/after report. Wall time never gates the
    /// diff — only the cycle classification above does.
    pub walls: Vec<WallRow>,
    /// Matched points carrying placement data on both sides
    /// (wirelength > 0), paired for the informational
    /// wirelength/overuse/traffic delta table. Like walls, never gates.
    pub places: Vec<PlaceRow>,
}

/// Per-point host wall-time pair of a matched baseline/current point.
#[derive(Clone, Debug)]
pub struct WallRow {
    /// Point identity ([`point_key`]).
    pub key: String,
    /// Baseline host wall time, nanoseconds (mean over reps).
    pub base_ns: f64,
    /// Current host wall time, nanoseconds (mean over reps).
    pub cur_ns: f64,
}

/// Per-point placement/traffic pair of a matched baseline/current point.
#[derive(Clone, Debug)]
pub struct PlaceRow {
    /// Point identity ([`point_key`]).
    pub key: String,
    /// Baseline routed wirelength (hops).
    pub base_wl: u64,
    /// Current routed wirelength (hops).
    pub cur_wl: u64,
    /// Baseline residual link overuse.
    pub base_ou: u64,
    /// Current residual link overuse.
    pub cur_ou: u64,
    /// Baseline predicted line fetches.
    pub base_fetches: u64,
    /// Current predicted line fetches.
    pub cur_fetches: u64,
    /// Baseline predicted missed-reuse fetches.
    pub base_missed: u64,
    /// Current predicted missed-reuse fetches.
    pub cur_missed: u64,
}

/// Stable identity string of a sweep point (kernel/n/features/goal/
/// fabric) — the key `diff_outcomes` matches baseline and current
/// artifacts on.
pub fn point_key(p: &SweepPoint) -> String {
    format!(
        "{}/n{}/{}/{:?}/{:?}",
        p.kernel,
        p.n,
        p.feature_name(),
        p.goal,
        p.fabric
    )
}

/// Compare two sweeps point by point. A regression is a matched point
/// whose current cycles exceed baseline cycles by more than
/// `tol_pct` percent.
pub fn diff_outcomes(
    base: &[SweepOutcome],
    cur: &[SweepOutcome],
    tol_pct: f64,
) -> SweepDiff {
    let cur_by_key: std::collections::HashMap<String, &SweepOutcome> =
        cur.iter().map(|o| (point_key(&o.point), o)).collect();
    let base_keys: std::collections::HashSet<String> =
        base.iter().map(|o| point_key(&o.point)).collect();
    let mut d = SweepDiff::default();
    for b in base {
        let key = point_key(&b.point);
        let Some(c) = cur_by_key.get(&key) else {
            d.missing.push(key);
            continue;
        };
        if b.wall_ns_mean > 0.0 && c.wall_ns_mean > 0.0 {
            d.walls.push(WallRow {
                key: key.clone(),
                base_ns: b.wall_ns_mean,
                cur_ns: c.wall_ns_mean,
            });
        }
        if b.wirelength > 0 && c.wirelength > 0 {
            d.places.push(PlaceRow {
                key: key.clone(),
                base_wl: b.wirelength,
                cur_wl: c.wirelength,
                base_ou: b.overuse,
                cur_ou: c.overuse,
                base_fetches: b.line_fetches,
                cur_fetches: c.line_fetches,
                base_missed: b.missed_reuse,
                cur_missed: c.missed_reuse,
            });
        }
        let limit = b.cycles as f64 * (1.0 + tol_pct / 100.0);
        let row = DiffRow { key, base: b.cycles, cur: c.cycles };
        if (c.cycles as f64) > limit {
            d.regressions.push(row);
        } else if c.cycles < b.cycles {
            d.improvements.push(row);
        } else {
            d.unchanged += 1;
        }
    }
    for c in cur {
        let key = point_key(&c.point);
        if !base_keys.contains(&key) {
            d.added.push(key);
        }
    }
    d
}

/// Build the `BENCH_sweep.json` document.
pub fn artifact_json(
    outcomes: &[Arc<SweepOutcome>],
    wall_s: f64,
    workers: usize,
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("revel-bench-sweep".into())),
        // Version 2 added per-point host wall time (wall_ns_mean /
        // wall_ns_min); version 3 added placement + reuse-accounting
        // fields (wirelength/overuse/line_fetches/missed_reuse).
        // Version-1/-2 artifacts still parse (new fields read 0).
        ("version", Json::Num(3.0)),
        ("workers", Json::Num(workers as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("freq_ghz", Json::Num(model::FREQ_GHZ)),
        (
            "results",
            Json::Arr(outcomes.iter().map(|o| o.to_json()).collect()),
        ),
    ])
}

/// Write the sweep artifact to `path`.
pub fn write_artifact(
    path: &str,
    outcomes: &[Arc<SweepOutcome>],
    wall_s: f64,
    workers: usize,
) -> std::io::Result<()> {
    std::fs::write(path, artifact_json(outcomes, wall_s, workers).pretty())
}

/// Parse a sweep artifact back into outcomes (schema round-trip).
pub fn read_artifact(text: &str) -> Result<Vec<SweepOutcome>, String> {
    let doc = json::parse(text)?;
    if doc.get("schema").and_then(Json::as_str) != Some("revel-bench-sweep") {
        return Err("not a revel-bench-sweep document".into());
    }
    doc.get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing results array".to_string())?
        .iter()
        .map(SweepOutcome::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cheap_points() -> Vec<SweepPoint> {
        vec![
            SweepPoint::new("solver", 8, Features::ALL, Goal::Latency),
            SweepPoint::new("solver", 12, Features::ALL, Goal::Latency),
            SweepPoint::new("fir", 12, Features::ALL, Goal::Throughput),
            SweepPoint::new("gemm", 12, Features::ALL, Goal::Latency),
        ]
    }

    #[test]
    fn cache_misses_then_hits() {
        let memo = cache::SweepCache::new();
        let pts = cheap_points();
        let opts = Options { workers: Some(2), use_cache: true };
        let a = run_all_in(&pts, &opts, Some(&memo)).unwrap();
        assert_eq!(memo.stats(), (0, pts.len() as u64), "first run all misses");
        assert_eq!(memo.len(), pts.len());
        let b = run_all_in(&pts, &opts, Some(&memo)).unwrap();
        assert_eq!(
            memo.stats(),
            (pts.len() as u64, pts.len() as u64),
            "second run all hits"
        );
        for (x, y) in a.iter().zip(&b) {
            assert!(Arc::ptr_eq(x, y), "second run returns the cached Arc");
        }
    }

    #[test]
    fn results_independent_of_worker_count() {
        let pts = cheap_points();
        let opts1 = Options { workers: Some(1), use_cache: false };
        let opts4 = Options { workers: Some(4), use_cache: false };
        let a = run_all_opts(&pts, &opts1).unwrap();
        let b = run_all_opts(&pts, &opts4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cycles, y.cycles, "{:?}", x.point);
            assert_eq!(x.stats.lane_cycles, y.stats.lane_cycles);
            assert_eq!(x.max_err, y.max_err);
        }
    }

    #[test]
    fn duplicate_points_execute_once_and_align() {
        let memo = cache::SweepCache::new();
        let p = SweepPoint::new("solver", 8, Features::ALL, Goal::Latency);
        let pts = vec![p.clone(), p.clone(), p];
        let opts = Options { workers: Some(2), use_cache: true };
        let out = run_all_in(&pts, &opts, Some(&memo)).unwrap();
        assert_eq!(out.len(), 3);
        assert!(Arc::ptr_eq(&out[0], &out[1]) && Arc::ptr_eq(&out[1], &out[2]));
        assert_eq!(memo.len(), 1, "one execution for three occurrences");
        assert_eq!(memo.stats().1, 1, "one miss, duplicates dedup before lookup");
    }

    #[test]
    fn fabric_override_points_change_results_and_restore_default() {
        let base = SweepPoint::new("solver", 12, Features::ALL, Goal::Latency);
        let small = base.clone().with_fabric(1, 1);
        let opts = Options { workers: Some(2), use_cache: false };
        let out = run_all_opts(&[base.clone(), small], &opts).unwrap();
        assert!(out[0].cycles > 0 && out[1].cycles > 0);
        // After the sweep the ambient fabric is the Table 3 default.
        assert_eq!(crate::workloads::fabric().temporal_tiles(), 2);
        // And a default-fabric rerun reproduces the base result.
        let again = run_all_opts(&[base], &opts).unwrap();
        assert_eq!(again[0].cycles, out[0].cycles);
    }

    #[test]
    fn json_schema_roundtrip() {
        let pts = vec![
            SweepPoint::new("solver", 8, Features::NONE, Goal::Latency),
            SweepPoint::new("fir", 12, Features::ALL, Goal::Throughput)
                .with_fabric(2, 2),
        ];
        let opts = Options { workers: Some(2), use_cache: false };
        let out = run_all_opts(&pts, &opts).unwrap();
        let doc = artifact_json(&out, 1.25, 4).pretty();
        let back = read_artifact(&doc).unwrap();
        assert_eq!(back.len(), out.len());
        for (orig, rt) in out.iter().zip(&back) {
            assert_eq!(rt.point, orig.point);
            assert_eq!(rt.cycles, orig.cycles);
            assert_eq!(rt.problems, orig.problems);
            assert_eq!(rt.flops, orig.flops);
            assert_eq!(rt.max_err, orig.max_err);
            assert_eq!(rt.stats.lane_cycles, orig.stats.lane_cycles);
            assert_eq!(rt.stats.commands, orig.stats.commands);
            assert!(orig.wall_ns_mean > 0.0, "execution records wall time");
            assert_eq!(rt.wall_ns_mean, orig.wall_ns_mean);
            assert_eq!(rt.wall_ns_min, orig.wall_ns_min);
            assert!(orig.wirelength > 0, "execution records placement metrics");
            assert!(orig.line_fetches > 0, "execution records predicted traffic");
            assert_eq!(rt.wirelength, orig.wirelength);
            assert_eq!(rt.overuse, orig.overuse);
            assert_eq!(rt.line_fetches, orig.line_fetches);
            assert_eq!(rt.missed_reuse, orig.missed_reuse);
        }
        // Round-trip is a fixed point: re-serializing parses identically.
        let doc2 = artifact_json(
            &back.into_iter().map(Arc::new).collect::<Vec<_>>(),
            1.25,
            4,
        )
        .pretty();
        assert_eq!(json::parse(&doc).unwrap(), json::parse(&doc2).unwrap());
    }

    #[test]
    fn sweep_diff_classifies_regressions_and_coverage() {
        let memo = cache::SweepCache::new();
        let pts = vec![
            SweepPoint::new("solver", 8, Features::ALL, Goal::Latency),
            SweepPoint::new("solver", 12, Features::ALL, Goal::Latency),
        ];
        let opts = Options { workers: Some(2), use_cache: true };
        let out = run_all_in(&pts, &opts, Some(&memo)).unwrap();
        let base: Vec<SweepOutcome> =
            out.iter().map(|o| o.as_ref().clone()).collect();
        // Identical runs: no regressions, everything unchanged; wall
        // time aggregates over all matched points.
        let d = diff_outcomes(&base, &base, 0.0);
        assert!(d.regressions.is_empty() && d.improvements.is_empty());
        assert_eq!(d.unchanged, 2);
        assert_eq!(d.walls.len(), 2);
        assert!(d.walls.iter().all(|w| w.base_ns > 0.0 && w.base_ns == w.cur_ns));
        assert_eq!(d.places.len(), 2, "placement data pairs on both sides");
        assert!(d.places.iter().all(|r| r.base_wl == r.cur_wl && r.base_ou == r.cur_ou));
        // A wall-less, placement-less baseline (old artifact) degrades
        // informationally.
        let mut old = base.clone();
        for o in &mut old {
            o.wall_ns_mean = 0.0;
            o.wall_ns_min = 0.0;
            o.wirelength = 0;
            o.overuse = 0;
        }
        let d = diff_outcomes(&old, &base, 0.0);
        assert!(d.walls.is_empty(), "no wall data on one side: not paired");
        assert!(d.places.is_empty(), "no placement data on one side: not paired");
        assert_eq!(d.unchanged, 2, "cycle gate unaffected by missing walls");
        // Inflate one current point: regression at 0%, absorbed by 200%.
        let mut slow = base.clone();
        slow[0].cycles = base[0].cycles * 2;
        let d = diff_outcomes(&base, &slow, 0.0);
        assert_eq!(d.regressions.len(), 1);
        assert!(d.regressions[0].key.contains("solver/n8"), "{:?}", d.regressions);
        assert!(diff_outcomes(&base, &slow, 200.0).regressions.is_empty());
        // Improvements and coverage changes classify.
        let mut fast = base.clone();
        fast[1].cycles -= 1;
        let d = diff_outcomes(&base, &fast, 0.0);
        assert_eq!(d.improvements.len(), 1);
        let d = diff_outcomes(&base, &base[..1], 0.0);
        assert_eq!(d.missing.len(), 1);
        let d = diff_outcomes(&base[..1], &base, 0.0);
        assert_eq!(d.added.len(), 1);
    }

    #[test]
    fn full_sweep_covers_every_kernel_and_goal() {
        let pts = full_sweep_points(&workloads::NAMES);
        let sizes: usize = workloads::NAMES.iter().map(|k| workloads::sizes(k).len()).sum();
        assert_eq!(pts.len(), 2 * sizes);
        for k in workloads::NAMES {
            assert!(pts.iter().any(|p| p.kernel == k && p.goal == Goal::Latency));
            assert!(pts.iter().any(|p| p.kernel == k && p.goal == Goal::Throughput));
        }
    }
}
