//! Minimal JSON document model, emitter, and parser (serde is
//! unavailable offline). Only what `BENCH_sweep.json` needs: objects,
//! arrays, strings, finite f64 numbers, booleans, null. Numbers are
//! emitted with enough precision to round-trip u64 cycle counts exactly
//! (all values in the artifact fit in f64's 53-bit mantissa or are
//! genuinely fractional).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, k: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(k),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => {
                ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1)))
            }
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    e.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        // Shortest f64 round-trip formatting is the Display default.
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (strict enough for round-tripping our own
/// output plus hand-edited artifacts).
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes: Vec<char> = text.chars().collect();
    let mut p = Parser { s: &bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.s.len() {
        return Err(format!("trailing data at char {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    s: &'a [char],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: char) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{c}' at char {}", self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        for c in word.chars() {
            self.eat(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some('n') => self.lit("null", Json::Null),
            Some('t') => self.lit("true", Json::Bool(true)),
            Some('f') => self.lit("false", Json::Bool(false)),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('[') => self.array(),
            Some('{') => self.object(),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at char {}", self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat('[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some(']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at char {}, got {other:?}",
                        self.i
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat('{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some('}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at char {}, got {other:?}",
                        self.i
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat('"')?;
        let mut out = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| "unterminated string".to_string())?;
            self.i += 1;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| "bad escape".to_string())?;
                    self.i += 1;
                    match e {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'n' => out.push('\n'),
                        'r' => out.push('\r'),
                        't' => out.push('\t'),
                        'b' => out.push('\u{8}'),
                        'f' => out.push('\u{c}'),
                        'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self
                                    .peek()
                                    .and_then(|c| c.to_digit(16))
                                    .ok_or_else(|| "bad \\u escape".to_string())?;
                                self.i += 1;
                                code = code * 16 + h;
                            }
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        other => return Err(format!("bad escape \\{other}")),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some('-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, '.' | 'e' | 'E' | '+' | '-')
        ) {
            self.i += 1;
        }
        let text: String = self.s[start..self.i].iter().collect();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::obj(vec![
            ("version", Json::Num(1.0)),
            (
                "results",
                Json::Arr(vec![Json::obj(vec![
                    ("kernel", Json::Str("cholesky".into())),
                    ("cycles", Json::Num(123456789.0)),
                    ("us", Json::Num(98.7654321)),
                    ("ok", Json::Bool(true)),
                    ("fabric", Json::Null),
                ])]),
            ),
        ]);
        for text in [doc.render(), doc.pretty()] {
            assert_eq!(parse(&text).unwrap(), doc, "{text}");
        }
    }

    #[test]
    fn strings_escape_and_parse() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn numbers_preserve_integers_and_floats() {
        for x in [0.0, -3.0, 1e15, 0.125, -2.5e-3, 123456789012.0] {
            let v = Json::Num(x);
            let back = parse(&v.render()).unwrap();
            assert_eq!(back.as_f64().unwrap(), x);
        }
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }
}
