//! Work-queue worker pool for the sweep engine: sweep points are
//! embarrassingly parallel (one simulated REVEL unit each), so they are
//! dispatched over `std::thread` workers pulling indices off a shared
//! atomic counter. Results come back in input order regardless of which
//! worker ran them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: `REVEL_WORKERS` if set (>0), else the machine's
/// available parallelism.
pub fn default_workers() -> usize {
    std::env::var("REVEL_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&w| w > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Run `f` over every item on up to `workers` threads; the returned
/// vector is aligned with `items`. A panicking worker propagates the
/// panic to the caller (scoped-thread join semantics).
pub fn run_parallel<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_align_with_input_order() {
        let items: Vec<usize> = (0..97).collect();
        for workers in [1, 2, 8] {
            let out = run_parallel(&items, workers, |&x| x * x);
            let want: Vec<usize> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, want, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = vec![];
        assert!(run_parallel(&none, 4, |&x| x).is_empty());
        assert_eq!(run_parallel(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn all_workers_can_contribute() {
        use std::collections::HashSet;
        let items: Vec<usize> = (0..64).collect();
        let out = run_parallel(&items, 4, |_| std::thread::current().id());
        let distinct: HashSet<_> = out.into_iter().collect();
        // With 64 items and 4 workers at least one thread ran something;
        // usually several do. (No strict assertion on >1: scheduling.)
        assert!(!distinct.is_empty());
    }
}
