//! The repo's single scoped worker-pool primitive. Sweep points and
//! co-simulation shards are both embarrassingly parallel between
//! synchronization points, so they share one mechanism: [`scope`]
//! starts `workers` scoped `std::thread` workers pulling boxed jobs off
//! one shared queue, runs the caller's closure (which submits jobs via
//! [`Scope::spawn`]), and joins every worker — so returning from
//! `scope` is a barrier. [`run_parallel`] (the sweep engine's
//! map-over-items entry point) is a thin layer on top. [`try_scope`]
//! is the structured-error variant: labeled jobs, panics caught and
//! returned as one [`RtError`] naming every label that died — so a
//! fault-test failure reports *which* shard's cells panicked instead
//! of aborting the whole process.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::runtime::RtError;

/// Worker count: `REVEL_WORKERS` if set (>0), else the machine's
/// available parallelism.
pub fn default_workers() -> usize {
    std::env::var("REVEL_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&w| w > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

type Job<'env> = Box<dyn FnOnce() + Send + 'env>;

struct Queue<'env> {
    jobs: VecDeque<Job<'env>>,
    /// Set when the scope closure has returned: once the queue drains,
    /// workers exit instead of waiting for more submissions.
    closed: bool,
}

/// Handle passed to the [`scope`] closure; submits jobs to the pool.
pub struct Scope<'env, 'p> {
    queue: &'p Mutex<Queue<'env>>,
    work: &'p Condvar,
    workers: usize,
}

impl<'env, 'p> Scope<'env, 'p> {
    /// Submit a job; some worker picks it up in FIFO order. Jobs may
    /// borrow anything that outlives the `scope` call.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'env) {
        let mut q = self.queue.lock().unwrap();
        q.jobs.push_back(Box::new(job));
        drop(q);
        self.work.notify_one();
    }

    /// Number of worker threads serving this scope.
    pub fn workers(&self) -> usize {
        self.workers
    }
}

/// Run `f` with a pool of `workers` scoped threads; every job submitted
/// through the handle has finished when `scope` returns (the workers
/// are joined — this is the barrier the cosim shard runner relies on).
/// A panicking job propagates to the caller via scoped-join semantics;
/// jobs still queued behind it on other workers are drained normally.
pub fn scope<'env, R>(
    workers: usize,
    f: impl FnOnce(&Scope<'env, '_>) -> R,
) -> R {
    let workers = workers.max(1);
    let queue = Mutex::new(Queue { jobs: VecDeque::new(), closed: false });
    let work = Condvar::new();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = {
                    let mut q = queue.lock().unwrap();
                    loop {
                        if let Some(j) = q.jobs.pop_front() {
                            break Some(j);
                        }
                        if q.closed {
                            break None;
                        }
                        q = work.wait(q).unwrap();
                    }
                };
                match job {
                    Some(j) => j(),
                    None => return,
                }
            });
        }
        let r = f(&Scope { queue: &queue, work: &work, workers });
        queue.lock().unwrap().closed = true;
        work.notify_all();
        r
    })
}

/// Best-effort human-readable panic payload (`&str` / `String` cover
/// every `panic!` in practice; anything else gets a placeholder).
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Handle passed to the [`try_scope`] closure: like [`Scope`], but
/// every job carries a label and a panicking job is *caught* — its
/// label + payload are collected instead of unwinding through the
/// pool — so the caller learns exactly which jobs died.
pub struct TryScope<'env, 'p> {
    inner: &'p Scope<'env, 'p>,
    panics: Arc<Mutex<Vec<String>>>,
}

impl<'env, 'p> TryScope<'env, 'p> {
    /// Submit a labeled job. If it panics, `label: payload` is
    /// recorded and the remaining jobs keep running.
    pub fn spawn(&self, label: impl Into<String>, job: impl FnOnce() + Send + 'env) {
        let label = label.into();
        let panics = Arc::clone(&self.panics);
        self.inner.spawn(move || {
            if let Err(p) =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
            {
                let msg = panic_message(p.as_ref());
                panics.lock().unwrap().push(format!("{label}: {msg}"));
            }
        });
    }

    /// Number of worker threads serving this scope.
    pub fn workers(&self) -> usize {
        self.inner.workers()
    }
}

/// Structured-error variant of [`scope`]: jobs are labeled, panics are
/// caught per job, and the result is `Err` listing every label that
/// panicked (in completion order) — the barrier still holds, so jobs
/// queued behind a dead one run to completion first.
pub fn try_scope<'env, R>(
    workers: usize,
    f: impl FnOnce(&TryScope<'env, '_>) -> R,
) -> Result<R, RtError> {
    let panics = Arc::new(Mutex::new(Vec::new()));
    let collected = Arc::clone(&panics);
    let r = scope(workers, |s| f(&TryScope { inner: s, panics }));
    let died = collected.lock().unwrap();
    if died.is_empty() {
        Ok(r)
    } else {
        Err(RtError(format!("worker panic: {}", died.join("; "))))
    }
}

/// Run `f` over every item on up to `workers` threads; the returned
/// vector is aligned with `items`. A panicking worker propagates the
/// panic to the caller (scoped-thread join semantics).
pub fn run_parallel<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f = &f;
    scope(workers, |s| {
        for (item, slot) in items.iter().zip(&slots) {
            s.spawn(move || {
                let r = f(item);
                *slot.lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_align_with_input_order() {
        let items: Vec<usize> = (0..97).collect();
        for workers in [1, 2, 8] {
            let out = run_parallel(&items, workers, |&x| x * x);
            let want: Vec<usize> = items.iter().map(|&x| x * x).collect();
            assert_eq!(out, want, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = vec![];
        assert!(run_parallel(&none, 4, |&x| x).is_empty());
        assert_eq!(run_parallel(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn all_workers_can_contribute() {
        use std::collections::HashSet;
        let items: Vec<usize> = (0..64).collect();
        let out = run_parallel(&items, 4, |_| std::thread::current().id());
        let distinct: HashSet<_> = out.into_iter().collect();
        // With 64 items and 4 workers at least one thread ran something;
        // usually several do. (No strict assertion on >1: scheduling.)
        assert!(!distinct.is_empty());
    }

    #[test]
    fn scope_is_a_barrier() {
        // Every spawned job has run by the time `scope` returns, and
        // jobs may mutate disjoint borrows of caller state.
        let mut cells = vec![0usize; 33];
        scope(4, |s| {
            for (i, c) in cells.iter_mut().enumerate() {
                s.spawn(move || *c = i + 1);
            }
        });
        assert!(cells.iter().enumerate().all(|(i, &c)| c == i + 1));
    }

    #[test]
    fn scope_supports_sequential_rounds() {
        // The shard-runner pattern: repeated barriered rounds over the
        // same mutable state, one fresh scope per round.
        let mut shards = vec![0u64; 5];
        for _round in 0..7 {
            scope(3, |s| {
                for sh in shards.iter_mut() {
                    s.spawn(move || *sh += 1);
                }
            });
        }
        assert!(shards.iter().all(|&v| v == 7));
    }

    #[test]
    fn scope_runs_many_jobs_on_few_workers() {
        let hits = AtomicUsize::new(0);
        scope(2, |s| {
            for _ in 0..100 {
                s.spawn(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    #[should_panic(expected = "job panic propagates")]
    fn scope_propagates_job_panics() {
        scope(2, |s| {
            s.spawn(|| panic!("job panic propagates"));
        });
    }

    #[test]
    fn try_scope_reports_which_labeled_job_died() {
        let hits = AtomicUsize::new(0);
        let err = try_scope(2, |s| {
            s.spawn("cells 0..2", || {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            s.spawn("cells 2..4", || panic!("unit 3 exploded"));
            s.spawn("cells 4..6", || {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        })
        .unwrap_err();
        // The error names the dead shard's cell range and payload; the
        // healthy jobs still ran to the barrier.
        assert!(err.0.contains("cells 2..4"), "{err}");
        assert!(err.0.contains("unit 3 exploded"), "{err}");
        assert!(!err.0.contains("cells 0..2"), "{err}");
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn try_scope_returns_the_closure_value_when_nothing_dies() {
        let got = try_scope(3, |s| {
            for _ in 0..10 {
                s.spawn("noop", || {});
            }
            s.workers()
        })
        .unwrap();
        assert_eq!(got, 3);
    }
}
