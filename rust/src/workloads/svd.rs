//! One-sided Jacobi SVD (paper Fig 6 right). Per column pair (p, q):
//!
//! * `dot` (critical reduce): app = a_p.a_p, aqq = a_q.a_q,
//!   apq = a_p.a_q — three gated reductions back to back;
//! * `rot` (non-critical — the deepest sub-critical chain of the suite:
//!   the reason SVD needs the largest temporal region, Fig 20):
//!   tau/t/c/s rotation parameters with divide + two sqrts;
//! * `rotate` (critical): [a_p'; a_q'] = [c -s; s c] [a_p; a_q].
//!
//! Fine-grain deps: three dot results stream to `rot`, then (c, s)
//! stream to `rotate` with column-length reuse. Columns update in place
//! (rmw pairs). After `SWEEPS` sweeps the column norms are the singular
//! values; verification mirrors the exact pair order and formulas.
//! Built on the typed [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op, Operand};
use crate::isa::{LaneMask, Program, Reuse};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::Mat;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

const W: usize = 4;
/// Jacobi sweeps (fixed schedule; enough for n<=32 convergence).
pub const SWEEPS: usize = 6;

/// Typed port handles of the three dataflows.
pub struct Ports {
    /// dot: first column stream (width W).
    pub dot_a: In,
    /// dot: second column stream (width W).
    pub dot_b: In,
    /// dot: reduction emit gate.
    pub dot_gate: In,
    /// rot: app.
    pub app: In,
    /// rot: aqq.
    pub aqq: In,
    /// rot: apq.
    pub apq: In,
    /// rotate: a_p column (width W).
    pub rot_ap: In,
    /// rotate: a_q column (width W).
    pub rot_aq: In,
    /// rotate: c scalar (reused).
    pub rot_c: In,
    /// rotate: s scalar (reused).
    pub rot_s: In,
    /// dot out (gated): the three reductions per pair.
    pub dot_out: Out,
    /// rot out: c.
    pub c_out: Out,
    /// rot out: s.
    pub s_out: Out,
    /// rotate out: a_p'.
    pub ap_out: Out,
    /// rotate out: a_q'.
    pub aq_out: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// A, column-major, `n*n` words (rotated in place).
    pub a: Region,
    /// Region hand-off scratch for the non-fine-grain ablation (5
    /// words: app/aqq/apq/c/s).
    pub tmp: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("svd");

    let mut d = k.dfg("dot", Criticality::Critical);
    let a = d.input(W);
    let b = d.input(W);
    let gate = d.input(1);
    let prod = d.node(Op::Mul, &[a.wire(), b.wire()]);
    let s = d.node(Op::AccReduce, &[prod, gate.wire()]);
    let dot_out = d.output_gated(s, 1, gate);
    d.done();

    let mut r = k.dfg("rot", Criticality::NonCritical);
    let app = r.input(1);
    let aqq = r.input(1);
    let apq = r.input(1);
    // tau = (aqq - app + tiny) / (2 apq): apq == 0 -> tau = +-inf -> t = 0.
    let num = r.node(Op::Sub, &[aqq.wire(), app.wire()]);
    let numb = r.node(Op::Add, &[num, Operand::Const(1e-300)]);
    let den = r.node(Op::Mul, &[Operand::Const(2.0), apq.wire()]);
    let tau = r.node(Op::Div, &[numb, den]);
    let ge = r.node(Op::CmpGe, &[tau, Operand::Const(0.0)]);
    let sg = r.node(Op::Select, &[ge, Operand::Const(1.0), Operand::Const(-1.0)]);
    let at_ = r.node(Op::Abs, &[tau]);
    let tau2 = r.node(Op::Mul, &[tau, tau]);
    let tau2p1 = r.node(Op::Add, &[Operand::Const(1.0), tau2]);
    let sq = r.node(Op::Sqrt, &[tau2p1]);
    let denom = r.node(Op::Add, &[at_, sq]);
    let t = r.node(Op::Div, &[sg, denom]);
    let t2 = r.node(Op::Mul, &[t, t]);
    let t2p1 = r.node(Op::Add, &[Operand::Const(1.0), t2]);
    let c = r.node(Op::Rsqrt, &[t2p1]);
    let s2 = r.node(Op::Mul, &[c, t]);
    let c_out = r.output(c, 1);
    let s_out = r.output(s2, 1);
    r.done();

    // Rotation as a complex multiply (c + i s)(ap + i aq) using the
    // Gauss 3-multiplication form — the naive 4-mult version exceeds
    // the fabric's 9 multiply tiles at width 4.
    let mut ro = k.dfg("rotate", Criticality::Critical);
    let ap = ro.input(W);
    let aq = ro.input(W);
    let cc = ro.input(1);
    let ss = ro.input(1);
    let apq_sum = ro.node(Op::Add, &[ap.wire(), aq.wire()]);
    let smc = ro.node(Op::Sub, &[ss.wire(), cc.wire()]);
    let cps = ro.node(Op::Add, &[cc.wire(), ss.wire()]);
    let k1 = ro.node(Op::Mul, &[cc.wire(), apq_sum]);
    let k2 = ro.node(Op::Mul, &[ap.wire(), smc]);
    let k3 = ro.node(Op::Mul, &[aq.wire(), cps]);
    let pn = ro.node(Op::Sub, &[k1, k3]);
    let qn = ro.node(Op::Add, &[k1, k2]);
    let ap_out = ro.output(pn, W);
    let aq_out = ro.output(qn, W);
    ro.done();

    let built = k.build()?;
    let ports = Ports {
        dot_a: a,
        dot_b: b,
        dot_gate: gate,
        app,
        aqq,
        apq,
        rot_ap: ap,
        rot_aq: aq,
        rot_c: cc,
        rot_s: ss,
        dot_out,
        c_out,
        s_out,
        ap_out,
        aq_out,
    };
    Ok((built, ports))
}

/// Allocate the scratchpad layout for problem size `n`.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let a = al.region("svd.A", (n * n) as i64)?;
    let tmp = al.region("svd.tmp", 5)?;
    Ok(Layout { a, tmp })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

fn at(n: i64, i: i64, j: i64) -> i64 {
    j * n + i
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    program_sweeps(n, SWEEPS, feats, mask)
}

/// Program with an explicit sweep count (testing/debug).
pub fn program_sweeps(
    n: usize,
    sweeps: usize,
    feats: Features,
    mask: LaneMask,
) -> Result<Program, WlError> {
    let plan = plan(n, feats)?;
    let n_i = n as i64;
    let p = &plan.ports;
    let (a, tmp) = (&plan.lay.a, &plan.lay.tmp);
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);
    let col = |j: i64| a.lin(at(n_i, 0, j), n_i);
    let firings = (n_i + W as i64 - 1) / W as i64;

    for _sweep in 0..sweeps {
        for pi in 0..n_i - 1 {
            for qi in pi + 1..n_i {
                b.barrier();
                // Emit gate first (it must not queue behind blocked
                // loads), then the three dots: (p,p), (q,q), (p,q).
                b.gate_last_of_row(p.dot_gate, 1.0, 0.0, firings as f64, 3, 0.0);
                for (x, y) in [(pi, pi), (qi, qi), (pi, qi)] {
                    b.ld(col(x), p.dot_a);
                    b.ld(col(y), p.dot_b);
                }
                if feats.fine_grain {
                    for dst in [p.app, p.aqq, p.apq] {
                        b.xfer(p.dot_out, dst, 1);
                    }
                    for (src, dst) in [(p.c_out, p.rot_c), (p.s_out, p.rot_s)] {
                        b.xfer_reuse(src, dst, 1, Reuse::uniform(n as f64));
                    }
                } else {
                    // Region hand-offs through the scratchpad.
                    for k in 0..3i64 {
                        b.st(tmp.lin(k, 1), p.dot_out);
                    }
                    b.barrier();
                    for (k, dst) in [(0i64, p.app), (1, p.aqq), (2, p.apq)] {
                        b.ld(tmp.lin(k, 1), dst);
                    }
                    b.st(tmp.lin(3, 1), p.c_out);
                    b.st(tmp.lin(4, 1), p.s_out);
                    b.barrier();
                    b.ld_reuse(tmp.lin(3, 1), p.rot_c, Reuse::uniform(n as f64));
                    b.ld_reuse(tmp.lin(4, 1), p.rot_s, Reuse::uniform(n as f64));
                }
                // In-place rotation of both columns.
                b.st_rmw(col(pi), p.ap_out);
                b.st_rmw(col(qi), p.aq_out);
                b.ld_rmw(col(pi), p.rot_ap, 0);
                b.ld_rmw(col(qi), p.rot_aq, 0);
            }
        }
    }
    Ok(b.finish())
}

/// Scalar mirror with the exact same pair order and formulas.
pub fn svd_mirror(a: &mut Mat, sweeps: usize) {
    let n = a.rows;
    for _ in 0..sweeps {
        for p in 0..n - 1 {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    app += a[(i, p)] * a[(i, p)];
                    aqq += a[(i, q)] * a[(i, q)];
                    apq += a[(i, p)] * a[(i, q)];
                }
                let tau = (aqq - app + 1e-300) / (2.0 * apq);
                let sg = if tau >= 0.0 { 1.0 } else { -1.0 };
                let t = sg / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let vp = a[(i, p)];
                    let vq = a[(i, q)];
                    a[(i, p)] = c * vp - s * vq;
                    a[(i, q)] = s * vp + c * vq;
                }
            }
        }
    }
}

pub struct Instance {
    pub a: Mat,
    pub a_ref: Mat,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(n, n, |i, j| {
        (((i * 5 + j * 3 + seed * 2) as f64) * 0.19).sin()
            + if i == j { 1.5 } else { 0.0 }
    });
    let mut a_ref = a.clone();
    svd_mirror(&mut a_ref, SWEEPS);
    Instance { a, a_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    let lay = layout(n).expect("svd layout fits the lane scratchpad");
    for j in 0..n {
        for i in 0..n {
            lane.spad
                .write(lay.a.addr(at(n as i64, i as i64, j as i64)), inst.a[(i, j)]);
        }
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1, // paper Table 5: SVD latency version = 1 lane
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    // Element-wise comparison is not an invariant here: when two
    // singular values nearly coincide, tau ~ 0 and the sign(tau) branch
    // picks one of two equally valid +-45-degree rotations; mirror and
    // simulation may legitimately diverge. Verify the invariants
    // instead: singular values (sorted column norms) and pairwise
    // column orthogonality.
    let a_region = lay.a;
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows;
            let col = |j: usize| -> Vec<f64> {
                (0..nn)
                    .map(|i| {
                        m.lanes[l]
                            .spad
                            .read(a_region.addr(at(nn as i64, i as i64, j as i64)))
                    })
                    .collect()
            };
            let mut got: Vec<f64> = (0..nn)
                .map(|j| col(j).iter().map(|x| x * x).sum::<f64>().sqrt())
                .collect();
            got.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let want = crate::util::linalg::svd_values(&inst.a, 30);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                let err = (g - w).abs() / w.max(1.0);
                if err > 1e-6 {
                    return Err(format!(
                        "lane {l} sigma[{k}]: got {g}, want {w}"
                    ));
                }
                max_err = max_err.max(err);
            }
            for p in 0..nn {
                for q in p + 1..nn {
                    let cp = col(p);
                    let cq = col(q);
                    let d: f64 = cp.iter().zip(&cq).map(|(a, b)| a * b).sum();
                    let np: f64 = cp.iter().map(|x| x * x).sum::<f64>().sqrt();
                    let nq: f64 = cq.iter().map(|x| x * x).sum::<f64>().sqrt();
                    let ortho = d.abs() / (np * nq).max(1e-300);
                    if ortho > 1e-5 {
                        return Err(format!(
                            "lane {l} cols ({p},{q}) not orthogonal: {ortho}"
                        ));
                    }
                }
            }
        }
        Ok(max_err)
    });
    let pairs = (n * (n - 1) / 2 * SWEEPS) as f64;
    let flops = lanes as f64 * pairs * (12.0 * n as f64 + 20.0);
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::svd_values;

    #[test]
    fn mirror_converges_to_singular_values() {
        let inst = instance(8, 0);
        // Column norms after the sweeps ~ singular values.
        let mut got: Vec<f64> = (0..8)
            .map(|j| (0..8).map(|i| inst.a_ref[(i, j)].powi(2)).sum::<f64>().sqrt())
            .collect();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want = svd_values(&inst.a, 20);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6 * w.max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn fgop_svd_is_correct_small_sizes() {
        for n in [8, 12] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn svd_16_correct() {
        prepare(16, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
    }

    #[test]
    fn ladder_versions_correct() {
        for (name, feats) in Features::ladder() {
            prepare(8, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn program_passes_the_vsc_check() {
        for feats in [Features::ALL, Features::NONE] {
            let prog = program_sweeps(8, 1, feats, LaneMask::one(0)).unwrap();
            let rep = crate::vsc::check_program(&prog, &SimConfig::default());
            assert!(rep.errors().is_empty(), "{feats:?}:\n{rep}");
        }
    }
}
