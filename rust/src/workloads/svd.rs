//! One-sided Jacobi SVD (paper Fig 6 right). Per column pair (p, q):
//!
//! * `dot` (critical reduce): app = a_p.a_p, aqq = a_q.a_q,
//!   apq = a_p.a_q — three gated reductions back to back;
//! * `rot` (non-critical — the deepest sub-critical chain of the suite:
//!   the reason SVD needs the largest temporal region, Fig 20):
//!   tau/t/c/s rotation parameters with divide + two sqrts;
//! * `rotate` (critical): [a_p'; a_q'] = [c -s; s c] [a_p; a_q].
//!
//! Fine-grain deps: three dot results stream to `rot`, then (c, s)
//! stream to `rotate` with column-length reuse. Columns update in place
//! (rmw pairs). After `SWEEPS` sweeps the column norms are the singular
//! values; verification mirrors the exact pair order and formulas.

use std::sync::Arc;

use super::{machine, push_ld, push_st, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op, Operand};
use crate::isa::{
    Cmd, ConstPattern, LaneMask, Pattern2D, Program, Reuse, VsCommand, XferDst,
};
use crate::sim::Machine;
use crate::util::linalg::Mat;

const W: usize = 4;
/// Jacobi sweeps (fixed schedule; enough for n<=32 convergence).
pub const SWEEPS: usize = 6;

const A_BASE: i64 = 0;
const TMP_BASE: i64 = 1100;

// Ports. In: 0=dot.a(W), 1=dot.b(W), 2=dot gate(1), 3=rot.app(1),
// 4=rot.aqq(1), 5=rot.apq(1), 6=rotate.ap(W), 7=rotate.aq(W),
// 8=rotate.c(1), 9=rotate.s(1).
// Out: 0=dot result, 1=c, 2=s, 3=a_p', 4=a_q'.
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut d = DfgBuilder::new("dot", Criticality::Critical);
    let a = d.in_port(0, W);
    let b = d.in_port(1, W);
    let gate = d.in_port(2, 1);
    let prod = d.node(Op::Mul, &[a, b]);
    let s = d.node(Op::AccReduce, &[prod, gate]);
    d.out_gated(0, s, 1, Some(gate));

    let mut r = DfgBuilder::new("rot", Criticality::NonCritical);
    let app = r.in_port(3, 1);
    let aqq = r.in_port(4, 1);
    let apq = r.in_port(5, 1);
    // tau = (aqq - app + tiny) / (2 apq): apq == 0 -> tau = +-inf -> t = 0.
    let num = r.node(Op::Sub, &[aqq, app]);
    let numb = r.node(Op::Add, &[num, Operand::Const(1e-300)]);
    let den = r.node(Op::Mul, &[Operand::Const(2.0), apq]);
    let tau = r.node(Op::Div, &[numb, den]);
    let ge = r.node(Op::CmpGe, &[tau, Operand::Const(0.0)]);
    let sg = r.node(Op::Select, &[ge, Operand::Const(1.0), Operand::Const(-1.0)]);
    let at_ = r.node(Op::Abs, &[tau]);
    let tau2 = r.node(Op::Mul, &[tau, tau]);
    let tau2p1 = r.node(Op::Add, &[Operand::Const(1.0), tau2]);
    let sq = r.node(Op::Sqrt, &[tau2p1]);
    let denom = r.node(Op::Add, &[at_, sq]);
    let t = r.node(Op::Div, &[sg, denom]);
    let t2 = r.node(Op::Mul, &[t, t]);
    let t2p1 = r.node(Op::Add, &[Operand::Const(1.0), t2]);
    let c = r.node(Op::Rsqrt, &[t2p1]);
    let s2 = r.node(Op::Mul, &[c, t]);
    r.out(1, c, 1);
    r.out(2, s2, 1);

    // Rotation as a complex multiply (c + i s)(ap + i aq) using the
    // Gauss 3-multiplication form — the naive 4-mult version exceeds
    // the fabric's 9 multiply tiles at width 4.
    let mut ro = DfgBuilder::new("rotate", Criticality::Critical);
    let ap = ro.in_port(6, W);
    let aq = ro.in_port(7, W);
    let cc = ro.in_port(8, 1);
    let ss = ro.in_port(9, 1);
    let apq_sum = ro.node(Op::Add, &[ap, aq]);
    let smc = ro.node(Op::Sub, &[ss, cc]);
    let cps = ro.node(Op::Add, &[cc, ss]);
    let k1 = ro.node(Op::Mul, &[cc, apq_sum]);
    let k2 = ro.node(Op::Mul, &[ap, smc]);
    let k3 = ro.node(Op::Mul, &[aq, cps]);
    let pn = ro.node(Op::Sub, &[k1, k3]);
    let qn = ro.node(Op::Add, &[k1, k2]);
    ro.out(3, pn, W);
    ro.out(4, qn, W);

    let cfg = LaneConfig {
        name: "svd".into(),
        dfgs: vec![d.build(), r.build(), ro.build()],
    };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

fn at(n: i64, i: i64, j: i64) -> i64 {
    A_BASE + j * n + i
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    program_sweeps(n, SWEEPS, feats, mask)
}

/// Program with an explicit sweep count (testing/debug).
pub fn program_sweeps(
    n: usize,
    sweeps: usize,
    feats: Features,
    mask: LaneMask,
) -> Result<Program, WlError> {
    let cfg = config(feats)?;
    let n_i = n as i64;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];
    let col = |j: i64| Pattern2D::lin(at(n_i, 0, j), n_i);
    let firings = (n_i + W as i64 - 1) / W as i64;

    for _sweep in 0..sweeps {
        for pi in 0..n_i - 1 {
            for qi in pi + 1..n_i {
                p.push(vs(Cmd::Barrier));
                // Emit gate first (it must not queue behind blocked
                // loads), then the three dots: (p,p), (q,q), (p,q).
                p.push(vs(Cmd::ConstSt {
                    pat: ConstPattern::last_of_row(1.0, 0.0, firings as f64, 3, 0.0),
                    port: 2,
                }));
                for (x, y) in [(pi, pi), (qi, qi), (pi, qi)] {
                    push_ld(&mut p, mask, col(x), 0, None, feats, None);
                    push_ld(&mut p, mask, col(y), 1, None, feats, None);
                }
                if feats.fine_grain {
                    for dst in [3usize, 4, 5] {
                        p.push(vs(Cmd::Xfer {
                            src_port: 0,
                            dst_port: dst,
                            dst: XferDst::Local,
                            n: 1,
                            reuse: None,
                        }));
                    }
                    for (src, dst) in [(1usize, 8usize), (2, 9)] {
                        p.push(vs(Cmd::Xfer {
                            src_port: src,
                            dst_port: dst,
                            dst: XferDst::Local,
                            n: 1,
                            reuse: Some(Reuse::uniform(n as f64)),
                        }));
                    }
                } else {
                    // Region hand-offs through the scratchpad.
                    for k in 0..3i64 {
                        p.push(vs(Cmd::LocalSt {
                            pat: Pattern2D::lin(TMP_BASE + k, 1),
                            port: 0,
                            rmw: false,
                        }));
                    }
                    p.push(vs(Cmd::Barrier));
                    for (k, dst) in [(0i64, 3usize), (1, 4), (2, 5)] {
                        push_ld(
                            &mut p,
                            mask,
                            Pattern2D::lin(TMP_BASE + k, 1),
                            dst,
                            None,
                            feats,
                            None,
                        );
                    }
                    p.push(vs(Cmd::LocalSt {
                        pat: Pattern2D::lin(TMP_BASE + 3, 1),
                        port: 1,
                        rmw: false,
                    }));
                    p.push(vs(Cmd::LocalSt {
                        pat: Pattern2D::lin(TMP_BASE + 4, 1),
                        port: 2,
                        rmw: false,
                    }));
                    p.push(vs(Cmd::Barrier));
                    push_ld(
                        &mut p,
                        mask,
                        Pattern2D::lin(TMP_BASE + 3, 1),
                        8,
                        Some(Reuse::uniform(n as f64)),
                        feats,
                        None,
                    );
                    push_ld(
                        &mut p,
                        mask,
                        Pattern2D::lin(TMP_BASE + 4, 1),
                        9,
                        Some(Reuse::uniform(n as f64)),
                        feats,
                        None,
                    );
                }
                // In-place rotation of both columns.
                push_st(&mut p, mask, col(pi), 3, true, feats);
                push_st(&mut p, mask, col(qi), 4, true, feats);
                push_ld(&mut p, mask, col(pi), 6, None, feats, Some(0));
                push_ld(&mut p, mask, col(qi), 7, None, feats, Some(0));
            }
        }
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

/// Scalar mirror with the exact same pair order and formulas.
pub fn svd_mirror(a: &mut Mat, sweeps: usize) {
    let n = a.rows;
    for _ in 0..sweeps {
        for p in 0..n - 1 {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    app += a[(i, p)] * a[(i, p)];
                    aqq += a[(i, q)] * a[(i, q)];
                    apq += a[(i, p)] * a[(i, q)];
                }
                let tau = (aqq - app + 1e-300) / (2.0 * apq);
                let sg = if tau >= 0.0 { 1.0 } else { -1.0 };
                let t = sg / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let vp = a[(i, p)];
                    let vq = a[(i, q)];
                    a[(i, p)] = c * vp - s * vq;
                    a[(i, q)] = s * vp + c * vq;
                }
            }
        }
    }
}

pub struct Instance {
    pub a: Mat,
    pub a_ref: Mat,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(n, n, |i, j| {
        (((i * 5 + j * 3 + seed * 2) as f64) * 0.19).sin()
            + if i == j { 1.5 } else { 0.0 }
    });
    let mut a_ref = a.clone();
    svd_mirror(&mut a_ref, SWEEPS);
    Instance { a, a_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    for j in 0..n {
        for i in 0..n {
            lane.spad.write(at(n as i64, i as i64, j as i64), inst.a[(i, j)]);
        }
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1, // paper Table 5: SVD latency version = 1 lane
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    // Element-wise comparison is not an invariant here: when two
    // singular values nearly coincide, tau ~ 0 and the sign(tau) branch
    // picks one of two equally valid +-45-degree rotations; mirror and
    // simulation may legitimately diverge. Verify the invariants
    // instead: singular values (sorted column norms) and pairwise
    // column orthogonality.
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows;
            let col = |j: usize| -> Vec<f64> {
                (0..nn)
                    .map(|i| m.lanes[l].spad.read(at(nn as i64, i as i64, j as i64)))
                    .collect()
            };
            let mut got: Vec<f64> = (0..nn)
                .map(|j| col(j).iter().map(|x| x * x).sum::<f64>().sqrt())
                .collect();
            got.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let want = crate::util::linalg::svd_values(&inst.a, 30);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                let err = (g - w).abs() / w.max(1.0);
                if err > 1e-6 {
                    return Err(format!(
                        "lane {l} sigma[{k}]: got {g}, want {w}"
                    ));
                }
                max_err = max_err.max(err);
            }
            for p in 0..nn {
                for q in p + 1..nn {
                    let cp = col(p);
                    let cq = col(q);
                    let d: f64 = cp.iter().zip(&cq).map(|(a, b)| a * b).sum();
                    let np: f64 = cp.iter().map(|x| x * x).sum::<f64>().sqrt();
                    let nq: f64 = cq.iter().map(|x| x * x).sum::<f64>().sqrt();
                    let ortho = d.abs() / (np * nq).max(1e-300);
                    if ortho > 1e-5 {
                        return Err(format!(
                            "lane {l} cols ({p},{q}) not orthogonal: {ortho}"
                        ));
                    }
                }
            }
        }
        Ok(max_err)
    });
    let pairs = (n * (n - 1) / 2 * SWEEPS) as f64;
    let flops = lanes as f64 * pairs * (12.0 * n as f64 + 20.0);
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::svd_values;

    #[test]
    fn mirror_converges_to_singular_values() {
        let inst = instance(8, 0);
        // Column norms after the sweeps ~ singular values.
        let mut got: Vec<f64> = (0..8)
            .map(|j| (0..8).map(|i| inst.a_ref[(i, j)].powi(2)).sum::<f64>().sqrt())
            .collect();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want = svd_values(&inst.a, 20);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6 * w.max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn fgop_svd_is_correct_small_sizes() {
        for n in [8, 12] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn svd_16_correct() {
        prepare(16, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
    }

    #[test]
    fn ladder_versions_correct() {
        for (name, feats) in Features::ladder() {
            prepare(8, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
