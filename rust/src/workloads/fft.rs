//! Radix-2 DIT FFT (paper Table 5: RR access, stream-reuse for the
//! twiddle table, no fine-grain deps). In-place butterflies over
//! bit-reversed input; log2(n) stages, each a new set of strided
//! streams. The per-stage store->load ordering between stages is
//! enforced by the lane's memory interlock — the stage-serialization
//! plus the deep pipeline is exactly why the paper finds small FFTs the
//! one place the DSP stays competitive (Q5: reconfiguration/drain on
//! short phases).
//!
//! Early stages (half < vector width) run with masked partial vectors;
//! the twiddle streams use a rewinding 2D pattern (c_j = 0) — the
//! "streaming-reuse to reduce scratchpad bandwidth" of Q1.

use std::sync::Arc;

use super::{Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op};
use crate::isa::{Cmd, LaneMask, Pattern2D, Program, VsCommand};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::fft as fft_ref;

/// Vector width of the butterfly dataflow.
const W: usize = 4;

// Scratchpad layout: ping-pong complex buffers (stages alternate
// between them so no stage is an in-place RMW — the stores of stage s
// and the loads of stage s+1 still order through the memory interlock,
// but within a stage everything streams freely) plus the twiddle table.
// n=1024 needs 5n words; the paper's 8KB SPAD would stream the second
// buffer + twiddles from the shared scratchpad — we model that residency
// with a larger local SPAD (see DESIGN.md SSDeviations).
fn layout(n: usize) -> (i64, i64, i64, i64) {
    // (buf0 re, buf0 im, twiddle re, twiddle im); buf1 = buf0 + 4n.
    let re = 0i64;
    let im = n as i64;
    let twr = 4 * n as i64;
    let twi = twr + (n / 2) as i64;
    (re, im, twr, twi)
}

/// Base of the ping-pong buffer used as *input* of stage `s`.
fn buf(n: usize, s: usize) -> (i64, i64) {
    if s % 2 == 0 {
        (0, n as i64)
    } else {
        (2 * n as i64, 3 * n as i64)
    }
}

// Ports. In: 0=ar(W), 1=ai(W), 2=br(W), 3=bi(W), 4=wr(W), 5=wi(W).
// Out: 0=ar', 1=ai', 2=br', 3=bi'.
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut f = DfgBuilder::new("butterfly", Criticality::Critical);
    let ar = f.in_port(0, W);
    let ai = f.in_port(1, W);
    let br = f.in_port(2, W);
    let bi = f.in_port(3, W);
    let wr = f.in_port(4, W);
    let wi = f.in_port(5, W);
    let m1 = f.node(Op::Mul, &[br, wr]);
    let m2 = f.node(Op::Mul, &[bi, wi]);
    let tr = f.node(Op::Sub, &[m1, m2]);
    let m3 = f.node(Op::Mul, &[br, wi]);
    let m4 = f.node(Op::Mul, &[bi, wr]);
    let ti = f.node(Op::Add, &[m3, m4]);
    let or0 = f.node(Op::Add, &[ar, tr]);
    let oi0 = f.node(Op::Add, &[ai, ti]);
    let or1 = f.node(Op::Sub, &[ar, tr]);
    let oi1 = f.node(Op::Sub, &[ai, ti]);
    f.out(0, or0, W);
    f.out(1, oi0, W);
    f.out(2, or1, W);
    f.out(3, oi1, W);
    let cfg = LaneConfig { name: "fft".into(), dfgs: vec![f.build()] };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    assert!(n.is_power_of_two());
    let cfg = config(feats)?;
    let (_, _, twr, twi) = layout(n);
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];
    let mut len = 2usize;
    let mut stage = 0usize;
    while len <= n {
        let (sre, sim_) = buf(n, stage);
        let (dre, dim_) = buf(n, stage + 1);
        let half = (len / 2) as i64;
        let groups = (n / len) as i64;
        // Top/bottom halves of each butterfly group (RR streams).
        let shape = |base: i64, off: i64| {
            Pattern2D::rect(base + off, 1, half, len as i64, groups)
        };
        // Twiddles: the same half-row re-read per group (c_j = 0): the
        // stream-reuse that cuts scratchpad bandwidth.
        let tw_stride = (n / len) as i64;
        let wr = Pattern2D::rect(twr, tw_stride, half, 0, groups);
        let wi = Pattern2D::rect(twi, tw_stride, half, 0, groups);
        // Ping-pong: read stage input from one buffer, write outputs to
        // the other. The memory interlock orders stage s+1's loads
        // after stage s's stores automatically (range overlap). The
        // four output streams interleave within the destination buffer
        // (coarse bounds overlap, addresses disjoint) — mark them rmw
        // so they don't falsely WAW-serialize against each other; the
        // next stage's (non-rmw) loads still wait for them.
        for (src, dst, port) in [
            (shape(sre, 0), shape(dre, 0), 0usize),
            (shape(sim_, 0), shape(dim_, 0), 1),
            (shape(sre, half), shape(dre, half), 2),
            (shape(sim_, half), shape(dim_, half), 3),
        ] {
            p.push(vs(Cmd::LocalSt { pat: dst, port, rmw: true }));
            p.push(vs(Cmd::LocalLd {
                pat: src,
                port,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
        }
        p.push(vs(Cmd::LocalLd {
            pat: wr,
            port: 4,
            reuse: None,
            masked: feats.masking,
            rmw: None,
        }));
        p.push(vs(Cmd::LocalLd {
            pat: wi,
            port: 5,
            reuse: None,
            masked: feats.masking,
            rmw: None,
        }));
        len <<= 1;
        stage += 1;
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

/// Number of butterfly stages (which ping-pong buffer holds the result).
pub fn stages(n: usize) -> usize {
    n.trailing_zeros() as usize
}

pub struct Instance {
    /// Bit-reversed input (marshalled at load time).
    pub re_in: Vec<f64>,
    pub im_in: Vec<f64>,
    pub re_ref: Vec<f64>,
    pub im_ref: Vec<f64>,
}

fn bit_reverse(n: usize, x: &[f64]) -> Vec<f64> {
    let bits = n.trailing_zeros();
    let mut out = vec![0.0; n];
    for (i, &v) in x.iter().enumerate() {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        out[j as usize] = v;
    }
    out
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let re: Vec<f64> = (0..n).map(|i| ((i * 3 + seed) as f64 * 0.17).sin()).collect();
    let im: Vec<f64> = (0..n).map(|i| ((i * 5 + seed) as f64 * 0.11).cos()).collect();
    let mut re_ref = re.clone();
    let mut im_ref = im.clone();
    fft_ref(&mut re_ref, &mut im_ref);
    Instance {
        re_in: bit_reverse(n, &re),
        im_in: bit_reverse(n, &im),
        re_ref,
        im_ref,
    }
}

pub fn load_lane(lane: &mut crate::sim::Lane, n: usize, inst: &Instance) {
    let (re, im, twr, twi) = layout(n);
    lane.spad.load_slice(re, &inst.re_in);
    lane.spad.load_slice(im, &inst.im_in);
    for k in 0..n / 2 {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        lane.spad.write(twr + k as i64, ang.cos());
        lane.spad.write(twi + k as i64, ang.sin());
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    // Table 5: FFT uses 1 lane; throughput replicates across 8.
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let spad = (5 * n).max(2048).next_power_of_two();
    let mut m = Machine::new(SimConfig {
        lanes,
        lane_spad_words: spad,
        ..Default::default()
    });
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], n, inst);
    }
    let verify = Box::new(move |m: &Machine| {
        let (re, im) = buf(n, stages(n));
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            for i in 0..n {
                let gr = m.lanes[l].spad.read(re + i as i64);
                let gi = m.lanes[l].spad.read(im + i as i64);
                let er = (gr - inst.re_ref[i]).abs();
                let ei = (gi - inst.im_ref[i]).abs();
                if er > 1e-6 || ei > 1e-6 {
                    return Err(format!(
                        "lane {l} X[{i}]: got ({gr},{gi}), want ({},{})",
                        inst.re_ref[i], inst.im_ref[i]
                    ));
                }
                max_err = max_err.max(er.max(ei));
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * 5.0 * n as f64 * (n as f64).log2();
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_correct_small_sizes() {
        for n in [16, 64, 128] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn fft_1024_runs() {
        prepare(1024, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
    }

    #[test]
    fn fft_throughput_eight_lanes() {
        let r = prepare(64, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }
}
