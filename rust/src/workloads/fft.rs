//! Radix-2 DIT FFT (paper Table 5: RR access, stream-reuse for the
//! twiddle table, no fine-grain deps). In-place butterflies over
//! bit-reversed input; log2(n) stages, each a new set of strided
//! streams. The per-stage store->load ordering between stages is
//! enforced by the lane's memory interlock — the stage-serialization
//! plus the deep pipeline is exactly why the paper finds small FFTs the
//! one place the DSP stays competitive (Q5: reconfiguration/drain on
//! short phases).
//!
//! Early stages (half < vector width) run with masked partial vectors;
//! the twiddle streams use a rewinding 2D pattern (c_j = 0) — the
//! "streaming-reuse to reduce scratchpad bandwidth" of Q1. Built on the
//! typed [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op};
use crate::isa::{LaneMask, Program};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::fft as fft_ref;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

/// Vector width of the butterfly dataflow.
const W: usize = 4;

/// Typed port handles of the butterfly dataflow.
pub struct Ports {
    /// Top-half real stream.
    pub ar: In,
    /// Top-half imaginary stream.
    pub ai: In,
    /// Bottom-half real stream.
    pub br: In,
    /// Bottom-half imaginary stream.
    pub bi: In,
    /// Twiddle real stream (rewinding).
    pub wr: In,
    /// Twiddle imaginary stream (rewinding).
    pub wi: In,
    /// Top output (real, imaginary).
    pub or0: Out,
    /// Top output imaginary.
    pub oi0: Out,
    /// Bottom output real.
    pub or1: Out,
    /// Bottom output imaginary.
    pub oi1: Out,
}

/// Scratchpad regions: ping-pong complex buffers (stages alternate
/// between them so no stage is an in-place RMW — the stores of stage s
/// and the loads of stage s+1 still order through the memory interlock,
/// but within a stage everything streams freely) plus the twiddle
/// table. n=1024 needs 5n words; the paper's 8KB SPAD would stream the
/// second buffer + twiddles from the shared scratchpad — we model that
/// residency with a larger local SPAD (see DESIGN.md Deviations).
pub struct Layout {
    /// Buffer 0 real part (stage inputs for even stages).
    pub re0: Region,
    /// Buffer 0 imaginary part.
    pub im0: Region,
    /// Buffer 1 real part.
    pub re1: Region,
    /// Buffer 1 imaginary part.
    pub im1: Region,
    /// Twiddle cosines, n/2 words.
    pub twr: Region,
    /// Twiddle sines, n/2 words.
    pub twi: Region,
}

impl Layout {
    /// The (re, im) regions holding the *input* of stage `s`.
    pub fn buf(&self, s: usize) -> (&Region, &Region) {
        if s % 2 == 0 {
            (&self.re0, &self.im0)
        } else {
            (&self.re1, &self.im1)
        }
    }
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

/// Local scratchpad words needed for an n-point FFT.
pub fn spad_words(n: usize) -> usize {
    (5 * n).max(2048).next_power_of_two()
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("fft");
    let mut f = k.dfg("butterfly", Criticality::Critical);
    let ar = f.input(W);
    let ai = f.input(W);
    let br = f.input(W);
    let bi = f.input(W);
    let wr = f.input(W);
    let wi = f.input(W);
    let m1 = f.node(Op::Mul, &[br.wire(), wr.wire()]);
    let m2 = f.node(Op::Mul, &[bi.wire(), wi.wire()]);
    let tr = f.node(Op::Sub, &[m1, m2]);
    let m3 = f.node(Op::Mul, &[br.wire(), wi.wire()]);
    let m4 = f.node(Op::Mul, &[bi.wire(), wr.wire()]);
    let ti = f.node(Op::Add, &[m3, m4]);
    let o0 = f.node(Op::Add, &[ar.wire(), tr]);
    let e0 = f.node(Op::Add, &[ai.wire(), ti]);
    let o1 = f.node(Op::Sub, &[ar.wire(), tr]);
    let e1 = f.node(Op::Sub, &[ai.wire(), ti]);
    let or0 = f.output(o0, W);
    let oi0 = f.output(e0, W);
    let or1 = f.output(o1, W);
    let oi1 = f.output(e1, W);
    f.done();
    let built = k.build()?;
    Ok((built, Ports { ar, ai, br, bi, wr, wi, or0, oi0, or1, oi1 }))
}

/// Allocate the scratchpad layout for an n-point FFT.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::with_capacity(spad_words(n));
    let re0 = al.region("fft.re0", n as i64)?;
    let im0 = al.region("fft.im0", n as i64)?;
    let re1 = al.region("fft.re1", n as i64)?;
    let im1 = al.region("fft.im1", n as i64)?;
    let twr = al.region("fft.twr", (n / 2) as i64)?;
    let twi = al.region("fft.twi", (n / 2) as i64)?;
    Ok(Layout { re0, im0, re1, im1, twr, twi })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    assert!(n.is_power_of_two());
    let plan = plan(n, feats)?;
    let p = &plan.ports;
    let lay = &plan.lay;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);
    let mut len = 2usize;
    let mut stage = 0usize;
    while len <= n {
        let (sre, sim_) = lay.buf(stage);
        let (dre, dim_) = lay.buf(stage + 1);
        let half = (len / 2) as i64;
        let groups = (n / len) as i64;
        // Top/bottom halves of each butterfly group (RR streams).
        let shape = |reg: &Region, off: i64| reg.rect(off, 1, half, len as i64, groups);
        // Twiddles: the same half-row re-read per group (c_j = 0): the
        // stream-reuse that cuts scratchpad bandwidth.
        let tw_stride = (n / len) as i64;
        let wr = lay.twr.rect(0, tw_stride, half, 0, groups);
        let wi = lay.twi.rect(0, tw_stride, half, 0, groups);
        // Ping-pong: read stage input from one buffer, write outputs to
        // the other. The memory interlock orders stage s+1's loads
        // after stage s's stores automatically (range overlap). The
        // four output streams interleave within the destination buffer
        // (coarse bounds overlap, addresses disjoint) — mark them rmw
        // so they don't falsely WAW-serialize against each other; the
        // next stage's (non-rmw) loads still wait for them. All streams
        // are rectangular-native: never decomposed by the ablation.
        for (src, dst, in_p, out_p) in [
            (shape(sre, 0), shape(dre, 0), p.ar, p.or0),
            (shape(sim_, 0), shape(dim_, 0), p.ai, p.oi0),
            (shape(sre, half), shape(dre, half), p.br, p.or1),
            (shape(sim_, half), shape(dim_, half), p.bi, p.oi1),
        ] {
            b.st_rect(dst, out_p, true);
            b.ld_rect(src, in_p, None);
        }
        b.ld_rect(wr, p.wr, None);
        b.ld_rect(wi, p.wi, None);
        len <<= 1;
        stage += 1;
    }
    Ok(b.finish())
}

/// Number of butterfly stages (which ping-pong buffer holds the result).
pub fn stages(n: usize) -> usize {
    n.trailing_zeros() as usize
}

pub struct Instance {
    /// Bit-reversed input (marshalled at load time).
    pub re_in: Vec<f64>,
    pub im_in: Vec<f64>,
    pub re_ref: Vec<f64>,
    pub im_ref: Vec<f64>,
}

fn bit_reverse(n: usize, x: &[f64]) -> Vec<f64> {
    let bits = n.trailing_zeros();
    let mut out = vec![0.0; n];
    for (i, &v) in x.iter().enumerate() {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        out[j as usize] = v;
    }
    out
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let re: Vec<f64> = (0..n).map(|i| ((i * 3 + seed) as f64 * 0.17).sin()).collect();
    let im: Vec<f64> = (0..n).map(|i| ((i * 5 + seed) as f64 * 0.11).cos()).collect();
    let mut re_ref = re.clone();
    let mut im_ref = im.clone();
    fft_ref(&mut re_ref, &mut im_ref);
    Instance {
        re_in: bit_reverse(n, &re),
        im_in: bit_reverse(n, &im),
        re_ref,
        im_ref,
    }
}

pub fn load_lane(lane: &mut crate::sim::Lane, n: usize, inst: &Instance) {
    let lay = layout(n).expect("fft layout fits the configured scratchpad");
    lane.spad.load_slice(lay.re0.base(), &inst.re_in);
    lane.spad.load_slice(lay.im0.base(), &inst.im_in);
    for k in 0..n / 2 {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        lane.spad.write(lay.twr.addr(k as i64), ang.cos());
        lane.spad.write(lay.twi.addr(k as i64), ang.sin());
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    // Table 5: FFT uses 1 lane; throughput replicates across 8.
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = Machine::new(SimConfig {
        lanes,
        lane_spad_words: spad_words(n),
        max_cycles: crate::sim::max_cycles_budget(),
        ..Default::default()
    });
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], n, inst);
    }
    let (out_re, out_im) = {
        let (r, i) = lay.buf(stages(n));
        (*r, *i)
    };
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            for i in 0..n {
                let gr = m.lanes[l].spad.read(out_re.addr(i as i64));
                let gi = m.lanes[l].spad.read(out_im.addr(i as i64));
                let er = (gr - inst.re_ref[i]).abs();
                let ei = (gi - inst.im_ref[i]).abs();
                if er > 1e-6 || ei > 1e-6 {
                    return Err(format!(
                        "lane {l} X[{i}]: got ({gr},{gi}), want ({},{})",
                        inst.re_ref[i], inst.im_ref[i]
                    ));
                }
                max_err = max_err.max(er.max(ei));
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * 5.0 * n as f64 * (n as f64).log2();
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_correct_small_sizes() {
        for n in [16, 64, 128] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn fft_1024_runs() {
        prepare(1024, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
    }

    #[test]
    fn fft_throughput_eight_lanes() {
        let r = prepare(64, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        let prog = program(64, Features::ALL, LaneMask::one(0)).unwrap();
        let sim = SimConfig {
            lanes: 1,
            lane_spad_words: spad_words(64),
            ..Default::default()
        };
        let rep = crate::vsc::check_program(&prog, &sim);
        assert!(rep.errors().is_empty(), "{rep}");
    }
}
