//! GEMM (beamforming): C[m x 64] = A[m x 16] B[16 x 64] — the paper's
//! regular, non-FGOP workload (Table 5: RR access, no fine-grain deps,
//! no heterogeneous fabric, no masking required: all dims are
//! width-divisible). One accumulating dataflow:
//!
//!   `acc[lane] += a_ik * b_kj`,   emitted (and reset) after k = 16.
//!
//! Streams per (row i, column-chunk jc): the B tile rows (2D rectangular
//! stream, k-major) and the A row scalars (broadcast: one scratchpad
//! word feeds all 8 lanes — the stream-reuse bandwidth saving the paper
//! notes even non-FGOP kernels enjoy). Built on the typed
//! [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op};
use crate::isa::{LaneMask, Program};
use crate::sim::Machine;
use crate::util::linalg::Mat;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

/// Vector width (64 columns = 8 chunks of 8).
const W: usize = 8;
/// Fixed inner dimensions matching the paper/AOT artifacts.
pub const K: usize = 16;
pub const P: usize = 64;

/// C (up to 48x64 words) exceeds the 8KB local SPAD; hardware would
/// stream C to the shared scratchpad — modeled as a larger local.
const SPAD_WORDS: usize = 8192;

/// Typed port handles of the accumulating dataflow.
pub struct Ports {
    /// B tile chunk stream (width W).
    pub b: In,
    /// A row scalars.
    pub a: In,
    /// Accumulator emit gate.
    pub gate: In,
    /// C output chunks (gated).
    pub c: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// A row block, `rows x 16`, row-major.
    pub a: Region,
    /// B, `16 x 64`, row-major.
    pub b: Region,
    /// C, `rows x 64`, row-major.
    pub c: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("gemm");
    let mut g = k.dfg("gemm", Criticality::Critical);
    let b = g.input(W);
    let a = g.input(1);
    let gate = g.input(1);
    let prod = g.node(Op::Mul, &[b.wire(), a.wire()]);
    let acc = g.node(Op::Acc, &[prod, gate.wire()]);
    let c = g.output_gated(acc, W, gate);
    g.done();
    let built = k.build()?;
    Ok((built, Ports { b, a, gate, c }))
}

/// Allocate the scratchpad layout for `rows` resident A rows per lane.
pub fn layout(rows: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::with_capacity(SPAD_WORDS);
    let a = al.region("gemm.A", (rows * K) as i64)?;
    let b = al.region("gemm.B", (K * P) as i64)?;
    let c = al.region("gemm.C", (rows * P) as i64)?;
    Ok(Layout { a, b, c })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(rows: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(rows)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Rows handled per lane for an m-row latency-split across `lanes`.
fn rows_per_lane(m: usize, lanes: usize) -> usize {
    m / lanes
}

/// Program for `rows` rows of A resident per lane (same commands on all
/// masked lanes; each lane's scratchpad holds its own row block).
pub fn program(rows: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let plan = plan(rows, feats)?;
    let p = &plan.ports;
    let lay = &plan.lay;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);
    // C streams to memory through one hoisted command (issued first so
    // the output port drains for the whole run).
    b.st(lay.c.lin(0, (rows * P) as i64), p.c);
    let chunks = P / W;
    for i in 0..rows {
        for jc in 0..chunks {
            // B tile: k rows of the jc-th column chunk (RR stream —
            // rectangular-native, never decomposed by the ablation).
            b.ld_rect(
                lay.b.rect((jc * W) as i64, 1, W as i64, P as i64, K as i64),
                p.b,
                None,
            );
            // A row scalars, one per k step.
            b.ld(lay.a.lin((i * K) as i64, K as i64), p.a);
            // Emit gate: accumulate 15 steps, emit on the 16th.
            b.gate_last_of_row(p.gate, 1.0, 0.0, K as f64, 1, 0.0);
        }
    }
    Ok(b.finish())
}

pub struct Instance {
    pub a: Mat,
    pub b: Mat,
    pub c_ref: Mat,
}

pub fn instance(m: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(m, K, |i, j| ((i * 7 + j * 3 + seed) as f64 * 0.13).sin());
    let b = Mat::from_fn(K, P, |i, j| ((i * 5 + j + seed) as f64 * 0.29).cos());
    let c_ref = a.matmul(&b);
    Instance { a, b, c_ref }
}

pub fn prepare(m: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let (lanes, rows, problems) = match goal {
        // Latency: one GEMM split row-wise across lanes.
        Goal::Latency => {
            let lanes = if m % 8 == 0 { 8 } else { 4 };
            (lanes, rows_per_lane(m, lanes), 1)
        }
        // Throughput: one full GEMM per lane.
        Goal::Throughput => (8, m, 8),
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(rows, feats, mask)?;
    let lay = layout(rows)?;
    let mut mach = crate::sim::Machine::new(crate::sim::SimConfig {
        lanes,
        lane_spad_words: SPAD_WORDS,
        max_cycles: crate::sim::max_cycles_budget(),
        ..Default::default()
    });
    let insts: Vec<Instance> = match goal {
        Goal::Latency => vec![instance(m, 0)],
        Goal::Throughput => (0..lanes).map(|l| instance(m, l)).collect(),
    };
    for l in 0..lanes {
        let inst = &insts[if problems == 1 { 0 } else { l }];
        let row0 = if problems == 1 { l * rows } else { 0 };
        for r in 0..rows {
            for k in 0..K {
                mach.lanes[l]
                    .spad
                    .write(lay.a.addr((r * K + k) as i64), inst.a[(row0 + r, k)]);
            }
        }
        for k in 0..K {
            for j in 0..P {
                mach.lanes[l]
                    .spad
                    .write(lay.b.addr((k * P + j) as i64), inst.b[(k, j)]);
            }
        }
    }
    let c_region = lay.c;
    let verify = Box::new(move |mach: &Machine| {
        let mut max_err = 0.0f64;
        for l in 0..lanes {
            let inst = &insts[if problems == 1 { 0 } else { l }];
            let row0 = if problems == 1 { l * rows } else { 0 };
            for r in 0..rows {
                for j in 0..P {
                    let got = mach.lanes[l].spad.read(c_region.addr((r * P + j) as i64));
                    let want = inst.c_ref[(row0 + r, j)];
                    let err = (got - want).abs();
                    if err > 1e-9 {
                        return Err(format!(
                            "lane {l} C[{r}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
        }
        Ok(max_err)
    });
    let flops = (2 * m * K * P * problems.max(1)) as f64;
    Ok(Prepared { machine: mach, prog, verify, flops, problems })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_correct_all_sizes_latency() {
        for m in [12, 24, 48] {
            prepare(m, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("m={m}: {e}"));
        }
    }

    #[test]
    fn gemm_correct_throughput() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn gemm_hits_high_utilization() {
        // Regular streaming kernel: the fabric should be busy most of the
        // time (paper Fig 1: GEMM reaches 30-80% even on CPUs/DSPs).
        let r = prepare(48, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert!(
            r.stats.utilization() > 0.5,
            "utilization {:.3}",
            r.stats.utilization()
        );
    }

    #[test]
    fn program_passes_the_vsc_check() {
        let prog = program(12, Features::ALL, LaneMask::first_n(4)).unwrap();
        let sim = crate::sim::SimConfig {
            lanes: 4,
            lane_spad_words: SPAD_WORDS,
            ..Default::default()
        };
        let rep = crate::vsc::check_program(&prog, &sim);
        assert!(rep.errors().is_empty(), "{rep}");
    }
}
