//! GEMM (beamforming): C[m x 64] = A[m x 16] B[16 x 64] — the paper's
//! regular, non-FGOP workload (Table 5: RR access, no fine-grain deps,
//! no heterogeneous fabric, no masking required: all dims are
//! width-divisible). One accumulating dataflow:
//!
//!   `acc[lane] += a_ik * b_kj`,   emitted (and reset) after k = 16.
//!
//! Streams per (row i, column-chunk jc): the B tile rows (2D rectangular
//! stream, k-major) and the A row scalars (broadcast: one scratchpad
//! word feeds all 8 lanes — the stream-reuse bandwidth saving the paper
//! notes even non-FGOP kernels enjoy).

use std::sync::Arc;

use super::{Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op};
use crate::isa::{Cmd, ConstPattern, LaneMask, Pattern2D, Program, VsCommand};
use crate::sim::Machine;
use crate::util::linalg::Mat;

/// Vector width (64 columns = 8 chunks of 8).
const W: usize = 8;
/// Fixed inner dimensions matching the paper/AOT artifacts.
pub const K: usize = 16;
pub const P: usize = 64;

const A_BASE: i64 = 0; // m x 16 row-major
const B_BASE: i64 = 1024; // 16 x 64 row-major
const C_BASE: i64 = 0; // reuse A region? no — C after B
const C_OFF: i64 = 1024 + (K * P) as i64;

// Ports. In: 0=b(W), 1=a(1), 2=emit gate(1). Out: 0=c(W).
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut g = DfgBuilder::new("gemm", Criticality::Critical);
    let b = g.in_port(0, W);
    let a = g.in_port(1, 1);
    let gate = g.in_port(2, 1);
    let prod = g.node(Op::Mul, &[b, a]);
    let acc = g.node(Op::Acc, &[prod, gate]);
    g.out_gated(0, acc, W, Some(gate));
    let cfg = LaneConfig { name: "gemm".into(), dfgs: vec![g.build()] };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

/// Rows handled per lane for an m-row latency-split across `lanes`.
fn rows_per_lane(m: usize, lanes: usize) -> usize {
    m / lanes
}

/// Program for `rows` rows of A resident per lane (same commands on all
/// masked lanes; each lane's scratchpad holds its own row block).
pub fn program(rows: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let cfg = config(feats)?;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];
    // C streams to memory through one hoisted command (issued first so
    // the output port drains for the whole run).
    p.push(vs(Cmd::LocalSt {
        pat: Pattern2D::lin(C_OFF, (rows * P) as i64),
        port: 0,
        rmw: false,
    }));
    let chunks = P / W;
    for i in 0..rows {
        for jc in 0..chunks {
            // B tile: k rows of the jc-th column chunk (RR stream).
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::rect(
                    B_BASE + (jc * W) as i64,
                    1,
                    W as i64,
                    P as i64,
                    K as i64,
                ),
                port: 0,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            // A row scalars, one per k step.
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(A_BASE + (i * K) as i64, K as i64),
                port: 1,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            // Emit gate: accumulate 15 steps, emit on the 16th.
            p.push(vs(Cmd::ConstSt {
                pat: ConstPattern::last_of_row(1.0, 0.0, K as f64, 1, 0.0),
                port: 2,
            }));
        }
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

pub struct Instance {
    pub a: Mat,
    pub b: Mat,
    pub c_ref: Mat,
}

pub fn instance(m: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(m, K, |i, j| ((i * 7 + j * 3 + seed) as f64 * 0.13).sin());
    let b = Mat::from_fn(K, P, |i, j| ((i * 5 + j + seed) as f64 * 0.29).cos());
    let c_ref = a.matmul(&b);
    Instance { a, b, c_ref }
}

pub fn prepare(m: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let (lanes, rows, problems) = match goal {
        // Latency: one GEMM split row-wise across lanes.
        Goal::Latency => {
            let lanes = if m % 8 == 0 { 8 } else { 4 };
            (lanes, rows_per_lane(m, lanes), 1)
        }
        // Throughput: one full GEMM per lane.
        Goal::Throughput => (8, m, 8),
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(rows, feats, mask)?;
    // C (up to 48x64 words) exceeds the 8KB local SPAD; hardware would
    // stream C to the shared scratchpad — modeled as a larger local.
    let mut mach = crate::sim::Machine::new(crate::sim::SimConfig {
        lanes,
        lane_spad_words: 8192,
        ..Default::default()
    });
    let insts: Vec<Instance> = match goal {
        Goal::Latency => vec![instance(m, 0)],
        Goal::Throughput => (0..lanes).map(|l| instance(m, l)).collect(),
    };
    for l in 0..lanes {
        let inst = &insts[if problems == 1 { 0 } else { l }];
        let row0 = if problems == 1 { l * rows } else { 0 };
        for r in 0..rows {
            for k in 0..K {
                mach.lanes[l]
                    .spad
                    .write(A_BASE + (r * K + k) as i64, inst.a[(row0 + r, k)]);
            }
        }
        for k in 0..K {
            for j in 0..P {
                mach.lanes[l].spad.write(B_BASE + (k * P + j) as i64, inst.b[(k, j)]);
            }
        }
    }
    let verify = Box::new(move |mach: &Machine| {
        let mut max_err = 0.0f64;
        for l in 0..lanes {
            let inst = &insts[if problems == 1 { 0 } else { l }];
            let row0 = if problems == 1 { l * rows } else { 0 };
            for r in 0..rows {
                for j in 0..P {
                    let got = mach.lanes[l].spad.read(C_OFF + (r * P + j) as i64);
                    let want = inst.c_ref[(row0 + r, j)];
                    let err = (got - want).abs();
                    if err > 1e-9 {
                        return Err(format!(
                            "lane {l} C[{r}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
        }
        Ok(max_err)
    });
    let flops = (2 * m * K * P * problems.max(1)) as f64;
    Ok(Prepared { machine: mach, prog, verify, flops, problems })
}

// Silence the unused-constant lint for the aliased base.
const _: i64 = C_BASE;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_correct_all_sizes_latency() {
        for m in [12, 24, 48] {
            prepare(m, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("m={m}: {e}"));
        }
    }

    #[test]
    fn gemm_correct_throughput() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn gemm_hits_high_utilization() {
        // Regular streaming kernel: the fabric should be busy most of the
        // time (paper Fig 1: GEMM reaches 30-80% even on CPUs/DSPs).
        let r = prepare(48, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert!(
            r.stats.utilization() > 0.5,
            "utilization {:.3}",
            r.stats.utilization()
        );
    }
}
