//! Cholesky decomposition (paper Fig 5 / Fig 13 — the running example).
//! In-place right-looking factorization, three dataflow regions:
//!
//! * `point` (non-critical): inva = 1/sqrt(a_kk);
//! * `vector` (critical): l_ik = a_ik * inva, i in [k..n);
//! * `matrix` (critical): a_ij -= l_ik * l_jk over the trailing triangle.
//!
//! Fine-grain ordered dependences (all XFER, no memory round-trip):
//! point -> vector (inva, reused n-k times), and the loop-carried path
//! matrix -> {point, vector}: the *first column* of iteration k's
//! trailing update is exactly iteration k+1's input column, so the
//! matrix dataflow forwards it through two gated outputs (whole column
//! at vector width; first element as the next a_kk). This is the
//! Fig 2(c) region overlap: point/vector of k+1 execute while matrix k
//! is still streaming.
//!
//! Authored against the typed [`crate::vsc`] builder: ports come from
//! [`Ports`] (handles minted by the kernel builder), scratchpad bases
//! from [`Layout`] (the region allocator) — this module contains no
//! hand-written port numbers or base addresses. It doubles as the
//! `docs/VSC_API.md` walkthrough example.

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op};
use crate::isa::{ConstPattern, LaneMask, Pattern2D, Program, Reuse};
use crate::sim::{Machine, SimConfig};
use crate::util::ceil_div;
use crate::util::linalg::{cholesky as chol_ref, Mat};
use crate::vsc::{BuiltKernel, In, Kernel, Out, ProgBuilder, Region, SpadAlloc};

/// Vector width of the critical dataflows.
const W: usize = 8;

/// Typed port handles of the three dataflows. The gated forwards exist
/// only when the fine-grain feature is on.
pub struct Ports {
    /// vector: column of A (width W).
    pub acol: In,
    /// vector: 1/sqrt(a_kk) scalar.
    pub inva: In,
    /// matrix: trailing-block element stream (width W).
    pub a: In,
    /// matrix: l_jk scalar per trailing column.
    pub ci: In,
    /// point: pivot a_kk.
    pub akk: In,
    /// matrix: column-k suffix per trailing column (width W).
    pub cj: In,
    /// matrix: gate for the forwarded first trailing column.
    pub gate_col: Option<In>,
    /// matrix: gate for the forwarded next pivot.
    pub gate_akk: Option<In>,
    /// vector out: the L column.
    pub lcol: Out,
    /// point out: inva.
    pub inva_out: Out,
    /// matrix out: updated trailing elements.
    pub a_upd: Out,
    /// matrix out (gated): first trailing column -> next `acol`.
    pub col_fwd: Option<Out>,
    /// matrix out (gated): first trailing element -> next `akk`.
    pub akk_fwd: Option<Out>,
}

/// Scratchpad regions: the in-place array A (column-major; becomes L in
/// the lower triangle) and the non-fine-grain inva round-trip scratch.
pub struct Layout {
    /// A / L, `n*n` words, column-major.
    pub a: Region,
    /// Per-iteration inva scratch (non-fine-grain ablation only).
    pub tmp: Region,
}

/// A planned kernel instance: frozen builder + compiled config + typed
/// ports + allocated layout.
pub struct Plan {
    built: BuiltKernel,
    /// Compiled (placed + routed) lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("cholesky");

    let mut pt = k.dfg("point", Criticality::NonCritical);
    let akk = pt.input(1);
    let inva = pt.node(Op::Rsqrt, &[akk.wire()]);
    let inva_out = pt.output(inva, 1);
    pt.done();

    let mut v = k.dfg("vector", Criticality::Critical);
    let acol = v.input(W);
    let iv = v.input(1);
    let l = v.node(Op::Mul, &[acol.wire(), iv.wire()]);
    let lcol = v.output(l, W);
    v.done();

    let mut m = k.dfg("matrix", Criticality::Critical);
    let a = m.input(W);
    let ci = m.input(1);
    let cj = m.input(W);
    let prod = m.node(Op::Mul, &[cj.wire(), ci.wire()]);
    let upd = m.node(Op::Sub, &[a.wire(), prod]);
    let a_upd = m.output(upd, W);
    let (gate_col, gate_akk, col_fwd, akk_fwd) = if feats.fine_grain {
        let gcol = m.input(W);
        let gakk = m.input(W);
        let cf = m.output_gated(upd, W, gcol);
        let af = m.output_gated(upd, 1, gakk);
        (Some(gcol), Some(gakk), Some(cf), Some(af))
    } else {
        (None, None, None, None)
    };
    m.done();

    let built = k.build()?;
    let ports = Ports {
        acol,
        inva: iv,
        a,
        ci,
        akk,
        cj,
        gate_col,
        gate_akk,
        lcol,
        inva_out,
        a_upd,
        col_fwd,
        akk_fwd,
    };
    Ok((built, ports))
}

/// Allocate the scratchpad layout for problem size `n`.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let a = al.region("cholesky.A", (n * n) as i64)?;
    let tmp = al.region("cholesky.inva_tmp", n as i64)?;
    Ok(Layout { a, tmp })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Column-major offset of `A[i][j]` inside the A region.
fn at(n: i64, i: i64, j: i64) -> i64 {
    j * n + i
}

/// The trailing-triangle 2D pattern at iteration k: columns j=k+1..n,
/// each covering rows i=j..n (start advances by n+1 per column, length
/// shrinks by one — the RI stream of Fig 10b).
fn trailing(a: &Region, n: i64, k: i64) -> Pattern2D {
    a.inductive(at(n, k + 1, k + 1), 1, (n - k - 1) as f64, n + 1, n - k - 1, -1.0)
}

/// The cj pattern at iteration k: for each trailing column j, the
/// column-k suffix l_ik, i=j..n (same shape as `trailing`, shifted into
/// column k).
fn cj_pat(a: &Region, n: i64, k: i64) -> Pattern2D {
    a.inductive(at(n, k + 1, k), 1, (n - k - 1) as f64, 1, n - k - 1, -1.0)
}

/// Matrix-region gate streams for iteration k (row-aligned with the
/// trailing data): gate_col = ones over the whole first column, zeros
/// after; gate_akk = a single one, zeros after.
fn push_gates(b: &mut ProgBuilder, ports: &Ports, n: i64, k: i64) {
    let first = n - k - 1; // first trailing column length
    let (gcol, gakk) = (ports.gate_col.unwrap(), ports.gate_akk.unwrap());
    b.gate_run(gcol, 1.0, first);
    b.gate_first_of_row(gakk, 1.0, 0.0, first as f64, 1, 0.0);
    if first > 1 {
        // Zeros over the remaining columns (lengths first-1, first-2, ...).
        let zeros = ConstPattern {
            val1: 0.0,
            n1: (first - 1) as f64,
            s1: -1.0,
            val2: 0.0,
            n2: 0.0,
            s2: 0.0,
            n_j: first - 1,
        };
        b.const_st(zeros.clone(), gcol);
        b.const_st(zeros, gakk);
    }
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let plan = plan(n, feats)?;
    let n_i = n as i64;
    let p = &plan.ports;
    let a = &plan.lay.a;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);

    if feats.fine_grain {
        // Bootstrap: iteration 0's inputs from memory.
        b.ld(a.lin(at(n_i, 0, 0), 1), p.akk);
        b.ld(a.lin(at(n_i, 0, 0), n_i), p.acol);
    }

    for k in 0..n_i {
        let len = n_i - k; // column k live length (diagonal included)
        if feats.fine_grain {
            // point -> vector: inva reused for the whole column.
            b.xfer_reuse(p.inva_out, p.inva, 1, Reuse::uniform(len as f64));
        } else {
            // Memory round-trip for every region transition.
            b.barrier();
            b.ld(a.lin(at(n_i, k, k), 1), p.akk);
            b.st(plan.lay.tmp.lin(k, 1), p.inva_out);
            b.barrier();
            b.ld_reuse(plan.lay.tmp.lin(k, 1), p.inva, Reuse::uniform(len as f64));
            b.ld(a.lin(at(n_i, k, k), len), p.acol);
        }
        // L column k lands over A's column k.
        b.st(a.lin(at(n_i, k, k), len), p.lcol);

        if k < n_i - 1 {
            // ---- matrix region ------------------------------------------
            b.barrier();
            if feats.inductive {
                // In-place trailing update: rmw store + lag-0 rmw load
                // (the pair touches disjoint columns row-by-row).
                b.st_rmw(trailing(a, n_i, k), p.a_upd);
                b.ld_rmw(trailing(a, n_i, k), p.a, 0);
                // ci: l_jk scalars, element t reused (n-k-1-t) times.
                b.ld_reuse(
                    a.lin(at(n_i, k + 1, k), n_i - k - 1),
                    p.ci,
                    Reuse { n_r: (n_i - k - 1) as f64, s_r: -1.0 },
                );
                // cj: column-k suffixes per trailing column.
                b.ld(cj_pat(a, n_i, k), p.cj);
            } else {
                // Rectangular-only ISA: one command set per trailing
                // column, interleaved so each column's store follows its
                // load (Fig 11's O(n) decomposition).
                for r in 0..n_i - k - 1 {
                    let col = k + 1 + r;
                    let len = n_i - col;
                    b.ld_reuse(
                        a.lin(at(n_i, col, k), 1),
                        p.ci,
                        Reuse::uniform(len as f64),
                    );
                    b.ld(a.lin(at(n_i, col, col), len), p.a);
                    b.ld(a.lin(at(n_i, col, k), len), p.cj);
                    b.st_rmw(a.lin(at(n_i, col, col), len), p.a_upd);
                    if feats.fine_grain {
                        let g = if r == 0 { 1.0 } else { 0.0 };
                        b.gate_run(p.gate_col.unwrap(), g, len);
                        b.gate_first_of_row(
                            p.gate_akk.unwrap(),
                            g,
                            0.0,
                            len as f64,
                            1,
                            0.0,
                        );
                    }
                }
            }
            if feats.fine_grain {
                if feats.inductive {
                    push_gates(&mut b, p, n_i, k);
                }
                // Forward the first trailing column to iteration k+1.
                b.xfer(
                    p.col_fwd.unwrap(),
                    p.acol,
                    ceil_div((n_i - k - 1) as usize, W) as i64,
                );
                b.xfer(p.akk_fwd.unwrap(), p.akk, 1);
            }
        }
    }
    Ok(b.finish())
}

/// Feature set of every Cholesky *tile* program in the task-graph
/// subsystem ([`crate::taskgraph`]): the tested "+inductive" ladder
/// shape with memory round-trips between dataflow regions. Fine-grain
/// gated forwarding is deliberately off — tile programs are short and
/// rebuilt per task, and the gate-port streams only exist on the
/// fine-grain kernel build.
pub const TILE_FEATS: Features = Features {
    inductive: true,
    fine_grain: false,
    heterogeneous: true,
    masking: true,
};

/// Plan for the `b x b` tile kernels (compile once, relocate per slot).
/// Tile programs built from this plan must use [`TILE_FEATS`].
pub fn tile_plan(b: usize) -> Result<Plan, WlError> {
    plan(b, TILE_FEATS)
}

/// POTRF tile task: factor the diagonal tile held in `target`
/// (column-major `b x b`) in place — the whole [`program`] body at
/// `n = b`, relocated into an arbitrary slot region. `tmp` is the
/// `b`-word inva round-trip scratch.
pub fn tile_potrf_program(
    plan: &Plan,
    b_sz: usize,
    target: Region,
    tmp: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), TILE_FEATS, mask);
    for k in 0..n_i {
        let len = n_i - k;
        b.barrier();
        b.ld(target.lin(at(n_i, k, k), 1), p.akk);
        b.st(tmp.lin(k, 1), p.inva_out);
        b.barrier();
        b.ld_reuse(tmp.lin(k, 1), p.inva, Reuse::uniform(len as f64));
        b.ld(target.lin(at(n_i, k, k), len), p.acol);
        b.st(target.lin(at(n_i, k, k), len), p.lcol);
        if k < n_i - 1 {
            b.barrier();
            b.st_rmw(trailing(&target, n_i, k), p.a_upd);
            b.ld_rmw(trailing(&target, n_i, k), p.a, 0);
            b.ld_reuse(
                target.lin(at(n_i, k + 1, k), n_i - k - 1),
                p.ci,
                Reuse { n_r: (n_i - k - 1) as f64, s_r: -1.0 },
            );
            b.ld(cj_pat(&target, n_i, k), p.cj);
        }
    }
    b.finish()
}

/// TRSM tile task: scale the panel tile `target` (rows of tile `I`,
/// columns of panel `K`) by the factored diagonal tile in `left`, with
/// the same per-pivot trailing update the untiled kernel applies —
/// restricted to `target`'s `b` rows. The point dataflow re-derives the
/// column scale from `left`'s diagonal, so timing matches the untiled
/// region schedule; numerics of record come from the host-side replay
/// ([`crate::taskgraph::exec`]).
pub fn tile_trsm_program(
    plan: &Plan,
    b_sz: usize,
    left: Region,
    target: Region,
    tmp: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), TILE_FEATS, mask);
    for k in 0..n_i {
        let t = n_i - k - 1;
        b.barrier();
        b.ld(left.lin(at(n_i, k, k), 1), p.akk);
        b.st(tmp.lin(k, 1), p.inva_out);
        b.barrier();
        b.ld_reuse(tmp.lin(k, 1), p.inva, Reuse::uniform(n_i as f64));
        b.ld(target.lin(at(n_i, 0, k), n_i), p.acol);
        b.st(target.lin(at(n_i, 0, k), n_i), p.lcol);
        if t > 0 {
            b.barrier();
            let block = target.rect(at(n_i, 0, k + 1), 1, n_i, n_i, t);
            b.st_rmw(block.clone(), p.a_upd);
            b.ld_rmw(block, p.a, 0);
            b.ld_reuse(
                left.lin(at(n_i, k + 1, k), t),
                p.ci,
                Reuse::uniform(n_i as f64),
            );
            b.ld(target.rect(at(n_i, 0, k), 1, n_i, 0, t), p.cj);
        }
    }
    b.finish()
}

/// SYRK/GEMM tile task: `target -= left_colk * right_colk^T` summed
/// over the `b` columns of panel `K` — the trailing update restricted
/// to one `b x b` tile. `left` holds tile `(I, K)`, `right` tile
/// `(J, K)`; a SYRK passes the same region for both. The symmetric
/// (SYRK) case is billed as the full square — a documented ~2x cycle
/// overestimate that applies identically to every schedule.
pub fn tile_gemm_program(
    plan: &Plan,
    b_sz: usize,
    left: Region,
    right: Region,
    target: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), TILE_FEATS, mask);
    for k in 0..n_i {
        b.barrier();
        let block = target.rect(0, 1, n_i, n_i, n_i);
        b.st_rmw(block.clone(), p.a_upd);
        b.ld_rmw(block, p.a, 0);
        b.ld_reuse(
            right.lin(at(n_i, 0, k), n_i),
            p.ci,
            Reuse::uniform(n_i as f64),
        );
        b.ld(left.rect(at(n_i, 0, k), 1, n_i, 0, n_i), p.cj);
    }
    b.finish()
}

/// Problem data for one lane.
pub struct Instance {
    pub a: Mat,
    pub l_ref: Mat,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::spd(n, seed as f64 * 1.3);
    let l_ref = chol_ref(&a);
    Instance { a, l_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    let lay = layout(n).expect("cholesky layout fits the lane scratchpad");
    for j in 0..n {
        for i in 0..n {
            lane.spad
                .write(lay.a.addr(at(n as i64, i as i64, j as i64)), inst.a[(i, j)]);
        }
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let a_region = lay.a;
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows;
            for j in 0..nn {
                for i in j..nn {
                    let got = m.lanes[l]
                        .spad
                        .read(a_region.addr(at(nn as i64, i as i64, j as i64)));
                    let want = inst.l_ref[(i, j)];
                    let err = (got - want).abs();
                    if err > 1e-9 {
                        return Err(format!(
                            "lane {l} L[{i}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * (n * n * n) as f64 / 3.0;
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program_stats;

    #[test]
    fn fgop_cholesky_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            prepare(12, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn fgop_beats_base_substantially() {
        let base = prepare(24, Features::NONE, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let full = prepare(24, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(
            full.cycles * 2 <= base.cycles,
            "FGOP {} vs base {}",
            full.cycles,
            base.cycles
        );
    }

    #[test]
    fn inductive_streams_cut_commands() {
        let ind = program(16, Features::ALL, LaneMask::one(0)).unwrap();
        let no = program(
            16,
            Features { inductive: false, ..Features::ALL },
            LaneMask::one(0),
        )
        .unwrap();
        assert!(
            program_stats(&ind).commands * 5 < program_stats(&no).commands * 2,
            "{} vs {}",
            ind.len(),
            no.len()
        );
    }

    #[test]
    fn throughput_runs_eight_lanes() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        for feats in [Features::ALL, Features::NONE] {
            let prog = program(12, feats, LaneMask::one(0)).unwrap();
            let rep = crate::vsc::check_program(&prog, &SimConfig::default());
            assert!(rep.errors().is_empty(), "{feats:?}:\n{rep}");
        }
    }

    /// Slot regions for tile tests: two operand tiles + target + tmp.
    fn tile_regions(b: usize) -> (Region, Region, Region, Region) {
        let mut al = SpadAlloc::with_capacity(SimConfig::default().lane_spad_words);
        let s0 = al.region("t.s0", (b * b) as i64).unwrap();
        let s1 = al.region("t.s1", (b * b) as i64).unwrap();
        let s2 = al.region("t.s2", (b * b) as i64).unwrap();
        let tmp = al.region("t.tmp", b as i64).unwrap();
        (s0, s1, s2, tmp)
    }

    #[test]
    fn tile_programs_pass_the_vsc_check() {
        for b in [8usize, 16] {
            let plan = tile_plan(b).unwrap();
            let (s0, s1, s2, tmp) = tile_regions(b);
            let mask = LaneMask::one(0);
            for (name, prog) in [
                ("potrf", tile_potrf_program(&plan, b, s0, tmp, mask)),
                ("trsm", tile_trsm_program(&plan, b, s0, s1, tmp, mask)),
                ("gemm", tile_gemm_program(&plan, b, s0, s1, s2, mask)),
                ("syrk", tile_gemm_program(&plan, b, s0, s0, s2, mask)),
            ] {
                let rep = crate::vsc::check_program(&prog, &SimConfig::default());
                assert!(rep.errors().is_empty(), "b={b} {name}:\n{rep}");
            }
        }
    }

    #[test]
    fn potrf_tile_matches_reference_on_the_machine() {
        // The diagonal tile task is a complete b x b factorization, so
        // the simulated result must match the untiled reference — the
        // same 1e-9 bound `prepare` enforces.
        for b in [8usize, 16] {
            let plan = tile_plan(b).unwrap();
            let (s0, _, _, tmp) = tile_regions(b);
            let mask = LaneMask::one(0);
            let prog = tile_potrf_program(&plan, b, s0, tmp, mask);
            let inst = instance(b, 3);
            let mut m = machine(1);
            for j in 0..b {
                for i in 0..b {
                    m.lanes[0].spad.write(
                        s0.addr(at(b as i64, i as i64, j as i64)),
                        inst.a[(i, j)],
                    );
                }
            }
            m.run(prog).unwrap();
            for j in 0..b {
                for i in j..b {
                    let got =
                        m.lanes[0].spad.read(s0.addr(at(b as i64, i as i64, j as i64)));
                    let want = inst.l_ref[(i, j)];
                    assert!(
                        (got - want).abs() < 1e-9,
                        "b={b} L[{i}][{j}]: got {got}, want {want}"
                    );
                }
            }
        }
    }
}
