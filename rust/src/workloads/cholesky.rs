//! Cholesky decomposition (paper Fig 5 / Fig 13 — the running example).
//! In-place right-looking factorization, three dataflow regions:
//!
//! * `point` (non-critical): inva = 1/sqrt(a_kk);
//! * `vector` (critical): l_ik = a_ik * inva, i in [k..n);
//! * `matrix` (critical): a_ij -= l_ik * l_jk over the trailing triangle.
//!
//! Fine-grain ordered dependences (all XFER, no memory round-trip):
//! point -> vector (inva, reused n-k times), and the loop-carried path
//! matrix -> {point, vector}: the *first column* of iteration k's
//! trailing update is exactly iteration k+1's input column, so the
//! matrix dataflow forwards it through two gated outputs (whole column
//! at vector width; first element as the next a_kk). This is the
//! Fig 2(c) region overlap: point/vector of k+1 execute while matrix k
//! is still streaming.

use std::sync::Arc;

use super::{machine, push_ld, push_st, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op};
use crate::isa::{
    Cmd, ConstPattern, LaneMask, Pattern2D, Program, Reuse, VsCommand, XferDst,
};
use crate::sim::Machine;
use crate::util::ceil_div;
use crate::util::linalg::{cholesky as chol_ref, Mat};

/// Vector width of the critical dataflows.
const W: usize = 8;

/// In-place array A (column-major, becomes L in the lower triangle).
const A_BASE: i64 = 0;
/// Scratch for the non-fine-grain inva round-trip.
const TMP_BASE: i64 = 1500;

// Ports. In: 0=acol(W), 1=inva(1), 2=a(W), 3=ci(1), 4=akk(1), 5=cj(W),
// 6=gate_col(W), 7=gate_akk(W).
// Out: 0=lcol, 2=inva, 3=a_upd, 4=col_fwd (gated), 5=akk_fwd (gated).
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut pt = DfgBuilder::new("point", Criticality::NonCritical);
    let akk = pt.in_port(4, 1);
    let inva = pt.node(Op::Rsqrt, &[akk]);
    pt.out(2, inva, 1);

    let mut v = DfgBuilder::new("vector", Criticality::Critical);
    let acol = v.in_port(0, W);
    let iv = v.in_port(1, 1);
    let l = v.node(Op::Mul, &[acol, iv]);
    v.out(0, l, W);

    let mut m = DfgBuilder::new("matrix", Criticality::Critical);
    let a = m.in_port(2, W);
    let ci = m.in_port(3, 1);
    let cj = m.in_port(5, W);
    let prod = m.node(Op::Mul, &[cj, ci]);
    let upd = m.node(Op::Sub, &[a, prod]);
    m.out(3, upd, W);
    if feats.fine_grain {
        let gcol = m.in_port(6, W);
        let gakk = m.in_port(7, W);
        m.out_gated(4, upd, W, Some(gcol));
        m.out_gated(5, upd, 1, Some(gakk));
    }

    let cfg = LaneConfig {
        name: "cholesky".into(),
        dfgs: vec![pt.build(), v.build(), m.build()],
    };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

/// Column-major address of `A[i][j]`.
fn at(n: i64, i: i64, j: i64) -> i64 {
    A_BASE + j * n + i
}

/// The trailing-triangle 2D pattern at iteration k: columns j=k+1..n,
/// each covering rows i=j..n (start advances by n+1 per column, length
/// shrinks by one — the RI stream of Fig 10b).
fn trailing(n: i64, k: i64) -> Pattern2D {
    Pattern2D::inductive(
        at(n, k + 1, k + 1),
        1,
        (n - k - 1) as f64,
        n + 1,
        n - k - 1,
        -1.0,
    )
}

/// The cj pattern at iteration k: for each trailing column j, the
/// column-k suffix l_ik, i=j..n (same shape as `trailing`, shifted into
/// column k).
fn cj_pat(n: i64, k: i64) -> Pattern2D {
    Pattern2D::inductive(at(n, k + 1, k), 1, (n - k - 1) as f64, 1, n - k - 1, -1.0)
}

/// Matrix-region gate streams for iteration k (row-aligned with the
/// trailing data): gate_col = ones over the whole first column, zeros
/// after; gate_akk = a single one, zeros after.
fn push_gates(p: &mut Program, mask: LaneMask, n: i64, k: i64) {
    let first = n - k - 1; // first trailing column length
    let vs = |c: Cmd| VsCommand::new(c, mask);
    p.push(vs(Cmd::ConstSt {
        pat: ConstPattern {
            val1: 1.0,
            n1: first as f64,
            s1: 0.0,
            val2: 0.0,
            n2: 0.0,
            s2: 0.0,
            n_j: 1,
        },
        port: 6,
    }));
    p.push(vs(Cmd::ConstSt {
        pat: ConstPattern::first_of_row(1.0, 0.0, first as f64, 1, 0.0),
        port: 7,
    }));
    if first > 1 {
        // Zeros over the remaining columns (lengths first-1, first-2, ...).
        let zeros = ConstPattern {
            val1: 0.0,
            n1: (first - 1) as f64,
            s1: -1.0,
            val2: 0.0,
            n2: 0.0,
            s2: 0.0,
            n_j: first - 1,
        };
        p.push(vs(Cmd::ConstSt { pat: zeros.clone(), port: 6 }));
        p.push(vs(Cmd::ConstSt { pat: zeros, port: 7 }));
    }
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let cfg = config(feats)?;
    let n_i = n as i64;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];

    if feats.fine_grain {
        // Bootstrap: iteration 0's inputs from memory.
        push_ld(&mut p, mask, Pattern2D::lin(at(n_i, 0, 0), 1), 4, None, feats, None);
        push_ld(&mut p, mask, Pattern2D::lin(at(n_i, 0, 0), n_i), 0, None, feats, None);
    }

    for k in 0..n_i {
        let len = n_i - k; // column k live length (diagonal included)
        if feats.fine_grain {
            // point -> vector: inva reused for the whole column.
            p.push(vs(Cmd::Xfer {
                src_port: 2,
                dst_port: 1,
                dst: XferDst::Local,
                n: 1,
                reuse: Some(Reuse::uniform(len as f64)),
            }));
        } else {
            // Memory round-trip for every region transition.
            p.push(vs(Cmd::Barrier));
            push_ld(&mut p, mask, Pattern2D::lin(at(n_i, k, k), 1), 4, None, feats, None);
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(TMP_BASE + k, 1),
                port: 2,
                rmw: false,
            }));
            p.push(vs(Cmd::Barrier));
            push_ld(
                &mut p,
                mask,
                Pattern2D::lin(TMP_BASE + k, 1),
                1,
                Some(Reuse::uniform(len as f64)),
                feats,
                None,
            );
            push_ld(&mut p, mask, Pattern2D::lin(at(n_i, k, k), len), 0, None, feats, None);
        }
        // L column k lands over A's column k.
        push_st(&mut p, mask, Pattern2D::lin(at(n_i, k, k), len), 0, false, feats);

        if k < n_i - 1 {
            // ---- matrix region ------------------------------------------
            p.push(vs(Cmd::Barrier));
            if feats.inductive {
                // In-place trailing update: rmw store + lag-0 rmw load
                // (the pair touches disjoint columns row-by-row).
                push_st(&mut p, mask, trailing(n_i, k), 3, true, feats);
                push_ld(&mut p, mask, trailing(n_i, k), 2, None, feats, Some(0));
                // ci: l_jk scalars, element t reused (n-k-1-t) times.
                push_ld(
                    &mut p,
                    mask,
                    Pattern2D::lin(at(n_i, k + 1, k), n_i - k - 1),
                    3,
                    Some(Reuse { n_r: (n_i - k - 1) as f64, s_r: -1.0 }),
                    feats,
                    None,
                );
                // cj: column-k suffixes per trailing column.
                push_ld(&mut p, mask, cj_pat(n_i, k), 5, None, feats, None);
            } else {
                // Rectangular-only ISA: one command set per trailing
                // column, interleaved so each column's store follows its
                // load (Fig 11's O(n) decomposition).
                for r in 0..n_i - k - 1 {
                    let col = k + 1 + r;
                    let len = n_i - col;
                    push_ld(
                        &mut p,
                        mask,
                        Pattern2D::lin(at(n_i, col, k), 1),
                        3,
                        Some(Reuse::uniform(len as f64)),
                        feats,
                        None,
                    );
                    push_ld(
                        &mut p,
                        mask,
                        Pattern2D::lin(at(n_i, col, col), len),
                        2,
                        None,
                        feats,
                        None,
                    );
                    push_ld(
                        &mut p,
                        mask,
                        Pattern2D::lin(at(n_i, col, k), len),
                        5,
                        None,
                        feats,
                        None,
                    );
                    push_st(
                        &mut p,
                        mask,
                        Pattern2D::lin(at(n_i, col, col), len),
                        3,
                        true,
                        feats,
                    );
                    if feats.fine_grain {
                        let g = if r == 0 { 1.0 } else { 0.0 };
                        p.push(vs(Cmd::ConstSt {
                            pat: ConstPattern {
                                val1: g,
                                n1: len as f64,
                                s1: 0.0,
                                val2: 0.0,
                                n2: 0.0,
                                s2: 0.0,
                                n_j: 1,
                            },
                            port: 6,
                        }));
                        p.push(vs(Cmd::ConstSt {
                            pat: ConstPattern::first_of_row(g, 0.0, len as f64, 1, 0.0),
                            port: 7,
                        }));
                    }
                }
            }
            if feats.fine_grain {
                if feats.inductive {
                    push_gates(&mut p, mask, n_i, k);
                }
                // Forward the first trailing column to iteration k+1.
                p.push(vs(Cmd::Xfer {
                    src_port: 4,
                    dst_port: 0,
                    dst: XferDst::Local,
                    n: ceil_div((n_i - k - 1) as usize, W) as i64,
                    reuse: None,
                }));
                p.push(vs(Cmd::Xfer {
                    src_port: 5,
                    dst_port: 4,
                    dst: XferDst::Local,
                    n: 1,
                    reuse: None,
                }));
            }
        }
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

/// Problem data for one lane.
pub struct Instance {
    pub a: Mat,
    pub l_ref: Mat,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::spd(n, seed as f64 * 1.3);
    let l_ref = chol_ref(&a);
    Instance { a, l_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    for j in 0..n {
        for i in 0..n {
            lane.spad.write(at(n as i64, i as i64, j as i64), inst.a[(i, j)]);
        }
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows;
            for j in 0..nn {
                for i in j..nn {
                    let got =
                        m.lanes[l].spad.read(at(nn as i64, i as i64, j as i64));
                    let want = inst.l_ref[(i, j)];
                    let err = (got - want).abs();
                    if err > 1e-9 {
                        return Err(format!(
                            "lane {l} L[{i}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * (n * n * n) as f64 / 3.0;
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program_stats;

    #[test]
    fn fgop_cholesky_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            prepare(12, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn fgop_beats_base_substantially() {
        let base = prepare(24, Features::NONE, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let full = prepare(24, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(
            full.cycles * 2 <= base.cycles,
            "FGOP {} vs base {}",
            full.cycles,
            base.cycles
        );
    }

    #[test]
    fn inductive_streams_cut_commands() {
        let ind = program(16, Features::ALL, LaneMask::one(0)).unwrap();
        let no = program(
            16,
            Features { inductive: false, ..Features::ALL },
            LaneMask::one(0),
        )
        .unwrap();
        assert!(
            program_stats(&ind).commands * 5 < program_stats(&no).commands * 2,
            "{} vs {}",
            ind.len(),
            no.len()
        );
    }

    #[test]
    fn throughput_runs_eight_lanes() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }
}
