//! Centro-symmetric FIR filter (paper "Centro-FIR", Table 5): taps are
//! symmetric (`h[j] = h[m-1-j]`), so the kernel folds the window:
//!
//!   `y[i] = sum_{j < m/2} h[j] * (x[i+j] + x[i+m-1-j])`
//!
//! halving the multiplies. One accumulating dataflow over output chunks:
//! the two window streams walk toward each other (the second with a
//! negative outer stride), the tap scalar broadcasts across lanes, and
//! the accumulator emits after m/2 steps. Built on the typed
//! [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op};
use crate::isa::{LaneMask, Program};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::fir as fir_ref;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

/// Vector width (one output chunk per accumulation group).
const W: usize = 8;
/// Output samples (matches the AOT artifacts: input = 64 + m - 1).
pub const N_OUT: usize = 64;

/// Typed port handles of the folded-window dataflow.
pub struct Ports {
    /// Forward half-window stream (width W).
    pub xa: In,
    /// Backward half-window stream (width W).
    pub xb: In,
    /// Tap scalar per accumulation step.
    pub h: In,
    /// Accumulator emit gate.
    pub gate: In,
    /// Output chunks (gated).
    pub y: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// Input samples, `N_OUT + m - 1` words.
    pub x: Region,
    /// Taps, m words.
    pub h: Region,
    /// Outputs, `N_OUT` words.
    pub y: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("fir");
    let mut f = k.dfg("fir", Criticality::Critical);
    let xa = f.input(W);
    let xb = f.input(W);
    let h = f.input(1);
    let gate = f.input(1);
    let s = f.node(Op::Add, &[xa.wire(), xb.wire()]);
    let prod = f.node(Op::Mul, &[s, h.wire()]);
    let acc = f.node(Op::Acc, &[prod, gate.wire()]);
    let y = f.output_gated(acc, W, gate);
    f.done();
    let built = k.build()?;
    Ok((built, Ports { xa, xb, h, gate, y }))
}

/// Allocate the scratchpad layout for tap count `m`.
pub fn layout(m: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let x = al.region("fir.x", (N_OUT + m - 1) as i64)?;
    let h = al.region("fir.h", m as i64)?;
    let y = al.region("fir.y", N_OUT as i64)?;
    Ok(Layout { x, h, y })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(m: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(m)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Program computing `chunks` output chunks per lane, tap count m (even).
pub fn program(
    m: usize,
    chunks: usize,
    feats: Features,
    mask: LaneMask,
    lane_stride: i64,
) -> Result<Program, WlError> {
    assert!(m % 2 == 0, "centro-symmetric fold needs even tap count");
    let plan = plan(m, feats)?;
    let half = (m / 2) as i64;
    let p = &plan.ports;
    let lay = &plan.lay;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);
    // Hoisted emit gate (one emission per chunk) and output stream,
    // issued first so they serve the whole run.
    b.gate_last_of_row(p.gate, 1.0, 0.0, half as f64, chunks as i64, 0.0);
    b.st_strided_lanes(lay.y.lin(0, (chunks * W) as i64), p.y, lane_stride);
    for ic in 0..chunks as i64 {
        let x0 = ic * W as i64;
        // Forward half-window walk: row j covers x[i + j].
        b.ld_strided_lanes(lay.x.rect(x0, 1, W as i64, 1, half), p.xa, lane_stride);
        // Backward half-window walk: row j covers x[i + m-1-j].
        b.ld_strided_lanes(
            lay.x.rect(x0 + m as i64 - 1, 1, W as i64, -1, half),
            p.xb,
            lane_stride,
        );
        // Taps, one scalar per accumulation step.
        b.ld(lay.h.lin(0, half), p.h);
    }
    Ok(b.finish())
}

pub struct Instance {
    pub x: Vec<f64>,
    pub h: Vec<f64>,
    pub y_ref: Vec<f64>,
}

pub fn instance(m: usize, seed: usize) -> Instance {
    let x: Vec<f64> =
        (0..N_OUT + m - 1).map(|i| ((i + seed * 3) as f64 * 0.21).sin()).collect();
    // Centro-symmetric taps.
    let mut h = vec![0.0; m];
    for j in 0..m / 2 {
        let v = ((j + 1 + seed) as f64 * 0.4).cos() * 0.3;
        h[j] = v;
        h[m - 1 - j] = v;
    }
    let y_ref = fir_ref(&x, &h);
    Instance { x, h, y_ref }
}

pub fn prepare(m: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = 8;
    let mask = LaneMask::first_n(lanes);
    let chunks_total = N_OUT / W;
    let (chunks, stride, problems) = match goal {
        // Latency: one filter, output chunks split across lanes.
        Goal::Latency => (chunks_total / lanes, (chunks_total / lanes * W) as i64, 1),
        // Throughput: a full filter per lane.
        Goal::Throughput => (chunks_total, 0, lanes),
    };
    let prog = program(m, chunks, feats, mask, stride)?;
    let lay = layout(m)?;
    let mut mach = machine(lanes);
    let insts: Vec<Instance> = match goal {
        Goal::Latency => vec![instance(m, 0)],
        Goal::Throughput => (0..lanes).map(|l| instance(m, l)).collect(),
    };
    for l in 0..lanes {
        let inst = &insts[if problems == 1 { 0 } else { l }];
        mach.lanes[l].spad.load_slice(lay.x.base(), &inst.x);
        mach.lanes[l].spad.load_slice(lay.h.base(), &inst.h);
    }
    let y_region = lay.y;
    let verify = Box::new(move |mach: &Machine| {
        let mut max_err = 0.0f64;
        for l in 0..lanes {
            let inst = &insts[if problems == 1 { 0 } else { l }];
            for c in 0..chunks * W {
                let (y_idx, off) = if problems == 1 {
                    (l * chunks * W + c, (l * chunks * W + c) as i64)
                } else {
                    (c, c as i64)
                };
                let got = mach.lanes[l].spad.read(y_region.addr(off));
                let want = inst.y_ref[y_idx];
                let err = (got - want).abs();
                if err > 1e-9 {
                    return Err(format!("lane {l} y[{y_idx}]: got {got}, want {want}"));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    let flops = (3 * N_OUT * m / 2 * problems.max(1)) as f64;
    Ok(Prepared { machine: mach, prog, verify, flops, problems })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fir_correct_all_sizes() {
        for m in [12, 16, 24, 32] {
            for goal in [Goal::Latency, Goal::Throughput] {
                prepare(m, Features::ALL, goal)
                    .unwrap()
                    .execute()
                    .unwrap_or_else(|e| panic!("m={m} {goal:?}: {e}"));
            }
        }
    }

    #[test]
    fn latency_split_beats_single_lane_throughput_time() {
        // 8 lanes sharing one filter finish faster than one lane doing
        // the full filter.
        let lat = prepare(32, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let thr = prepare(32, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert!(lat.cycles < thr.cycles, "{} vs {}", lat.cycles, thr.cycles);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        let prog = program(16, 1, Features::ALL, LaneMask::first_n(8), 8).unwrap();
        let rep = crate::vsc::check_program(&prog, &SimConfig::default());
        assert!(rep.errors().is_empty(), "{rep}");
    }
}
