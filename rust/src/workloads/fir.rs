//! Centro-symmetric FIR filter (paper "Centro-FIR", Table 5): taps are
//! symmetric (`h[j] = h[m-1-j]`), so the kernel folds the window:
//!
//!   `y[i] = sum_{j < m/2} h[j] * (x[i+j] + x[i+m-1-j])`
//!
//! halving the multiplies. One accumulating dataflow over output chunks:
//! the two window streams walk toward each other (the second with a
//! negative outer stride), the tap scalar broadcasts across lanes, and
//! the accumulator emits after m/2 steps.

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op};
use crate::isa::{Cmd, ConstPattern, LaneMask, Pattern2D, Program, VsCommand};
use crate::sim::Machine;
use crate::util::linalg::fir as fir_ref;

/// Vector width (one output chunk per accumulation group).
const W: usize = 8;
/// Output samples (matches the AOT artifacts: input = 64 + m - 1).
pub const N_OUT: usize = 64;

const X_BASE: i64 = 0;
const H_BASE: i64 = 256;
const Y_BASE: i64 = 320;

// Ports. In: 0=xa(W), 1=xb(W), 2=h(1), 3=emit gate(1). Out: 0=y(W).
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut f = DfgBuilder::new("fir", Criticality::Critical);
    let xa = f.in_port(0, W);
    let xb = f.in_port(1, W);
    let h = f.in_port(2, 1);
    let gate = f.in_port(3, 1);
    let s = f.node(Op::Add, &[xa, xb]);
    let prod = f.node(Op::Mul, &[s, h]);
    let acc = f.node(Op::Acc, &[prod, gate]);
    f.out_gated(0, acc, W, Some(gate));
    let cfg = LaneConfig { name: "fir".into(), dfgs: vec![f.build()] };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

/// Program computing `chunks` output chunks per lane, tap count m (even).
pub fn program(
    m: usize,
    chunks: usize,
    feats: Features,
    mask: LaneMask,
    lane_stride: i64,
) -> Result<Program, WlError> {
    assert!(m % 2 == 0, "centro-symmetric fold needs even tap count");
    let cfg = config(feats)?;
    let half = (m / 2) as i64;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];
    // Hoisted emit gate (one emission per chunk) and output stream,
    // issued first so they serve the whole run.
    p.push(vs(Cmd::ConstSt {
        pat: ConstPattern::last_of_row(1.0, 0.0, half as f64, chunks as i64, 0.0),
        port: 3,
    }));
    p.push(VsCommand::with_stride(
        Cmd::LocalSt {
            pat: Pattern2D::lin(Y_BASE, (chunks * W) as i64),
            port: 0,
            rmw: false,
        },
        mask,
        lane_stride,
    ));
    for ic in 0..chunks as i64 {
        let x0 = X_BASE + ic * W as i64;
        // Forward half-window walk: row j covers x[i + j].
        p.push(VsCommand::with_stride(
            Cmd::LocalLd {
                pat: Pattern2D::rect(x0, 1, W as i64, 1, half),
                port: 0,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            },
            mask,
            lane_stride,
        ));
        // Backward half-window walk: row j covers x[i + m-1-j].
        p.push(VsCommand::with_stride(
            Cmd::LocalLd {
                pat: Pattern2D::rect(x0 + m as i64 - 1, 1, W as i64, -1, half),
                port: 1,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            },
            mask,
            lane_stride,
        ));
        // Taps, one scalar per accumulation step.
        p.push(vs(Cmd::LocalLd {
            pat: Pattern2D::lin(H_BASE, half),
            port: 2,
            reuse: None,
            masked: feats.masking,
            rmw: None,
        }));
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

pub struct Instance {
    pub x: Vec<f64>,
    pub h: Vec<f64>,
    pub y_ref: Vec<f64>,
}

pub fn instance(m: usize, seed: usize) -> Instance {
    let x: Vec<f64> =
        (0..N_OUT + m - 1).map(|i| ((i + seed * 3) as f64 * 0.21).sin()).collect();
    // Centro-symmetric taps.
    let mut h = vec![0.0; m];
    for j in 0..m / 2 {
        let v = ((j + 1 + seed) as f64 * 0.4).cos() * 0.3;
        h[j] = v;
        h[m - 1 - j] = v;
    }
    let y_ref = fir_ref(&x, &h);
    Instance { x, h, y_ref }
}

pub fn prepare(m: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = 8;
    let mask = LaneMask::first_n(lanes);
    let chunks_total = N_OUT / W;
    let (chunks, stride, problems) = match goal {
        // Latency: one filter, output chunks split across lanes.
        Goal::Latency => (chunks_total / lanes, (chunks_total / lanes * W) as i64, 1),
        // Throughput: a full filter per lane.
        Goal::Throughput => (chunks_total, 0, lanes),
    };
    let prog = program(m, chunks, feats, mask, stride)?;
    let mut mach = machine(lanes);
    let insts: Vec<Instance> = match goal {
        Goal::Latency => vec![instance(m, 0)],
        Goal::Throughput => (0..lanes).map(|l| instance(m, l)).collect(),
    };
    for l in 0..lanes {
        let inst = &insts[if problems == 1 { 0 } else { l }];
        mach.lanes[l].spad.load_slice(X_BASE, &inst.x);
        mach.lanes[l].spad.load_slice(H_BASE, &inst.h);
    }
    let verify = Box::new(move |mach: &Machine| {
        let mut max_err = 0.0f64;
        for l in 0..lanes {
            let inst = &insts[if problems == 1 { 0 } else { l }];
            for c in 0..chunks * W {
                let (y_idx, addr) = if problems == 1 {
                    (l * chunks * W + c, Y_BASE + (l * chunks * W + c) as i64)
                } else {
                    (c, Y_BASE + c as i64)
                };
                let got = mach.lanes[l].spad.read(addr);
                let want = inst.y_ref[y_idx];
                let err = (got - want).abs();
                if err > 1e-9 {
                    return Err(format!("lane {l} y[{y_idx}]: got {got}, want {want}"));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    let flops = (3 * N_OUT * m / 2 * problems.max(1)) as f64;
    Ok(Prepared { machine: mach, prog, verify, flops, problems })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fir_correct_all_sizes() {
        for m in [12, 16, 24, 32] {
            for goal in [Goal::Latency, Goal::Throughput] {
                prepare(m, Features::ALL, goal)
                    .unwrap()
                    .execute()
                    .unwrap_or_else(|e| panic!("m={m} {goal:?}: {e}"));
            }
        }
    }

    #[test]
    fn latency_split_beats_single_lane_throughput_time() {
        // 8 lanes sharing one filter finish faster than one lane doing
        // the full filter.
        let lat = prepare(32, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let thr = prepare(32, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert!(lat.cycles < thr.cycles, "{} vs {}", lat.cycles, thr.cycles);
    }
}
