//! The paper's workloads (Table 5's seven DSP kernels plus Table 4's
//! LU) expressed as REVEL programs: dataflow graphs + vector-stream
//! control programs, in latency- and throughput-optimized versions,
//! with per-feature ablation switches that generate the five mechanism
//! levels of Fig 19.
//!
//! Every workload is authored against the typed [`crate::vsc`] builder
//! layer: port handles come from the kernel builder, scratchpad bases
//! from the [`crate::vsc::SpadAlloc`] region allocator — no hand-written
//! port numbers or base addresses anywhere in this tree.
//!
//! Every workload is *functionally simulated*: the build step loads real
//! input data into the machine's scratchpads, and `RunOutcome::verify`
//! checks the simulated results against the `util::linalg` reference
//! (tests additionally cross-check against the PJRT golden model).

pub mod cholesky;
pub mod fft;
pub mod fir;
pub mod gemm;
pub mod lu;
pub mod qr;
pub mod solver;
pub mod svd;

use crate::compiler::{CompileError, CompileOptions, FabricSpec, PlaceStrategy};
use crate::isa::Program;
use crate::sim::{Machine, SimConfig, SimError, Stats};

/// FGOP feature switches (paper Fig 19's incremental mechanism ladder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Features {
    /// Inductive (RI) streams + inductive reuse. Off = every inductive
    /// pattern is decomposed into per-row rectangular commands (Fig 11).
    pub inductive: bool,
    /// Fine-grain ordered dependences via XFER. Off = dataflows
    /// communicate through the scratchpad with barriers between regions.
    pub fine_grain: bool,
    /// Heterogeneous fabric. Off = non-critical dataflows serialize on
    /// shared dedicated resources.
    pub heterogeneous: bool,
    /// Implicit vector masking. Off = partial vectors scalarize.
    pub masking: bool,
}

impl Features {
    pub const ALL: Features = Features {
        inductive: true,
        fine_grain: true,
        heterogeneous: true,
        masking: true,
    };
    pub const NONE: Features = Features {
        inductive: false,
        fine_grain: false,
        heterogeneous: false,
        masking: false,
    };

    /// The five incremental versions of Fig 19, in order:
    /// base dataflow/vector-stream -> +inductive -> +fine-grain deps ->
    /// +heterogeneous fabric -> +implicit masking.
    pub fn ladder() -> [(&'static str, Features); 5] {
        [
            ("base", Features::NONE),
            ("+inductive", Features { inductive: true, ..Features::NONE }),
            (
                "+fine-grain",
                Features {
                    inductive: true,
                    fine_grain: true,
                    ..Features::NONE
                },
            ),
            (
                "+hetero",
                Features {
                    inductive: true,
                    fine_grain: true,
                    heterogeneous: true,
                    masking: false,
                },
            ),
            ("+masking", Features::ALL),
        ]
    }

    pub fn compile_opts(&self) -> CompileOptions {
        CompileOptions { heterogeneous: self.heterogeneous, ..Default::default() }
    }
}

impl Default for Features {
    fn default() -> Self {
        Features::ALL
    }
}

/// Latency-optimized (single problem, possibly spread across lanes) or
/// throughput-optimized (data-parallel problems across all lanes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Goal {
    Latency,
    Throughput,
}

/// Errors surfaced while building or running a workload.
#[derive(Debug)]
pub enum WlError {
    /// Kernel/layout construction failed (vsc builder or allocator).
    Build(String),
    Compile(CompileError),
    Sim(SimError),
    Verify(String),
}

impl std::fmt::Display for WlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WlError::Build(s) => write!(f, "build: {s}"),
            WlError::Compile(e) => write!(f, "compile: {e}"),
            WlError::Sim(e) => write!(f, "sim: {e}"),
            WlError::Verify(s) => write!(f, "verify: {s}"),
        }
    }
}

impl std::error::Error for WlError {}

impl From<CompileError> for WlError {
    fn from(e: CompileError) -> Self {
        WlError::Compile(e)
    }
}

impl From<SimError> for WlError {
    fn from(e: SimError) -> Self {
        WlError::Sim(e)
    }
}

impl From<crate::vsc::AllocError> for WlError {
    fn from(e: crate::vsc::AllocError) -> Self {
        WlError::Build(e.to_string())
    }
}

impl From<String> for WlError {
    fn from(e: String) -> Self {
        WlError::Build(e)
    }
}

/// A fully prepared run: machine with data preloaded + control program +
/// a verifier over the machine's final state.
pub struct Prepared {
    pub machine: Machine,
    pub prog: Program,
    /// Checks simulated outputs against the reference; returns max |err|.
    /// `Send + Sync` so a prepared run can migrate to a pool worker —
    /// the sharded co-simulation advances live machines on pool threads.
    pub verify: Box<dyn Fn(&Machine) -> Result<f64, String> + Send + Sync>,
    /// Useful FLOPs of the kernel (for utilization metrics).
    pub flops: f64,
    /// Problems solved in this run (8 for throughput versions).
    pub problems: usize,
}

/// Result of executing a prepared run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub cycles: u64,
    pub stats: Stats,
    pub max_err: f64,
    pub flops: f64,
    pub problems: usize,
}

impl RunOutcome {
    /// FLOPs per cycle across the whole unit.
    pub fn flops_per_cycle(&self) -> f64 {
        self.flops / self.cycles.max(1) as f64
    }
}

impl Prepared {
    pub fn execute(mut self) -> Result<RunOutcome, WlError> {
        self.machine.run(self.prog)?;
        let max_err =
            (self.verify)(&self.machine).map_err(WlError::Verify)?;
        Ok(RunOutcome {
            cycles: self.machine.stats.cycles,
            stats: self.machine.stats.clone(),
            max_err,
            flops: self.flops,
            problems: self.problems,
        })
    }
}

/// Default machine for a workload run. The watchdog uses the process
/// budget ([`crate::sim::max_cycles_budget`]) so the harness can raise
/// it for legitimately long ablation runs without the library ever
/// reading the environment.
pub fn machine(lanes: usize) -> Machine {
    Machine::new(SimConfig {
        lanes,
        max_cycles: crate::sim::max_cycles_budget(),
        ..Default::default()
    })
}

thread_local! {
    static FABRIC_OVERRIDE: std::cell::RefCell<Option<FabricSpec>> =
        const { std::cell::RefCell::new(None) };
    static PLACE_OVERRIDE: std::cell::Cell<Option<PlaceStrategy>> =
        const { std::cell::Cell::new(None) };
}

/// Spatial compilation is deterministic in (kernel, features, fabric,
/// placement strategy): memoize the compiled configuration so repeated
/// `prepare` calls (the benches re-run workloads hundreds of times)
/// skip the placer.
static CONFIG_CACHE: std::sync::Mutex<
    Option<std::collections::HashMap<ConfigKey, std::sync::Arc<crate::compiler::Configured>>>,
> = std::sync::Mutex::new(None);

/// Cache key: (kernel, feature bits, temporal tiles, total tiles,
/// placement-strategy discriminant).
type ConfigKey = (String, u8, usize, usize, u8);

fn config_key(kernel: &str, feats: Features, f: &FabricSpec) -> ConfigKey {
    let bits = (feats.inductive as u8)
        | (feats.fine_grain as u8) << 1
        | (feats.heterogeneous as u8) << 2
        | (feats.masking as u8) << 3;
    let strat = match place_strategy() {
        PlaceStrategy::Greedy => 0u8,
        PlaceStrategy::Negotiated => 1u8,
    };
    (kernel.to_string(), bits, f.temporal_tiles(), f.num_tiles(), strat)
}

/// Memoized [`crate::compiler::Configured::new`] over the current fabric.
pub fn cached_config(
    kernel: &str,
    feats: Features,
    build: impl FnOnce() -> Result<crate::dataflow::LaneConfig, WlError>,
) -> Result<std::sync::Arc<crate::compiler::Configured>, WlError> {
    let f = fabric();
    let key = config_key(kernel, feats, &f);
    {
        let g = CONFIG_CACHE.lock().unwrap();
        if let Some(map) = g.as_ref() {
            if let Some(c) = map.get(&key) {
                return Ok(c.clone());
            }
        }
    }
    let mut opts = feats.compile_opts();
    opts.strategy = place_strategy();
    let cfg = crate::compiler::Configured::new(build()?, &f, &opts)?;
    let mut g = CONFIG_CACHE.lock().unwrap();
    g.get_or_insert_with(Default::default).insert(key, cfg.clone());
    Ok(cfg)
}

/// Look up an already-compiled configuration without building (the
/// harness peeks at placement metrics after a run; `prepare` has
/// populated the cache by then). The solver kernel is cached under its
/// feature-dependent name.
pub fn peek_config(
    kernel: &str,
    feats: Features,
) -> Option<std::sync::Arc<crate::compiler::Configured>> {
    let name = match kernel {
        "solver" if !feats.fine_grain => "solver_nofg",
        k => k,
    };
    let key = config_key(name, feats, &fabric());
    let g = CONFIG_CACHE.lock().unwrap();
    g.as_ref()?.get(&key).cloned()
}

/// Override the fabric used when compiling workload configs on this
/// thread (Fig 20's temporal-region sensitivity sweep). Pass None to
/// restore the Table 3 default.
pub fn set_fabric(f: Option<FabricSpec>) {
    FABRIC_OVERRIDE.with(|c| *c.borrow_mut() = f);
}

/// Fabric used for compiling workload configs (Table 3 default unless
/// overridden via [`set_fabric`]).
pub fn fabric() -> FabricSpec {
    FABRIC_OVERRIDE
        .with(|c| c.borrow().clone())
        .unwrap_or_else(FabricSpec::default_revel)
}

/// Override the placement strategy used when compiling workload configs
/// on this thread (`revel place --strategy`, A/B property tests). Pass
/// None to restore the default (negotiated).
pub fn set_place_strategy(s: Option<PlaceStrategy>) {
    PLACE_OVERRIDE.with(|c| c.set(s));
}

/// Placement strategy workload configs compile with (negotiated unless
/// overridden via [`set_place_strategy`]).
pub fn place_strategy() -> PlaceStrategy {
    PLACE_OVERRIDE.with(|c| c.get()).unwrap_or(PlaceStrategy::Negotiated)
}

/// The registry of workload names in paper order (Table 4's LU joins
/// the seven Table 5 kernels).
pub const NAMES: [&str; 8] =
    ["svd", "qr", "cholesky", "lu", "solver", "fft", "gemm", "fir"];

/// Paper Table 5 data sizes per workload (small..large).
pub fn sizes(name: &str) -> Vec<usize> {
    match name {
        "svd" | "qr" | "cholesky" | "lu" | "solver" | "fir" => vec![12, 16, 24, 32],
        "fft" => vec![64, 128, 1024],
        "gemm" => vec![12, 24, 48],
        _ => panic!("unknown workload {name}"),
    }
}

/// Whether a workload exhibits FGOP (paper Table 5 "Dep" column).
pub fn is_fgop(name: &str) -> bool {
    matches!(name, "svd" | "qr" | "cholesky" | "lu" | "solver")
}

/// Build a prepared run by workload name.
pub fn prepare(
    name: &str,
    n: usize,
    feats: Features,
    goal: Goal,
) -> Result<Prepared, WlError> {
    match name {
        "cholesky" => cholesky::prepare(n, feats, goal),
        "lu" => lu::prepare(n, feats, goal),
        "solver" => solver::prepare(n, feats, goal),
        "qr" => qr::prepare(n, feats, goal),
        "svd" => svd::prepare(n, feats, goal),
        "gemm" => gemm::prepare(n, feats, goal),
        "fir" => fir::prepare(n, feats, goal),
        "fft" => fft::prepare(n, feats, goal),
        _ => panic!("unknown workload {name}"),
    }
}

/// Fig 11's per-row decomposition, re-exported for the ablation tests
/// (the typed builder applies it automatically; see
/// [`crate::vsc::ProgBuilder::ld_opts`]).
pub use crate::isa::decompose_rows;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_monotone_in_features() {
        let l = Features::ladder();
        assert_eq!(l[0].1, Features::NONE);
        assert_eq!(l[4].1, Features::ALL);
        // Each step only adds features.
        let as_bits = |f: Features| {
            [f.inductive, f.fine_grain, f.heterogeneous, f.masking]
                .iter()
                .filter(|&&b| b)
                .count()
        };
        for w in l.windows(2) {
            assert!(as_bits(w[1].1) == as_bits(w[0].1) + 1);
        }
    }

    #[test]
    fn decompose_covers_same_addresses() {
        let p = crate::isa::Pattern2D::inductive(5, 1, 6.0, 10, 5, -1.0);
        let want: Vec<i64> = p.iter().map(|(a, _)| a).collect();
        let got: Vec<i64> = decompose_rows(&p)
            .iter()
            .flat_map(|r| r.iter().map(|(a, _)| a).collect::<Vec<_>>())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn sizes_and_registry_consistent() {
        for n in NAMES {
            assert!(!sizes(n).is_empty());
        }
        assert!(is_fgop("cholesky") && !is_fgop("gemm"));
    }
}
