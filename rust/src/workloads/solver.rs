//! Triangular linear solver (paper Fig 2 / Fig 9): solve L x = b by
//! forward substitution. The canonical FGOP kernel:
//!
//! * two dataflows — `div` (non-critical: one divide per outer
//!   iteration) and `update` (critical, vectorized: b -= l * x);
//! * ordered dependences both ways: x_j from div feeds update with an
//!   inductively shrinking reuse (n-1-j), and the first element of each
//!   update row feeds the next div (loop-carried);
//! * inductive memory streams over the shrinking triangular domain;
//! * implicit masking of the non-width-divisible rows.
//!
//! With all FGOP features the whole kernel is ~11 control commands
//! (paper Fig 11); the ablations decompose streams per-row and/or
//! round-trip the fine-grain values through the scratchpad.

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op};
use crate::isa::{
    Cmd, ConstPattern, LaneMask, Pattern2D, Program, Reuse, VsCommand, XferDst,
};
use crate::util::linalg::{cholesky, fwd_solve, Mat};

/// Vector width of the critical update dataflow.
const W: usize = 4;

/// Scratchpad layout (per lane).
const L_BASE: i64 = 0;
const B_BASE: i64 = 1100;
const X_BASE: i64 = 1200;
/// Scratch region for the non-fine-grain x round-trip (disjoint from the
/// hoisted X store so the memory interlock doesn't pin it).
const XT_BASE: i64 = 1300;

// Port map. Input: 0=bvec, 1=lcol, 2=x (reused scalar), 3=update gate,
// 4=b_j, 5=l_jj, 6=div gate. Output: 0=b' (store), 1=b'[first] (to div),
// 2=x (store), 3=x (to update).
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut u = DfgBuilder::new("update", Criticality::Critical);
    let bv = u.in_port(0, W);
    let lc = u.in_port(1, W);
    let x = u.in_port(2, 1);
    let prod = u.node(Op::Mul, &[lc, x]);
    let bnew = u.node(Op::Sub, &[bv, prod]);
    u.out(0, bnew, W);
    if feats.fine_grain {
        let g = u.in_port(3, W);
        u.out_gated(1, bnew, 1, Some(g));
    }

    let mut d = DfgBuilder::new("div", Criticality::NonCritical);
    let bj = d.in_port(4, 1);
    let ljj = d.in_port(5, 1);
    let xv = d.node(Op::Div, &[bj, ljj]);
    d.out(2, xv, 1);
    if feats.fine_grain {
        let g = d.in_port(6, 1);
        d.out_gated(3, xv, 1, Some(g));
    }

    let cfg = LaneConfig { name: "solver".into(), dfgs: vec![u.build(), d.build()] };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

/// Build the control program for one n-sized solve on `mask` lanes.
pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let cfg = config(feats)?;
    let n_i = n as i64;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];

    if feats.fine_grain {
        // Diagonal l_jj feeds div every iteration (stride n+1) and the
        // x results stream to memory as produced — both hoisted for the
        // whole kernel.
        p.push(vs(Cmd::LocalLd {
            pat: Pattern2D::strided(L_BASE, n_i + 1, n_i),
            port: 5,
            reuse: None,
            masked: feats.masking,
            rmw: None,
        }));
        p.push(vs(Cmd::LocalSt {
            pat: Pattern2D::lin(X_BASE, n_i),
            port: 2,
            rmw: false,
        }));
        // b[0] seeds div; the rest arrive over the loop-carried XFER.
        p.push(vs(Cmd::LocalLd {
            pat: Pattern2D::lin(B_BASE, 1),
            port: 4,
            reuse: None,
            masked: feats.masking,
            rmw: None,
        }));
        // div emit gate: forward x for the first n-1 iterations only.
        p.push(vs(Cmd::ConstSt {
            pat: ConstPattern {
                val1: 1.0,
                n1: (n - 1) as f64,
                s1: 0.0,
                val2: 0.0,
                n2: 1.0,
                s2: 0.0,
                n_j: 1,
            },
            port: 6,
        }));
        let tri = |base: i64, c_j: i64| {
            Pattern2D::inductive(base, 1, (n - 1) as f64, c_j, n_i - 1, -1.0)
        };
        if feats.inductive {
            // The whole triangular domain in single commands (Fig 11).
            // The in-place b stream: rmw store issued *first*, paired
            // load second — element-level ordering lets row j's load
            // trail row j-1's store (cross-iteration RAW) while the
            // store trails the load within a row (WAR).
            p.push(vs(Cmd::LocalSt { pat: tri(B_BASE + 1, 1), port: 0, rmw: true }));
            p.push(vs(Cmd::LocalLd {
                pat: tri(B_BASE + 1, 1),
                port: 0,
                reuse: None,
                masked: feats.masking,
                rmw: Some(1),
            }));
            p.push(vs(Cmd::LocalLd {
                pat: tri(L_BASE + 1, n_i + 1),
                port: 1,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            p.push(vs(Cmd::ConstSt {
                pat: ConstPattern::first_of_row(1.0, 0.0, (n - 1) as f64, n_i - 1, -1.0),
                port: 3,
            }));
            // x_j consumed (n-1-j) times: inductive reuse stretch.
            p.push(vs(Cmd::Xfer {
                src_port: 3,
                dst_port: 2,
                dst: XferDst::Local,
                n: n_i - 1,
                reuse: Some(Reuse { n_r: (n - 1) as f64, s_r: -1.0 }),
            }));
            // Loop-carried: first updated element of each row -> next div.
            p.push(vs(Cmd::Xfer {
                src_port: 1,
                dst_port: 4,
                dst: XferDst::Local,
                n: n_i - 1,
                reuse: None,
            }));
        } else {
            // Rectangular-only ISA: decompose per row (Fig 11 right).
            for j in 0..n_i - 1 {
                let len = n_i - 1 - j;
                p.push(vs(Cmd::LocalLd {
                    pat: Pattern2D::lin(B_BASE + 1 + j, len),
                    port: 0,
                    reuse: None,
                    masked: feats.masking,
                    rmw: None,
                }));
                p.push(vs(Cmd::LocalLd {
                    pat: Pattern2D::lin(L_BASE + j * (n_i + 1) + 1, len),
                    port: 1,
                    reuse: None,
                    masked: feats.masking,
                    rmw: None,
                }));
                p.push(vs(Cmd::ConstSt {
                    pat: ConstPattern::first_of_row(1.0, 0.0, len as f64, 1, 0.0),
                    port: 3,
                }));
                p.push(vs(Cmd::Xfer {
                    src_port: 3,
                    dst_port: 2,
                    dst: XferDst::Local,
                    n: 1,
                    reuse: Some(Reuse::uniform(len as f64)),
                }));
                p.push(vs(Cmd::Xfer {
                    src_port: 1,
                    dst_port: 4,
                    dst: XferDst::Local,
                    n: 1,
                    reuse: None,
                }));
                p.push(vs(Cmd::LocalSt {
                    pat: Pattern2D::lin(B_BASE + 1 + j, len),
                    port: 0,
                    rmw: true,
                }));
            }
        }
    } else {
        // No fine-grain dependences: every region transition round-trips
        // through the scratchpad; the memory-ordering logic serializes
        // the regions (the task-parallel failure mode of Fig 8).
        for j in 0..n_i {
            // Without fine-grain ordering hardware the program must
            // barrier at every region transition (waits for all SPAD
            // streams *and* pipeline output to drain to memory).
            p.push(vs(Cmd::Barrier));
            // b[j] (written by the previous row's update store).
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(B_BASE + j, 1),
                port: 4,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            // l_jj per iteration (nothing is hoisted without FGOP).
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(L_BASE + j * (n_i + 1), 1),
                port: 5,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            // x[j] lands in memory: result copy + update-region copy.
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(X_BASE + j, 1),
                port: 2,
                rmw: false,
            }));
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(XT_BASE + j, 1),
                port: 3,
                rmw: false,
            }));
            if j == n_i - 1 {
                break;
            }
            let len = n_i - 1 - j;
            p.push(vs(Cmd::Barrier)); // x must land in memory first
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(XT_BASE + j, 1),
                port: 2,
                reuse: Some(Reuse::uniform(len as f64)),
                masked: feats.masking,
                rmw: None,
            }));
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(B_BASE + 1 + j, len),
                port: 0,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            p.push(vs(Cmd::LocalLd {
                pat: Pattern2D::lin(L_BASE + j * (n_i + 1) + 1, len),
                port: 1,
                reuse: None,
                masked: feats.masking,
                rmw: None,
            }));
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(B_BASE + 1 + j, len),
                port: 0,
                rmw: true,
            }));
        }
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

/// Non-fine-grain variants need div's x on an *output* port that a store
/// can drain per iteration; reuse port 3 for that (no gated tap exists).
/// The div DFG built without fine_grain emits x only on out port 2; the
/// per-j x store in `program` uses port 3 — so bind x there too.
fn config_no_fg(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut u = DfgBuilder::new("update", Criticality::Critical);
    let bv = u.in_port(0, W);
    let lc = u.in_port(1, W);
    let x = u.in_port(2, 1);
    let prod = u.node(Op::Mul, &[lc, x]);
    let bnew = u.node(Op::Sub, &[bv, prod]);
    u.out(0, bnew, W);

    let mut d = DfgBuilder::new("div", Criticality::NonCritical);
    let bj = d.in_port(4, 1);
    let ljj = d.in_port(5, 1);
    let xv = d.node(Op::Div, &[bj, ljj]);
    d.out(2, xv, 1);
    d.out(3, xv, 1);

    let cfg =
        LaneConfig { name: "solver_nofg".into(), dfgs: vec![u.build(), d.build()] };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

/// Problem data for one lane.
pub struct Instance {
    pub l: Mat,
    pub b: Vec<f64>,
    pub x_ref: Vec<f64>,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::spd(n, seed as f64 * 0.7);
    let l = cholesky(&a);
    let b: Vec<f64> = (0..n).map(|i| ((i + seed) as f64 * 0.37).sin() + 1.5).collect();
    let x_ref = fwd_solve(&l, &b);
    Instance { l, b, x_ref }
}

/// Load an instance into a lane's scratchpad (L column-major).
pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.l.rows;
    for j in 0..n {
        for i in 0..n {
            lane.spad.write(L_BASE + (j * n + i) as i64, inst.l[(i, j)]);
        }
    }
    lane.spad.load_slice(B_BASE, &inst.b);
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1, // paper Table 5: Solver latency version = 1 lane
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let mut prog = program(n, feats, mask)?;
    if !feats.fine_grain {
        // Swap in the no-tap config (x additionally on out port 3).
        prog[0] = VsCommand::new(Cmd::Configure(config_no_fg(feats)?), mask);
    }
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            for (j, &want) in inst.x_ref.iter().enumerate() {
                let got = m.lanes[l].spad.read(X_BASE + j as i64);
                let err = (got - want).abs();
                if err > 1e-9 {
                    return Err(format!(
                        "lane {l} x[{j}]: got {got}, want {want}"
                    ));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    Ok(Prepared {
        machine: m,
        prog,
        verify,
        flops: (lanes * n * n) as f64,
        problems: lanes,
    })
}

use crate::sim::Machine;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program_stats;

    #[test]
    fn fgop_solver_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            let r = prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert!(r.cycles > 0);
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            let r = prepare(16, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(r.cycles > 0, "{name}");
        }
    }

    #[test]
    fn fgop_features_improve_latency_monotonically_enough() {
        // The full-feature version must clearly beat the base version.
        let base = prepare(32, Features::NONE, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let full = prepare(32, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        // Measured band: ~1.6x (n=8) to ~2x (n=32); the paper's Fig 19
        // solver bar is ~2.5x total across mechanisms. The full version
        // runs at ~19 cycles/iteration — already below the paper's
        // ideal-ASIC solver model (2*max(ceil(i/4),14) ≈ 28/iter).
        assert!(
            full.cycles * 18 < base.cycles * 10,
            "FGOP {} vs base {}",
            full.cycles,
            base.cycles
        );
    }

    #[test]
    fn inductive_streams_cut_control_commands(/* Fig 11 */) {
        let ind = program(16, Features::ALL, LaneMask::one(0)).unwrap();
        let no_ind = program(
            16,
            Features { inductive: false, ..Features::ALL },
            LaneMask::one(0),
        )
        .unwrap();
        let si = program_stats(&ind);
        let sn = program_stats(&no_ind);
        assert!(si.commands * 4 < sn.commands, "{} vs {}", si.commands, sn.commands);
    }

    #[test]
    fn throughput_version_solves_eight_problems() {
        let r = prepare(16, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
        // Data-parallel lanes share one control program: the cycle cost
        // must be far below 8x the single-problem cost.
        let one = prepare(16, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(r.cycles < one.cycles * 3, "{} vs {}", r.cycles, one.cycles);
    }
}
