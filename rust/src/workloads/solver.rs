//! Triangular linear solver (paper Fig 2 / Fig 9): solve L x = b by
//! forward substitution. The canonical FGOP kernel:
//!
//! * two dataflows — `div` (non-critical: one divide per outer
//!   iteration) and `update` (critical, vectorized: b -= l * x);
//! * ordered dependences both ways: x_j from div feeds update with an
//!   inductively shrinking reuse (n-1-j), and the first element of each
//!   update row feeds the next div (loop-carried);
//! * inductive memory streams over the shrinking triangular domain;
//! * implicit masking of the non-width-divisible rows.
//!
//! With all FGOP features the whole kernel is ~11 control commands
//! (paper Fig 11); the ablations decompose streams per-row and/or
//! round-trip the fine-grain values through the scratchpad. Built on
//! the typed [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op};
use crate::isa::{ConstPattern, LaneMask, Program, Reuse};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::{cholesky, fwd_solve, Mat};
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

/// Vector width of the critical update dataflow.
const W: usize = 4;

/// Typed port handles. The gated taps (`gate_up`/`b_first`,
/// `gate_div`) exist only in the fine-grain variant; `x_tap` is the
/// second x output in both variants (gated when fine-grain, a plain
/// second binding otherwise — the per-iteration x store needs an output
/// a store can drain).
pub struct Ports {
    /// update: b suffix (width W).
    pub bvec: In,
    /// update: L column elements (width W).
    pub lcol: In,
    /// update: x_j scalar (reused).
    pub x: In,
    /// update: gate for the loop-carried first-element tap.
    pub gate_up: Option<In>,
    /// div: b_j.
    pub b_j: In,
    /// div: l_jj.
    pub l_jj: In,
    /// div: emit gate for the x forward.
    pub gate_div: Option<In>,
    /// update out: updated b elements.
    pub b_out: Out,
    /// update out (gated): first updated element -> next div.
    pub b_first: Option<Out>,
    /// div out: x results (streamed to memory).
    pub x_out: Out,
    /// div out: x copy for the update region.
    pub x_tap: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// L, column-major, `n*n` words.
    pub l: Region,
    /// b (updated in place).
    pub b: Region,
    /// x results.
    pub x: Region,
    /// Scratch for the non-fine-grain x round-trip (disjoint from the
    /// hoisted X store so the memory interlock doesn't pin it).
    pub xt: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    // The two variants differ in their output taps; they are distinct
    // configurations (and distinct compile-cache entries).
    let name = if feats.fine_grain { "solver" } else { "solver_nofg" };
    let mut k = Kernel::new(name);

    let mut u = k.dfg("update", Criticality::Critical);
    let bv = u.input(W);
    let lc = u.input(W);
    let x = u.input(1);
    let prod = u.node(Op::Mul, &[lc.wire(), x.wire()]);
    let bnew = u.node(Op::Sub, &[bv.wire(), prod]);
    let b_out = u.output(bnew, W);
    let (gate_up, b_first) = if feats.fine_grain {
        let g = u.input(W);
        (Some(g), Some(u.output_gated(bnew, 1, g)))
    } else {
        (None, None)
    };
    u.done();

    let mut d = k.dfg("div", Criticality::NonCritical);
    let bj = d.input(1);
    let ljj = d.input(1);
    let xv = d.node(Op::Div, &[bj.wire(), ljj.wire()]);
    let x_out = d.output(xv, 1);
    let (gate_div, x_tap) = if feats.fine_grain {
        let g = d.input(1);
        (Some(g), d.output_gated(xv, 1, g))
    } else {
        (None, d.output(xv, 1))
    };
    d.done();

    let built = k.build()?;
    let ports = Ports {
        bvec: bv,
        lcol: lc,
        x,
        gate_up,
        b_j: bj,
        l_jj: ljj,
        gate_div,
        b_out,
        b_first,
        x_out,
        x_tap,
    };
    Ok((built, ports))
}

/// Allocate the scratchpad layout for problem size `n`.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let l = al.region("solver.L", (n * n) as i64)?;
    let b = al.region("solver.b", n as i64)?;
    let x = al.region("solver.x", n as i64)?;
    let xt = al.region("solver.x_tmp", n as i64)?;
    Ok(Layout { l, b, x, xt })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Build the control program for one n-sized solve on `mask` lanes.
pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let plan = plan(n, feats)?;
    let n_i = n as i64;
    let p = &plan.ports;
    let lay = &plan.lay;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);

    if feats.fine_grain {
        // Diagonal l_jj feeds div every iteration (stride n+1) and the
        // x results stream to memory as produced — both hoisted for the
        // whole kernel.
        b.ld(lay.l.strided(0, n_i + 1, n_i), p.l_jj);
        b.st(lay.x.lin(0, n_i), p.x_out);
        // b[0] seeds div; the rest arrive over the loop-carried XFER.
        b.ld(lay.b.lin(0, 1), p.b_j);
        // div emit gate: forward x for the first n-1 iterations only.
        b.const_st(
            ConstPattern {
                val1: 1.0,
                n1: (n - 1) as f64,
                s1: 0.0,
                val2: 0.0,
                n2: 1.0,
                s2: 0.0,
                n_j: 1,
            },
            p.gate_div.unwrap(),
        );
        let tri = |reg: &Region, c_j: i64| {
            reg.inductive(1, 1, (n - 1) as f64, c_j, n_i - 1, -1.0)
        };
        if feats.inductive {
            // The whole triangular domain in single commands (Fig 11).
            // The in-place b stream: rmw store issued *first*, paired
            // load second — element-level ordering lets row j's load
            // trail row j-1's store (cross-iteration RAW) while the
            // store trails the load within a row (WAR).
            b.st_rmw(tri(&lay.b, 1), p.b_out);
            b.ld_rmw(tri(&lay.b, 1), p.bvec, 1);
            b.ld(tri(&lay.l, n_i + 1), p.lcol);
            b.gate_first_of_row(
                p.gate_up.unwrap(),
                1.0,
                0.0,
                (n - 1) as f64,
                n_i - 1,
                -1.0,
            );
            // x_j consumed (n-1-j) times: inductive reuse stretch.
            b.xfer_reuse(
                p.x_tap,
                p.x,
                n_i - 1,
                Reuse { n_r: (n - 1) as f64, s_r: -1.0 },
            );
            // Loop-carried: first updated element of each row -> next div.
            b.xfer(p.b_first.unwrap(), p.b_j, n_i - 1);
        } else {
            // Rectangular-only ISA: decompose per row (Fig 11 right).
            for j in 0..n_i - 1 {
                let len = n_i - 1 - j;
                b.ld(lay.b.lin(1 + j, len), p.bvec);
                b.ld(lay.l.lin(j * (n_i + 1) + 1, len), p.lcol);
                b.gate_first_of_row(p.gate_up.unwrap(), 1.0, 0.0, len as f64, 1, 0.0);
                b.xfer_reuse(p.x_tap, p.x, 1, Reuse::uniform(len as f64));
                b.xfer(p.b_first.unwrap(), p.b_j, 1);
                b.st_rmw(lay.b.lin(1 + j, len), p.b_out);
            }
        }
    } else {
        // No fine-grain dependences: every region transition round-trips
        // through the scratchpad; the memory-ordering logic serializes
        // the regions (the task-parallel failure mode of Fig 8).
        for j in 0..n_i {
            // Without fine-grain ordering hardware the program must
            // barrier at every region transition (waits for all SPAD
            // streams *and* pipeline output to drain to memory).
            b.barrier();
            // b[j] (written by the previous row's update store).
            b.ld(lay.b.lin(j, 1), p.b_j);
            // l_jj per iteration (nothing is hoisted without FGOP).
            b.ld(lay.l.lin(j * (n_i + 1), 1), p.l_jj);
            // x[j] lands in memory: result copy + update-region copy.
            b.st(lay.x.lin(j, 1), p.x_out);
            b.st(lay.xt.lin(j, 1), p.x_tap);
            if j == n_i - 1 {
                break;
            }
            let len = n_i - 1 - j;
            b.barrier(); // x must land in memory first
            b.ld_reuse(lay.xt.lin(j, 1), p.x, Reuse::uniform(len as f64));
            b.ld(lay.b.lin(1 + j, len), p.bvec);
            b.ld(lay.l.lin(j * (n_i + 1) + 1, len), p.lcol);
            b.st_rmw(lay.b.lin(1 + j, len), p.b_out);
        }
    }
    Ok(b.finish())
}

/// Problem data for one lane.
pub struct Instance {
    pub l: Mat,
    pub b: Vec<f64>,
    pub x_ref: Vec<f64>,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::spd(n, seed as f64 * 0.7);
    let l = cholesky(&a);
    let b: Vec<f64> = (0..n).map(|i| ((i + seed) as f64 * 0.37).sin() + 1.5).collect();
    let x_ref = fwd_solve(&l, &b);
    Instance { l, b, x_ref }
}

/// Load an instance into a lane's scratchpad (L column-major).
pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.l.rows;
    let lay = layout(n).expect("solver layout fits the lane scratchpad");
    for j in 0..n {
        for i in 0..n {
            lane.spad.write(lay.l.addr((j * n + i) as i64), inst.l[(i, j)]);
        }
    }
    lane.spad.load_slice(lay.b.base(), &inst.b);
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1, // paper Table 5: Solver latency version = 1 lane
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let x_region = lay.x;
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            for (j, &want) in inst.x_ref.iter().enumerate() {
                let got = m.lanes[l].spad.read(x_region.addr(j as i64));
                let err = (got - want).abs();
                if err > 1e-9 {
                    return Err(format!(
                        "lane {l} x[{j}]: got {got}, want {want}"
                    ));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    Ok(Prepared {
        machine: m,
        prog,
        verify,
        flops: (lanes * n * n) as f64,
        problems: lanes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program_stats;

    #[test]
    fn fgop_solver_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            let r = prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert!(r.cycles > 0);
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            let r = prepare(16, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(r.cycles > 0, "{name}");
        }
    }

    #[test]
    fn fgop_features_improve_latency_monotonically_enough() {
        // The full-feature version must clearly beat the base version.
        let base = prepare(32, Features::NONE, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        let full = prepare(32, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        // Measured band: ~1.6x (n=8) to ~2x (n=32); the paper's Fig 19
        // solver bar is ~2.5x total across mechanisms. The full version
        // runs at ~19 cycles/iteration — already below the paper's
        // ideal-ASIC solver model (2*max(ceil(i/4),14) ≈ 28/iter).
        assert!(
            full.cycles * 18 < base.cycles * 10,
            "FGOP {} vs base {}",
            full.cycles,
            base.cycles
        );
    }

    #[test]
    fn inductive_streams_cut_control_commands(/* Fig 11 */) {
        let ind = program(16, Features::ALL, LaneMask::one(0)).unwrap();
        let no_ind = program(
            16,
            Features { inductive: false, ..Features::ALL },
            LaneMask::one(0),
        )
        .unwrap();
        let si = program_stats(&ind);
        let sn = program_stats(&no_ind);
        assert!(si.commands * 4 < sn.commands, "{} vs {}", si.commands, sn.commands);
    }

    #[test]
    fn throughput_version_solves_eight_problems() {
        let r = prepare(16, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
        // Data-parallel lanes share one control program: the cycle cost
        // must be far below 8x the single-problem cost.
        let one = prepare(16, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(r.cycles < one.cycles * 3, "{} vs {}", r.cycles, one.cycles);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        for feats in [Features::ALL, Features::NONE] {
            let prog = program(12, feats, LaneMask::one(0)).unwrap();
            let rep = crate::vsc::check_program(&prog, &SimConfig::default());
            assert!(rep.errors().is_empty(), "{feats:?}:\n{rep}");
        }
    }
}
