//! LU decomposition (no pivoting — paper Table 4 lists LU in the
//! ideal-ASIC suite; instances are diagonally dominant SPD so pivoting
//! is unnecessary). In-place right-looking Doolittle factorization,
//! three dataflow regions mirroring Cholesky's shape:
//!
//! * `point` (non-critical): inv = 1 / a_kk;
//! * `vector` (critical): l_ik = a_ik * inv, i in (k..n);
//! * `matrix` (critical): a_ij -= l_ik * a_kj over the square trailing
//!   block (LU's trailing update is rectangular, not triangular — the
//!   structural difference from Cholesky).
//!
//! Fine-grain ordered dependence: point -> vector (inv, reused for the
//! whole column via XFER); the ablation round-trips it through the
//! scratchpad. The trailing block updates in place (rmw store + lag-0
//! rmw load), the L-column stream rewinds per trailing column
//! (c_j = 0 stream reuse), and the pivot-row scalars feed the matrix
//! region with column-length reuse.
//!
//! This workload is authored *purely* against the typed [`crate::vsc`]
//! API — it is the template for every future kernel PR.

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op, Operand};
use crate::isa::{LaneMask, Program, Reuse};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::Mat;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

/// Vector width of the critical dataflows.
const W: usize = 8;

/// Typed port handles of the three dataflows.
pub struct Ports {
    /// point: pivot a_kk.
    pub akk: In,
    /// vector: column-k suffix (width W).
    pub acol: In,
    /// vector: 1/a_kk scalar (reused).
    pub inv: In,
    /// matrix: trailing-block element stream (width W).
    pub a: In,
    /// matrix: L column suffix, rewound per trailing column (width W).
    pub lcol: In,
    /// matrix: pivot-row scalar a_kj per trailing column (reused).
    pub akj: In,
    /// point out: inv.
    pub inv_out: Out,
    /// vector out: the scaled L column.
    pub l_out: Out,
    /// matrix out: updated trailing elements.
    pub upd: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// A, column-major, `n*n` words (becomes L\U in place).
    pub a: Region,
    /// inv round-trip scratch (non-fine-grain ablation only).
    pub tmp: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("lu");

    let mut pt = k.dfg("point", Criticality::NonCritical);
    let akk = pt.input(1);
    let inv = pt.node(Op::Div, &[Operand::Const(1.0), akk.wire()]);
    let inv_out = pt.output(inv, 1);
    pt.done();

    let mut v = k.dfg("vector", Criticality::Critical);
    let acol = v.input(W);
    let iv = v.input(1);
    let l = v.node(Op::Mul, &[acol.wire(), iv.wire()]);
    let l_out = v.output(l, W);
    v.done();

    let mut m = k.dfg("matrix", Criticality::Critical);
    let a = m.input(W);
    let lc = m.input(W);
    let akj = m.input(1);
    let prod = m.node(Op::Mul, &[lc.wire(), akj.wire()]);
    let upd = m.node(Op::Sub, &[a.wire(), prod]);
    let upd_out = m.output(upd, W);
    m.done();

    let built = k.build()?;
    let ports = Ports {
        akk,
        acol,
        inv: iv,
        a,
        lcol: lc,
        akj,
        inv_out,
        l_out,
        upd: upd_out,
    };
    Ok((built, ports))
}

/// Allocate the scratchpad layout for problem size `n`.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let a = al.region("lu.A", (n * n) as i64)?;
    let tmp = al.region("lu.inv_tmp", n as i64)?;
    Ok(Layout { a, tmp })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Column-major offset of `A[i][j]` inside the A region.
fn at(n: i64, i: i64, j: i64) -> i64 {
    j * n + i
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let plan = plan(n, feats)?;
    let n_i = n as i64;
    let p = &plan.ports;
    let a = &plan.lay.a;
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);

    for k in 0..n_i - 1 {
        let t = n_i - k - 1; // trailing dimension
        // Pivot: written by the previous trailing update; the memory
        // interlock orders this load after that rmw store.
        b.ld(a.lin(at(n_i, k, k), 1), p.akk);
        if feats.fine_grain {
            // point -> vector: inv reused for the whole column.
            b.xfer_reuse(p.inv_out, p.inv, 1, Reuse::uniform(t as f64));
        } else {
            // Memory round-trip for the region transition.
            b.st(plan.lay.tmp.lin(k, 1), p.inv_out);
            b.barrier();
            b.ld_reuse(plan.lay.tmp.lin(k, 1), p.inv, Reuse::uniform(t as f64));
        }
        // Scale column k below the pivot; L lands over A in place.
        b.ld(a.lin(at(n_i, k + 1, k), t), p.acol);
        b.st(a.lin(at(n_i, k + 1, k), t), p.l_out);

        // ---- matrix region: square trailing update ------------------
        b.barrier();
        if feats.inductive {
            // Whole trailing block in single 2D commands: pivot-row
            // scalars (each reused for one column), the in-place rmw
            // pair over the block, and the rewinding L-column stream.
            b.ld_reuse(
                a.strided(at(n_i, k, k + 1), n_i, t),
                p.akj,
                Reuse::uniform(t as f64),
            );
            let block = a.rect(at(n_i, k + 1, k + 1), 1, t, n_i, t);
            b.st_rmw(block.clone(), p.upd);
            b.ld_rmw(block, p.a, 0);
            b.ld(a.rect(at(n_i, k + 1, k), 1, t, 0, t), p.lcol);
        } else {
            // Rectangular-only decomposition, interleaved per column so
            // the stores don't head-of-line block the command queue.
            for j in 0..t {
                b.ld_reuse(
                    a.lin(at(n_i, k, k + 1 + j), 1),
                    p.akj,
                    Reuse::uniform(t as f64),
                );
                let colp = a.lin(at(n_i, k + 1, k + 1 + j), t);
                b.st_rmw(colp.clone(), p.upd);
                b.ld_rmw(colp, p.a, 0);
                b.ld(a.lin(at(n_i, k + 1, k), t), p.lcol);
            }
        }
    }
    Ok(b.finish())
}

/// Plan for the `b x b` tile kernels of the task-graph subsystem
/// ([`crate::taskgraph`]). LU's kernel build ignores the feature set
/// (no gated ports), so tile programs run the full-feature shape.
pub fn tile_plan(b: usize) -> Result<Plan, WlError> {
    plan(b, Features::ALL)
}

/// GETRF tile task: factor the diagonal tile in `target` (column-major
/// `b x b`) in place — the whole [`program`] body at `n = b` relocated
/// into an arbitrary slot region.
pub fn tile_getrf_program(
    plan: &Plan,
    b_sz: usize,
    target: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), Features::ALL, mask);
    for k in 0..n_i - 1 {
        let t = n_i - k - 1;
        b.barrier();
        b.ld(target.lin(at(n_i, k, k), 1), p.akk);
        b.xfer_reuse(p.inv_out, p.inv, 1, Reuse::uniform(t as f64));
        b.ld(target.lin(at(n_i, k + 1, k), t), p.acol);
        b.st(target.lin(at(n_i, k + 1, k), t), p.l_out);
        b.barrier();
        b.ld_reuse(
            target.strided(at(n_i, k, k + 1), n_i, t),
            p.akj,
            Reuse::uniform(t as f64),
        );
        let block = target.rect(at(n_i, k + 1, k + 1), 1, t, n_i, t);
        b.st_rmw(block.clone(), p.upd);
        b.ld_rmw(block, p.a, 0);
        b.ld(target.rect(at(n_i, k + 1, k), 1, t, 0, t), p.lcol);
    }
    b.finish()
}

/// TRSM column-panel tile task: scale the `b` rows of `target` (tile
/// `(I, K)`, `I > K`) by each pivot of the factored diagonal tile in
/// `left`, applying the within-panel trailing updates restricted to
/// `target`'s rows. Host-side replay is the numerics of record
/// ([`crate::taskgraph::exec`]); the machine run supplies timing.
pub fn tile_trsm_col_program(
    plan: &Plan,
    b_sz: usize,
    left: Region,
    target: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), Features::ALL, mask);
    for k in 0..n_i {
        let t = n_i - k - 1;
        b.barrier();
        b.ld(left.lin(at(n_i, k, k), 1), p.akk);
        b.xfer_reuse(p.inv_out, p.inv, 1, Reuse::uniform(n_i as f64));
        b.ld(target.lin(at(n_i, 0, k), n_i), p.acol);
        b.st(target.lin(at(n_i, 0, k), n_i), p.l_out);
        if t > 0 {
            b.barrier();
            b.ld_reuse(
                left.strided(at(n_i, k, k + 1), n_i, t),
                p.akj,
                Reuse::uniform(n_i as f64),
            );
            let block = target.rect(at(n_i, 0, k + 1), 1, n_i, n_i, t);
            b.st_rmw(block.clone(), p.upd);
            b.ld_rmw(block, p.a, 0);
            b.ld(target.rect(at(n_i, 0, k), 1, n_i, 0, t), p.lcol);
        }
    }
    b.finish()
}

/// TRSM row-panel tile task: eliminate below each pivot row inside
/// `target` (tile `(K, J)`, `J > K`) using the unit-lower columns of
/// the factored diagonal tile in `left`. Row panels take no divides —
/// only the rectangular trailing updates, restricted to the tile.
pub fn tile_trsm_row_program(
    plan: &Plan,
    b_sz: usize,
    left: Region,
    target: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), Features::ALL, mask);
    for k in 0..n_i - 1 {
        let t = n_i - k - 1;
        b.barrier();
        b.ld_reuse(
            target.strided(at(n_i, k, 0), n_i, n_i),
            p.akj,
            Reuse::uniform(t as f64),
        );
        let block = target.rect(at(n_i, k + 1, 0), 1, t, n_i, n_i);
        b.st_rmw(block.clone(), p.upd);
        b.ld_rmw(block, p.a, 0);
        b.ld(left.rect(at(n_i, k + 1, k), 1, t, 0, n_i), p.lcol);
    }
    b.finish()
}

/// GEMM tile task: `target -= left_colk * right_rowk` summed over the
/// `b` pivots of panel `K`. `left` holds tile `(I, K)` (L columns),
/// `right` tile `(K, J)` (U rows); `target` is tile `(I, J)`.
pub fn tile_gemm_program(
    plan: &Plan,
    b_sz: usize,
    left: Region,
    right: Region,
    target: Region,
    mask: LaneMask,
) -> Program {
    let n_i = b_sz as i64;
    let p = &plan.ports;
    let mut b = plan.built.program(plan.cfg.clone(), Features::ALL, mask);
    for k in 0..n_i {
        b.barrier();
        b.ld_reuse(
            right.strided(at(n_i, k, 0), n_i, n_i),
            p.akj,
            Reuse::uniform(n_i as f64),
        );
        let block = target.rect(0, 1, n_i, n_i, n_i);
        b.st_rmw(block.clone(), p.upd);
        b.ld_rmw(block, p.a, 0);
        b.ld(left.rect(at(n_i, 0, k), 1, n_i, 0, n_i), p.lcol);
    }
    b.finish()
}

/// Scalar mirror of the exact simulated arithmetic (multiply by the
/// reciprocal, same update order).
pub fn lu_mirror(a: &mut Mat) {
    let n = a.rows;
    for k in 0..n.saturating_sub(1) {
        let inv = 1.0 / a[(k, k)];
        for i in k + 1..n {
            a[(i, k)] *= inv;
        }
        for j in k + 1..n {
            let akj = a[(k, j)];
            for i in k + 1..n {
                let l = a[(i, k)];
                a[(i, j)] -= l * akj;
            }
        }
    }
}

/// Problem data for one lane.
pub struct Instance {
    pub a: Mat,
    pub lu_ref: Mat,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    // Diagonally dominant SPD input: no pivoting required.
    let a = Mat::spd(n, seed as f64 * 0.9 + 0.1);
    let mut lu_ref = a.clone();
    lu_mirror(&mut lu_ref);
    Instance { a, lu_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    let lay = layout(n).expect("lu layout fits the lane scratchpad");
    for j in 0..n {
        for i in 0..n {
            lane.spad
                .write(lay.a.addr(at(n as i64, i as i64, j as i64)), inst.a[(i, j)]);
        }
    }
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let a_region = lay.a;
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows as i64;
            for j in 0..nn {
                for i in 0..nn {
                    let got = m.lanes[l].spad.read(a_region.addr(at(nn, i, j)));
                    let want = inst.lu_ref[(i as usize, j as usize)];
                    let err = (got - want).abs();
                    if err > 1e-9 {
                        return Err(format!(
                            "lane {l} LU[{i}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
        }
        Ok(max_err)
    });
    // ~2/3 n^3 useful flops (mul + sub over the trailing blocks).
    let flops = lanes as f64 * 2.0 / 3.0 * (n * n * n) as f64;
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program_stats;
    use crate::util::linalg::lu as lu_ref;

    #[test]
    fn mirror_matches_library_lu() {
        for n in [4, 8, 16] {
            let inst = instance(n, 0);
            let lib = lu_ref(&inst.a);
            assert!(
                inst.lu_ref.max_abs_diff(&lib) < 1e-8,
                "n={n}: mirror vs library LU"
            );
        }
    }

    #[test]
    fn fgop_lu_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            prepare(12, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn inductive_streams_cut_commands() {
        let ind = program(16, Features::ALL, LaneMask::one(0)).unwrap();
        let no = program(
            16,
            Features { inductive: false, ..Features::ALL },
            LaneMask::one(0),
        )
        .unwrap();
        assert!(
            program_stats(&ind).commands * 3 < program_stats(&no).commands,
            "{} vs {}",
            ind.len(),
            no.len()
        );
    }

    #[test]
    fn throughput_runs_eight_lanes() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        for feats in [Features::ALL, Features::NONE] {
            let prog = program(12, feats, LaneMask::one(0)).unwrap();
            let rep = crate::vsc::check_program(&prog, &SimConfig::default());
            assert!(rep.errors().is_empty(), "{feats:?}:\n{rep}");
        }
    }

    #[test]
    fn tile_programs_pass_the_vsc_check() {
        for b in [8usize, 16] {
            let mut al =
                SpadAlloc::with_capacity(SimConfig::default().lane_spad_words);
            let s0 = al.region("t.s0", (b * b) as i64).unwrap();
            let s1 = al.region("t.s1", (b * b) as i64).unwrap();
            let s2 = al.region("t.s2", (b * b) as i64).unwrap();
            let plan = tile_plan(b).unwrap();
            let mask = LaneMask::one(0);
            for (name, prog) in [
                ("getrf", tile_getrf_program(&plan, b, s0, mask)),
                ("trsm_col", tile_trsm_col_program(&plan, b, s0, s1, mask)),
                ("trsm_row", tile_trsm_row_program(&plan, b, s0, s1, mask)),
                ("gemm", tile_gemm_program(&plan, b, s0, s1, s2, mask)),
            ] {
                let rep = crate::vsc::check_program(&prog, &SimConfig::default());
                assert!(rep.errors().is_empty(), "b={b} {name}:\n{rep}");
            }
        }
    }

    #[test]
    fn getrf_tile_matches_mirror_on_the_machine() {
        for b in [8usize, 16] {
            let mut al =
                SpadAlloc::with_capacity(SimConfig::default().lane_spad_words);
            let s0 = al.region("t.s0", (b * b) as i64).unwrap();
            let plan = tile_plan(b).unwrap();
            let prog = tile_getrf_program(&plan, b, s0, LaneMask::one(0));
            let inst = instance(b, 2);
            let mut m = machine(1);
            for j in 0..b {
                for i in 0..b {
                    m.lanes[0].spad.write(
                        s0.addr(at(b as i64, i as i64, j as i64)),
                        inst.a[(i, j)],
                    );
                }
            }
            m.run(prog).unwrap();
            for j in 0..b {
                for i in 0..b {
                    let got =
                        m.lanes[0].spad.read(s0.addr(at(b as i64, i as i64, j as i64)));
                    let want = inst.lu_ref[(i, j)];
                    assert!(
                        (got - want).abs() < 1e-9,
                        "b={b} LU[{i}][{j}]: got {got}, want {want}"
                    );
                }
            }
        }
    }
}
