//! Householder QR decomposition (paper Fig 6 left). Per iteration k:
//!
//! * `dot` (critical, vectorized reduce): sigma = a_k . a_k, then
//!   w_j = inv * (v . a_j) for every trailing column — the AccReduce
//!   dataflow emits once per column (gated);
//! * `house` (non-critical, the paper's "complex sub-critical region"
//!   that needs the temporal fabric): norm/sign/v0/r_kk/inv chain with
//!   sqrt and divides;
//! * `update` (critical): a_j -= w_j * v.
//!
//! Fine-grain ordered deps: dot -> house (sigma), house -> dot (inv,
//! reused across all trailing dots), dot -> update (w_j, reused n-k
//! times — the `tau`/`w[j]` edges of Fig 6). The Householder vector v
//! lives in-place in column k (v0 overwrites a_kk; R's diagonal is
//! stored aside), and the v streams re-read it per column with a
//! rewinding (c_j = 0) pattern — stream-reuse cutting SPAD bandwidth.
//! Built on the typed [`crate::vsc`] layer: see [`Ports`] / [`Layout`].

use std::sync::Arc;

use super::{machine, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, Op, Operand};
use crate::isa::{LaneMask, Program, Reuse};
use crate::sim::{Machine, SimConfig};
use crate::util::linalg::Mat;
use crate::vsc::{BuiltKernel, In, Kernel, Out, Region, SpadAlloc};

const W: usize = 4;

/// Typed port handles of the three dataflows.
pub struct Ports {
    /// dot: column stream (width W).
    pub dot_a: In,
    /// dot: Householder vector stream (width W).
    pub dot_v: In,
    /// dot: reduction emit gate.
    pub dot_gate: In,
    /// dot: inv scalar from house.
    pub dot_inv: In,
    /// house: sigma.
    pub sigma: In,
    /// house: original a_kk.
    pub akk: In,
    /// update: trailing-column stream (width W).
    pub upd_a: In,
    /// update: Householder vector stream (width W).
    pub upd_v: In,
    /// update: w_j scalar (reused).
    pub upd_w: In,
    /// dot out (gated): sigma / w_j reductions.
    pub w_out: Out,
    /// house out: v0 (overwrites a_kk).
    pub v0: Out,
    /// house out: r_kk (parked in the diagonal store).
    pub rkk: Out,
    /// house out: inv = 2 / |v|^2.
    pub inv: Out,
    /// update out: updated trailing elements.
    pub a_upd: Out,
}

/// Scratchpad regions (per lane).
pub struct Layout {
    /// A, column-major, `n*n` words (in-place Householder vectors + R).
    pub a: Region,
    /// R's diagonal.
    pub rdiag: Region,
    /// The constant 1.0 (sigma dot multiplier).
    pub one: Region,
    /// sigma/inv/w_j round-trip scratch (`n+1` words).
    pub tmp: Region,
}

/// A planned kernel instance (see [`plan`]).
pub struct Plan {
    built: BuiltKernel,
    /// Compiled lane configuration.
    pub cfg: Arc<Configured>,
    /// Typed port handles.
    pub ports: Ports,
    /// Allocated scratchpad layout.
    pub lay: Layout,
}

fn kernel(_feats: Features) -> Result<(BuiltKernel, Ports), WlError> {
    let mut k = Kernel::new("qr");

    let mut d = k.dfg("dot", Criticality::Critical);
    let a = d.input(W);
    let v = d.input(W);
    let gate = d.input(1);
    let inv = d.input(1);
    let prod = d.node(Op::Mul, &[a.wire(), v.wire()]);
    let s = d.node(Op::AccReduce, &[prod, gate.wire()]);
    let w = d.node(Op::Mul, &[s, inv.wire()]);
    let w_out = d.output_gated(w, 1, gate);
    d.done();

    let mut h = k.dfg("house", Criticality::NonCritical);
    let sigma = h.input(1);
    let akk = h.input(1);
    let nrm = h.node(Op::Sqrt, &[sigma.wire()]);
    let ge = h.node(Op::CmpGe, &[akk.wire(), Operand::Const(0.0)]);
    let sg = h.node(Op::Select, &[ge, Operand::Const(1.0), Operand::Const(-1.0)]);
    let sn = h.node(Op::Mul, &[sg, nrm]);
    let v0 = h.node(Op::Add, &[akk.wire(), sn]);
    let rkk = h.node(Op::Neg, &[sn]);
    let akk2 = h.node(Op::Mul, &[akk.wire(), akk.wire()]);
    let v02 = h.node(Op::Mul, &[v0, v0]);
    let t1 = h.node(Op::Sub, &[sigma.wire(), akk2]);
    let vn2 = h.node(Op::Add, &[t1, v02]);
    let invv = h.node(Op::Div, &[Operand::Const(2.0), vn2]);
    let v0_out = h.output(v0, 1);
    let rkk_out = h.output(rkk, 1);
    let inv_out = h.output(invv, 1);
    h.done();

    let mut u = k.dfg("update", Criticality::Critical);
    let a2 = u.input(W);
    let v2 = u.input(W);
    let w2 = u.input(1);
    let p2 = u.node(Op::Mul, &[v2.wire(), w2.wire()]);
    let upd = u.node(Op::Sub, &[a2.wire(), p2]);
    let a_upd = u.output(upd, W);
    u.done();

    let built = k.build()?;
    let ports = Ports {
        dot_a: a,
        dot_v: v,
        dot_gate: gate,
        dot_inv: inv,
        sigma,
        akk,
        upd_a: a2,
        upd_v: v2,
        upd_w: w2,
        w_out,
        v0: v0_out,
        rkk: rkk_out,
        inv: inv_out,
        a_upd,
    };
    Ok((built, ports))
}

/// Allocate the scratchpad layout for problem size `n`.
pub fn layout(n: usize) -> Result<Layout, WlError> {
    let mut al = SpadAlloc::lane(&SimConfig::default());
    let a = al.region("qr.A", (n * n) as i64)?;
    let rdiag = al.region("qr.rdiag", n as i64)?;
    let one = al.region("qr.one", 1)?;
    let tmp = al.region("qr.tmp", n as i64 + 1)?;
    Ok(Layout { a, rdiag, one, tmp })
}

/// Build the plan: kernel (cached compile) + ports + layout.
pub fn plan(n: usize, feats: Features) -> Result<Plan, WlError> {
    let (built, ports) = kernel(feats)?;
    let lc = built.config.clone();
    let cfg = super::cached_config(built.name(), feats, move || Ok(lc))?;
    let lay = layout(n)?;
    Ok(Plan { built, cfg, ports, lay })
}

/// Column-major offset of `A[i][j]` inside the A region.
fn at(n: i64, i: i64, j: i64) -> i64 {
    j * n + i
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let plan = plan(n, feats)?;
    let n_i = n as i64;
    let p = &plan.ports;
    let (a, tmp) = (&plan.lay.a, &plan.lay.tmp);
    let mut b = plan.built.program(plan.cfg.clone(), feats, mask);

    for k in 0..n_i {
        let len = n_i - k; // live column height (rows k..n)
        let cols = n_i - k - 1; // trailing columns
        b.barrier();
        // a_kk (original) for the house region.
        b.ld(a.lin(at(n_i, k, k), 1), p.akk);
        // sigma dot: column k against itself, multiplier 1.0.
        b.ld(a.lin(at(n_i, k, k), len), p.dot_a);
        b.ld(a.lin(at(n_i, k, k), len), p.dot_v);
        b.ld_reuse(plan.lay.one.lin(0, 1), p.dot_inv, Reuse::uniform(len as f64));
        // Emit gate for all (1 + cols) dots of this iteration. Scalar
        // gate streams pace *firings*: ceil(len/W) per column.
        let firings = (len + W as i64 - 1) / W as i64;
        b.gate_last_of_row(p.dot_gate, 1.0, 0.0, firings as f64, cols + 1, 0.0);
        if feats.fine_grain {
            // dot -> house (sigma), house -> memory (v0, rkk),
            // house -> dot (inv).
            b.xfer(p.w_out, p.sigma, 1);
        } else {
            // sigma round-trips through the scratchpad.
            b.st(tmp.lin(0, 1), p.w_out);
            b.barrier();
            b.ld(tmp.lin(0, 1), p.sigma);
        }
        // v0 overwrites a_kk; r_kk parked in the diagonal store.
        b.st(a.lin(at(n_i, k, k), 1), p.v0);
        b.st(plan.lay.rdiag.lin(k, 1), p.rkk);
        if cols == 0 {
            // Last iteration: drain the unused inv output.
            b.st(tmp.lin(1, 1), p.inv);
            continue;
        }
        let inv_uses = (len * cols) as f64;
        if feats.fine_grain {
            b.xfer_reuse(p.inv, p.dot_inv, 1, Reuse::uniform(inv_uses));
        } else {
            b.st(tmp.lin(1, 1), p.inv);
            b.barrier();
            b.ld_reuse(tmp.lin(1, 1), p.dot_inv, Reuse::uniform(inv_uses));
        }
        // Trailing block patterns (rectangular within one iteration).
        let block = a.rect(at(n_i, k, k + 1), 1, len, n_i, cols);
        let vpat = a.rect(at(n_i, k, k), 1, len, 0, cols);
        // w dots over the trailing columns. The rectangular-only
        // decomposition must interleave the two streams per column —
        // back-to-back per-row commands head-of-line block the queue.
        if feats.inductive {
            b.ld_rmw(block.clone(), p.dot_a, 0);
            b.ld(vpat.clone(), p.dot_v);
        } else {
            for j in 0..cols {
                b.ld_rmw(a.lin(at(n_i, k, k + 1 + j), len), p.dot_a, 0);
                b.ld(a.lin(at(n_i, k, k), len), p.dot_v);
                if !feats.fine_grain {
                    // Drain each w_j to memory as it is produced — the
                    // 16-deep output FIFO cannot hold a whole trailing
                    // block's worth of emissions at n=32.
                    b.st(tmp.lin(2 + j, 1), p.w_out);
                }
            }
        }
        if feats.fine_grain {
            // w_j stream: one scalar per column, each reused len times.
            b.xfer_reuse(p.w_out, p.upd_w, cols, Reuse::uniform(len as f64));
            // In-place update of the trailing block.
            b.st_rmw(block.clone(), p.a_upd);
            b.ld_rmw(block, p.upd_a, 0);
            b.ld(vpat, p.upd_v);
        } else {
            // w_j through memory. (The rectangular-only decomposition
            // already interleaved these stores with the loads above —
            // decomposed streams head-of-line block the command queue
            // and overflow the output FIFO otherwise.)
            if feats.inductive {
                for j in 0..cols {
                    b.st(tmp.lin(2 + j, 1), p.w_out);
                }
            }
            b.barrier();
            for j in 0..cols {
                b.ld_reuse(tmp.lin(2 + j, 1), p.upd_w, Reuse::uniform(len as f64));
                let colp = a.lin(at(n_i, k, k + 1 + j), len);
                b.st_rmw(colp.clone(), p.a_upd);
                b.ld_rmw(colp, p.upd_a, 0);
                b.ld(a.lin(at(n_i, k, k), len), p.upd_v);
            }
        }
    }
    Ok(b.finish())
}

/// Scalar mirror of the exact simulated algorithm (same formulas and
/// reduction grouping are within f64 tolerance of the lane's order).
pub fn qr_mirror(a: &mut Mat, rdiag: &mut [f64]) {
    let n = a.rows;
    for k in 0..n {
        let sigma: f64 = (k..n).map(|i| a[(i, k)] * a[(i, k)]).sum();
        let akk = a[(k, k)];
        let nrm = sigma.sqrt();
        let sg = if akk >= 0.0 { 1.0 } else { -1.0 };
        let v0 = akk + sg * nrm;
        rdiag[k] = -sg * nrm;
        let vn2 = sigma - akk * akk + v0 * v0;
        let inv = 2.0 / vn2;
        a[(k, k)] = v0;
        for j in k + 1..n {
            let w: f64 = (k..n).map(|i| a[(i, k)] * a[(i, j)]).sum::<f64>() * inv;
            for i in k..n {
                let vi = a[(i, k)];
                a[(i, j)] -= w * vi;
            }
        }
    }
}

pub struct Instance {
    pub a: Mat,
    pub a_ref: Mat,
    pub rdiag_ref: Vec<f64>,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(n, n, |i, j| {
        (((i * 3 + j * 7 + seed) as f64) * 0.23).sin() + if i == j { 2.0 } else { 0.0 }
    });
    let mut a_ref = a.clone();
    let mut rdiag_ref = vec![0.0; n];
    qr_mirror(&mut a_ref, &mut rdiag_ref);
    Instance { a, a_ref, rdiag_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    let lay = layout(n).expect("qr layout fits the lane scratchpad");
    for j in 0..n {
        for i in 0..n {
            lane.spad
                .write(lay.a.addr(at(n as i64, i as i64, j as i64)), inst.a[(i, j)]);
        }
    }
    lane.spad.write(lay.one.addr(0), 1.0);
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let lay = layout(n)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let (a_region, rdiag_region) = (lay.a, lay.rdiag);
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows as i64;
            // R's upper triangle (rows above diag) + diagonal + the
            // in-place Householder vectors below the diagonal.
            for j in 0..nn {
                for i in 0..nn {
                    let got = m.lanes[l].spad.read(a_region.addr(at(nn, i, j)));
                    let want = inst.a_ref[(i as usize, j as usize)];
                    let err = (got - want).abs();
                    if err > 1e-8 {
                        return Err(format!(
                            "lane {l} A[{i}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
            for k in 0..nn {
                let got = m.lanes[l].spad.read(rdiag_region.addr(k));
                let err = (got - inst.rdiag_ref[k as usize]).abs();
                if err > 1e-8 {
                    return Err(format!("lane {l} rdiag[{k}]"));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * 4.0 / 3.0 * (n * n * n) as f64;
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::qr as qr_ref;

    #[test]
    fn mirror_matches_householder_reference() {
        // The mirror's R must equal the library QR's R up to signs.
        let n = 8;
        let inst = instance(n, 0);
        let (_, r) = qr_ref(&inst.a);
        for i in 0..n {
            let scale = inst.rdiag_ref[i] / r[(i, i)];
            assert!((scale.abs() - 1.0).abs() < 1e-9, "row {i} scale {scale}");
            for j in i + 1..n {
                assert!(
                    (inst.a_ref[(i, j)] - scale * r[(i, j)]).abs() < 1e-8,
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn fgop_qr_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            prepare(12, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn heterogeneous_fabric_helps_qr() {
        // QR's sub-critical house region is long: Fig 19 shows the big
        // jump only lands once the temporal fabric exists.
        let no_het = prepare(
            24,
            Features { heterogeneous: false, ..Features::ALL },
            Goal::Latency,
        )
        .unwrap()
        .execute()
        .unwrap();
        let het = prepare(24, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(het.cycles < no_het.cycles, "{} vs {}", het.cycles, no_het.cycles);
    }

    #[test]
    fn throughput_runs_eight_lanes() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }

    #[test]
    fn program_passes_the_vsc_check() {
        for feats in [Features::ALL, Features::NONE] {
            let prog = program(12, feats, LaneMask::one(0)).unwrap();
            let rep = crate::vsc::check_program(&prog, &SimConfig::default());
            assert!(rep.errors().is_empty(), "{feats:?}:\n{rep}");
        }
    }
}
