//! Householder QR decomposition (paper Fig 6 left). Per iteration k:
//!
//! * `dot` (critical, vectorized reduce): sigma = a_k . a_k, then
//!   w_j = inv * (v . a_j) for every trailing column — the AccReduce
//!   dataflow emits once per column (gated);
//! * `house` (non-critical, the paper's "complex sub-critical region"
//!   that needs the temporal fabric): norm/sign/v0/r_kk/inv chain with
//!   sqrt and divides;
//! * `update` (critical): a_j -= w_j * v.
//!
//! Fine-grain ordered deps: dot -> house (sigma), house -> dot (inv,
//! reused across all trailing dots), dot -> update (w_j, reused n-k
//! times — the `tau`/`w[j]` edges of Fig 6). The Householder vector v
//! lives in-place in column k (v0 overwrites a_kk; R's diagonal is
//! stored aside), and the v streams re-read it per column with a
//! rewinding (c_j = 0) pattern — stream-reuse cutting SPAD bandwidth.

use std::sync::Arc;

use super::{machine, push_ld, push_st, Features, Goal, Prepared, WlError};
use crate::compiler::Configured;
use crate::dataflow::{Criticality, DfgBuilder, LaneConfig, Op, Operand};
use crate::isa::{
    Cmd, ConstPattern, LaneMask, Pattern2D, Program, Reuse, VsCommand, XferDst,
};
use crate::sim::Machine;
use crate::util::linalg::Mat;

const W: usize = 4;

/// A (column-major, n<=32 => 1024 words), R diagonal, constants/scratch.
const A_BASE: i64 = 0;
const RDIAG_BASE: i64 = 1060;
const ONE_ADDR: i64 = 1100;
const TMP_BASE: i64 = 1200;

// Ports. In: 0=dot.a(W), 1=dot.v(W), 2=dot gate(1), 3=dot.inv(1),
// 4=house.sigma(1), 5=house.akk(1), 6=upd.a(W), 7=upd.v(W), 8=upd.w(1).
// Out: 0=w' (dot), 1=v0, 2=rkk, 3=inv, 4=a_upd.
fn config(feats: Features) -> Result<Arc<Configured>, WlError> {
    let mut d = DfgBuilder::new("dot", Criticality::Critical);
    let a = d.in_port(0, W);
    let v = d.in_port(1, W);
    let gate = d.in_port(2, 1);
    let inv = d.in_port(3, 1);
    let prod = d.node(Op::Mul, &[a, v]);
    let s = d.node(Op::AccReduce, &[prod, gate]);
    let w = d.node(Op::Mul, &[s, inv]);
    d.out_gated(0, w, 1, Some(gate));

    let mut h = DfgBuilder::new("house", Criticality::NonCritical);
    let sigma = h.in_port(4, 1);
    let akk = h.in_port(5, 1);
    let nrm = h.node(Op::Sqrt, &[sigma]);
    let ge = h.node(Op::CmpGe, &[akk, Operand::Const(0.0)]);
    let sg = h.node(Op::Select, &[ge, Operand::Const(1.0), Operand::Const(-1.0)]);
    let sn = h.node(Op::Mul, &[sg, nrm]);
    let v0 = h.node(Op::Add, &[akk, sn]);
    let rkk = h.node(Op::Neg, &[sn]);
    let akk2 = h.node(Op::Mul, &[akk, akk]);
    let v02 = h.node(Op::Mul, &[v0, v0]);
    let t1 = h.node(Op::Sub, &[sigma, akk2]);
    let vn2 = h.node(Op::Add, &[t1, v02]);
    let invv = h.node(Op::Div, &[Operand::Const(2.0), vn2]);
    h.out(1, v0, 1);
    h.out(2, rkk, 1);
    h.out(3, invv, 1);

    let mut u = DfgBuilder::new("update", Criticality::Critical);
    let a2 = u.in_port(6, W);
    let v2 = u.in_port(7, W);
    let w2 = u.in_port(8, 1);
    let p2 = u.node(Op::Mul, &[v2, w2]);
    let upd = u.node(Op::Sub, &[a2, p2]);
    u.out(4, upd, W);

    let cfg = LaneConfig {
        name: "qr".into(),
        dfgs: vec![d.build(), h.build(), u.build()],
    };
    super::cached_config(&cfg.name.clone(), feats, move || Ok(cfg))
}

fn at(n: i64, i: i64, j: i64) -> i64 {
    A_BASE + j * n + i
}

pub fn program(n: usize, feats: Features, mask: LaneMask) -> Result<Program, WlError> {
    let cfg = config(feats)?;
    let n_i = n as i64;
    let vs = |c: Cmd| VsCommand::new(c, mask);
    let mut p: Program = vec![vs(Cmd::Configure(cfg))];

    for k in 0..n_i {
        let len = n_i - k; // live column height (rows k..n)
        let cols = n_i - k - 1; // trailing columns
        p.push(vs(Cmd::Barrier));
        // a_kk (original) for the house region.
        push_ld(&mut p, mask, Pattern2D::lin(at(n_i, k, k), 1), 5, None, feats, None);
        // sigma dot: column k against itself, multiplier 1.0.
        push_ld(&mut p, mask, Pattern2D::lin(at(n_i, k, k), len), 0, None, feats, None);
        push_ld(&mut p, mask, Pattern2D::lin(at(n_i, k, k), len), 1, None, feats, None);
        push_ld(
            &mut p,
            mask,
            Pattern2D::lin(ONE_ADDR, 1),
            3,
            Some(Reuse::uniform(len as f64)),
            feats,
            None,
        );
        // Emit gate for all (1 + cols) dots of this iteration. Scalar
        // gate streams pace *firings*: ceil(len/W) per column.
        let firings = (len + W as i64 - 1) / W as i64;
        p.push(vs(Cmd::ConstSt {
            pat: ConstPattern::last_of_row(1.0, 0.0, firings as f64, cols + 1, 0.0),
            port: 2,
        }));
        if feats.fine_grain {
            // dot -> house (sigma), house -> memory (v0, rkk),
            // house -> dot (inv).
            p.push(vs(Cmd::Xfer {
                src_port: 0,
                dst_port: 4,
                dst: XferDst::Local,
                n: 1,
                reuse: None,
            }));
        } else {
            // sigma round-trips through the scratchpad.
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(TMP_BASE, 1),
                port: 0,
                rmw: false,
            }));
            p.push(vs(Cmd::Barrier));
            push_ld(&mut p, mask, Pattern2D::lin(TMP_BASE, 1), 4, None, feats, None);
        }
        // v0 overwrites a_kk; r_kk parked in the diagonal store.
        p.push(vs(Cmd::LocalSt {
            pat: Pattern2D::lin(at(n_i, k, k), 1),
            port: 1,
            rmw: false,
        }));
        p.push(vs(Cmd::LocalSt {
            pat: Pattern2D::lin(RDIAG_BASE + k, 1),
            port: 2,
            rmw: false,
        }));
        if cols == 0 {
            // Last iteration: drain the unused inv output.
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(TMP_BASE + 1, 1),
                port: 3,
                rmw: false,
            }));
            continue;
        }
        let inv_uses = (len * cols) as f64;
        if feats.fine_grain {
            p.push(vs(Cmd::Xfer {
                src_port: 3,
                dst_port: 3,
                dst: XferDst::Local,
                n: 1,
                reuse: Some(Reuse::uniform(inv_uses)),
            }));
        } else {
            p.push(vs(Cmd::LocalSt {
                pat: Pattern2D::lin(TMP_BASE + 1, 1),
                port: 3,
                rmw: false,
            }));
            p.push(vs(Cmd::Barrier));
            push_ld(
                &mut p,
                mask,
                Pattern2D::lin(TMP_BASE + 1, 1),
                3,
                Some(Reuse::uniform(inv_uses)),
                feats,
                None,
            );
        }
        // Trailing block patterns (rectangular within one iteration).
        let block = Pattern2D::rect(at(n_i, k, k + 1), 1, len, n_i, cols);
        let vpat = Pattern2D::rect(at(n_i, k, k), 1, len, 0, cols);
        // w dots over the trailing columns. The rectangular-only
        // decomposition must interleave the two streams per column —
        // back-to-back per-row commands head-of-line block the queue.
        if feats.inductive {
            push_ld(&mut p, mask, block.clone(), 0, None, feats, Some(0));
            push_ld(&mut p, mask, vpat.clone(), 1, None, feats, None);
        } else {
            for j in 0..cols {
                push_ld(
                    &mut p,
                    mask,
                    Pattern2D::lin(at(n_i, k, k + 1 + j), len),
                    0,
                    None,
                    feats,
                    Some(0),
                );
                push_ld(
                    &mut p,
                    mask,
                    Pattern2D::lin(at(n_i, k, k), len),
                    1,
                    None,
                    feats,
                    None,
                );
                if !feats.fine_grain {
                    // Drain each w_j to memory as it is produced — the
                    // 16-deep output FIFO cannot hold a whole trailing
                    // block's worth of emissions at n=32.
                    p.push(vs(Cmd::LocalSt {
                        pat: Pattern2D::lin(TMP_BASE + 2 + j, 1),
                        port: 0,
                        rmw: false,
                    }));
                }
            }
        }
        if feats.fine_grain {
            // w_j stream: one scalar per column, each reused len times.
            p.push(vs(Cmd::Xfer {
                src_port: 0,
                dst_port: 8,
                dst: XferDst::Local,
                n: cols,
                reuse: Some(Reuse::uniform(len as f64)),
            }));
            // In-place update of the trailing block.
            push_st(&mut p, mask, block.clone(), 4, true, feats);
            push_ld(&mut p, mask, block, 6, None, feats, Some(0));
            push_ld(&mut p, mask, vpat, 7, None, feats, None);
        } else {
            // w_j through memory. (The rectangular-only decomposition
            // already interleaved these stores with the loads above —
            // decomposed streams head-of-line block the command queue
            // and overflow the output FIFO otherwise.)
            if feats.inductive {
                for j in 0..cols {
                    p.push(vs(Cmd::LocalSt {
                        pat: Pattern2D::lin(TMP_BASE + 2 + j, 1),
                        port: 0,
                        rmw: false,
                    }));
                }
            }
            p.push(vs(Cmd::Barrier));
            for j in 0..cols {
                push_ld(
                    &mut p,
                    mask,
                    Pattern2D::lin(TMP_BASE + 2 + j, 1),
                    8,
                    Some(Reuse::uniform(len as f64)),
                    feats,
                    None,
                );
                let colp = Pattern2D::lin(at(n_i, k, k + 1 + j), len);
                push_st(&mut p, mask, colp.clone(), 4, true, feats);
                push_ld(&mut p, mask, colp, 6, None, feats, Some(0));
                push_ld(
                    &mut p,
                    mask,
                    Pattern2D::lin(at(n_i, k, k), len),
                    7,
                    None,
                    feats,
                    None,
                );
            }
        }
    }
    p.push(vs(Cmd::Wait));
    Ok(p)
}

/// Scalar mirror of the exact simulated algorithm (same formulas and
/// reduction grouping are within f64 tolerance of the lane's order).
pub fn qr_mirror(a: &mut Mat, rdiag: &mut [f64]) {
    let n = a.rows;
    for k in 0..n {
        let sigma: f64 = (k..n).map(|i| a[(i, k)] * a[(i, k)]).sum();
        let akk = a[(k, k)];
        let nrm = sigma.sqrt();
        let sg = if akk >= 0.0 { 1.0 } else { -1.0 };
        let v0 = akk + sg * nrm;
        rdiag[k] = -sg * nrm;
        let vn2 = sigma - akk * akk + v0 * v0;
        let inv = 2.0 / vn2;
        a[(k, k)] = v0;
        for j in k + 1..n {
            let w: f64 = (k..n).map(|i| a[(i, k)] * a[(i, j)]).sum::<f64>() * inv;
            for i in k..n {
                let vi = a[(i, k)];
                a[(i, j)] -= w * vi;
            }
        }
    }
}

pub struct Instance {
    pub a: Mat,
    pub a_ref: Mat,
    pub rdiag_ref: Vec<f64>,
}

pub fn instance(n: usize, seed: usize) -> Instance {
    let a = Mat::from_fn(n, n, |i, j| {
        (((i * 3 + j * 7 + seed) as f64) * 0.23).sin() + if i == j { 2.0 } else { 0.0 }
    });
    let mut a_ref = a.clone();
    let mut rdiag_ref = vec![0.0; n];
    qr_mirror(&mut a_ref, &mut rdiag_ref);
    Instance { a, a_ref, rdiag_ref }
}

pub fn load_lane(lane: &mut crate::sim::Lane, inst: &Instance) {
    let n = inst.a.rows;
    for j in 0..n {
        for i in 0..n {
            lane.spad.write(at(n as i64, i as i64, j as i64), inst.a[(i, j)]);
        }
    }
    lane.spad.write(ONE_ADDR, 1.0);
}

pub fn prepare(n: usize, feats: Features, goal: Goal) -> Result<Prepared, WlError> {
    let lanes = match goal {
        Goal::Latency => 1,
        Goal::Throughput => 8,
    };
    let mask = LaneMask::first_n(lanes);
    let prog = program(n, feats, mask)?;
    let mut m = machine(lanes);
    let insts: Vec<Instance> = (0..lanes).map(|l| instance(n, l)).collect();
    for (l, inst) in insts.iter().enumerate() {
        load_lane(&mut m.lanes[l], inst);
    }
    let verify = Box::new(move |m: &Machine| {
        let mut max_err = 0.0f64;
        for (l, inst) in insts.iter().enumerate() {
            let nn = inst.a.rows as i64;
            // R's upper triangle (rows above diag) + diagonal + the
            // in-place Householder vectors below the diagonal.
            for j in 0..nn {
                for i in 0..nn {
                    let got = m.lanes[l].spad.read(at(nn, i, j));
                    let want = inst.a_ref[(i as usize, j as usize)];
                    let err = (got - want).abs();
                    if err > 1e-8 {
                        return Err(format!(
                            "lane {l} A[{i}][{j}]: got {got}, want {want}"
                        ));
                    }
                    max_err = max_err.max(err);
                }
            }
            for k in 0..nn {
                let got = m.lanes[l].spad.read(RDIAG_BASE + k);
                let err = (got - inst.rdiag_ref[k as usize]).abs();
                if err > 1e-8 {
                    return Err(format!("lane {l} rdiag[{k}]"));
                }
                max_err = max_err.max(err);
            }
        }
        Ok(max_err)
    });
    let flops = lanes as f64 * 4.0 / 3.0 * (n * n * n) as f64;
    Ok(Prepared { machine: m, prog, verify, flops, problems: lanes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::linalg::qr as qr_ref;

    #[test]
    fn mirror_matches_householder_reference() {
        // The mirror's R must equal the library QR's R up to signs.
        let n = 8;
        let inst = instance(n, 0);
        let (_, r) = qr_ref(&inst.a);
        for i in 0..n {
            let scale = inst.rdiag_ref[i] / r[(i, i)];
            assert!((scale.abs() - 1.0).abs() < 1e-9, "row {i} scale {scale}");
            for j in i + 1..n {
                assert!(
                    (inst.a_ref[(i, j)] - scale * r[(i, j)]).abs() < 1e-8,
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn fgop_qr_is_correct_all_sizes() {
        for n in [8, 12, 16, 24, 32] {
            prepare(n, Features::ALL, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn all_feature_ladder_versions_are_correct() {
        for (name, feats) in Features::ladder() {
            prepare(12, feats, Goal::Latency)
                .unwrap()
                .execute()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn heterogeneous_fabric_helps_qr() {
        // QR's sub-critical house region is long: Fig 19 shows the big
        // jump only lands once the temporal fabric exists.
        let no_het = prepare(
            24,
            Features { heterogeneous: false, ..Features::ALL },
            Goal::Latency,
        )
        .unwrap()
        .execute()
        .unwrap();
        let het = prepare(24, Features::ALL, Goal::Latency)
            .unwrap()
            .execute()
            .unwrap();
        assert!(het.cycles < no_het.cycles, "{} vs {}", het.cycles, no_het.cycles);
    }

    #[test]
    fn throughput_runs_eight_lanes() {
        let r = prepare(12, Features::ALL, Goal::Throughput)
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(r.problems, 8);
    }
}
