//! Heterogeneous compute-fabric geometry (paper §6.3, Fig 15, Table 3).
//!
//! Per lane: a circuit-switched mesh of dedicated tiles (14 add-class,
//! 9 multiply, 3 sqrt/div) with a small temporal region (default 2x1
//! triggered-instruction tiles, 32 insts/FU) embedded in the mesh.
//! Table 6 accounts 23 dedicated + 2 temporal network nodes; we lay the
//! 26 FU tiles + 2 temporal tiles + 2 pass-through switches on a 6x5 grid.

use crate::dataflow::FuClass;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileKind {
    Fu(FuClass),
    /// Triggered-instruction (temporal) tile.
    Temporal,
    /// Routing-only switch (no FU).
    Pass,
}

#[derive(Clone, Debug)]
pub struct FabricSpec {
    pub width: usize,
    pub height: usize,
    pub tiles: Vec<TileKind>, // row-major width*height
    /// Static instructions a temporal FU can hold (Table 3: 32).
    pub temporal_capacity: usize,
    /// Instructions the temporal region retires per cycle (1 per FU).
    pub temporal_issue: usize,
}

impl FabricSpec {
    /// The paper's per-lane fabric: 14 add, 9 mul, 3 sqrt/div, `tw x th`
    /// temporal region (default 2x1; Fig 20 sweeps this).
    pub fn revel(tw: usize, th: usize) -> Self {
        // The FU inventory is fixed; a larger temporal region grows the
        // grid (paper Q8: temporal tiles *add* area, 12062 vs 2265 um^2).
        let width = 6;
        let needed = 14 + 9 + 3 + tw * th + 2; // FUs + temporal + switches
        let height = (needed + width - 1) / width;
        let mut tiles = Vec::with_capacity(width * height);
        // Deterministic layout: temporal region in the lower-left corner
        // (Fig 15), sqrt/div along the right edge, adders/multipliers
        // interleaved elsewhere.
        let mut budget_add = 14usize;
        let mut budget_mul = 9usize;
        let mut budget_sd = 3usize;
        let mut budget_temporal = tw * th;
        for y in 0..height {
            for x in 0..width {
                let in_temporal = x < tw && y >= height - th;
                let k = if in_temporal && budget_temporal > 0 {
                    budget_temporal -= 1;
                    TileKind::Temporal
                } else if x == width - 1 && budget_sd > 0 {
                    budget_sd -= 1;
                    TileKind::Fu(FuClass::SqrtDiv)
                } else if (x + y) % 2 == 0 && budget_add > 0 {
                    budget_add -= 1;
                    TileKind::Fu(FuClass::Add)
                } else if budget_mul > 0 {
                    budget_mul -= 1;
                    TileKind::Fu(FuClass::Mul)
                } else if budget_add > 0 {
                    budget_add -= 1;
                    TileKind::Fu(FuClass::Add)
                } else {
                    TileKind::Pass
                };
                tiles.push(k);
            }
        }
        Self { width, height, tiles, temporal_capacity: 32, temporal_issue: tw * th }
    }

    pub fn default_revel() -> Self {
        Self::revel(2, 1)
    }

    /// All-dedicated variant (Q9): temporal tiles replaced by pass-through.
    pub fn homogeneous() -> Self {
        let mut f = Self::revel(0, 0);
        f.temporal_issue = 0;
        f
    }

    pub fn idx(&self, x: usize, y: usize) -> usize {
        y * self.width + x
    }

    pub fn xy(&self, idx: usize) -> (usize, usize) {
        (idx % self.width, idx / self.width)
    }

    pub fn count(&self, kind: TileKind) -> usize {
        self.tiles.iter().filter(|&&t| t == kind).count()
    }

    pub fn fu_count(&self, cls: FuClass) -> usize {
        self.count(TileKind::Fu(cls))
    }

    pub fn temporal_tiles(&self) -> usize {
        self.count(TileKind::Temporal)
    }

    /// Mesh neighbors (4-connected).
    pub fn neighbors(&self, idx: usize) -> impl Iterator<Item = usize> + '_ {
        let (x, y) = self.xy(idx);
        let mut v = Vec::with_capacity(4);
        if x > 0 {
            v.push(self.idx(x - 1, y));
        }
        if x + 1 < self.width {
            v.push(self.idx(x + 1, y));
        }
        if y > 0 {
            v.push(self.idx(x, y - 1));
        }
        if y + 1 < self.height {
            v.push(self.idx(x, y + 1));
        }
        v.into_iter()
    }

    /// Mesh neighbors in ascending tile-index order (north, west, east,
    /// south). Routers expand neighbors through this so search order —
    /// and therefore tie-breaking — is pinned explicitly rather than
    /// inherited from whatever order `neighbors` happens to push.
    pub fn neighbors_sorted(&self, idx: usize) -> impl Iterator<Item = usize> + '_ {
        let (x, y) = self.xy(idx);
        let mut v = Vec::with_capacity(4);
        if y > 0 {
            v.push(self.idx(x, y - 1));
        }
        if x > 0 {
            v.push(self.idx(x - 1, y));
        }
        if x + 1 < self.width {
            v.push(self.idx(x + 1, y));
        }
        if y + 1 < self.height {
            v.push(self.idx(x, y + 1));
        }
        v.into_iter()
    }

    /// Directed link id between adjacent tiles (for congestion tracking).
    pub fn link_id(&self, a: usize, b: usize) -> usize {
        a * self.width * self.height + b
    }

    pub fn num_tiles(&self) -> usize {
        self.width * self.height
    }

    /// Port attach point for a global port id (paper: "each port attaches
    /// to a unique location within the grid"): input ports along the top
    /// row, output ports along the bottom row, spread by id.
    pub fn in_port_tile(&self, gid: usize) -> usize {
        self.idx(gid % self.width, 0)
    }

    pub fn out_port_tile(&self, gid: usize) -> usize {
        self.idx(gid % self.width, self.height - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn revel_fabric_matches_table3_inventory() {
        let f = FabricSpec::default_revel();
        assert_eq!(f.fu_count(FuClass::Add), 14);
        assert_eq!(f.fu_count(FuClass::Mul), 9);
        assert_eq!(f.fu_count(FuClass::SqrtDiv), 3);
        assert_eq!(f.temporal_tiles(), 2);
        assert!(f.num_tiles() >= 28);
    }

    #[test]
    fn temporal_sweep_changes_region_size() {
        for (tw, th) in [(0, 0), (1, 1), (2, 1), (2, 2), (4, 2)] {
            let f = FabricSpec::revel(tw, th);
            assert_eq!(f.temporal_tiles(), tw * th);
            assert_eq!(f.temporal_issue, tw * th);
            // FU inventory preserved regardless of temporal size.
            assert_eq!(f.fu_count(FuClass::Add), 14);
        }
    }

    #[test]
    fn neighbors_form_a_mesh() {
        let f = FabricSpec::default_revel();
        let corner = f.idx(0, 0);
        assert_eq!(f.neighbors(corner).count(), 2);
        let mid = f.idx(2, 2);
        assert_eq!(f.neighbors(mid).count(), 4);
        // Symmetric adjacency.
        for t in 0..f.num_tiles() {
            for n in f.neighbors(t) {
                assert!(f.neighbors(n).any(|m| m == t));
            }
        }
    }

    #[test]
    fn sorted_neighbors_ascend() {
        let f = FabricSpec::default_revel();
        for t in 0..f.num_tiles() {
            let v: Vec<usize> = f.neighbors_sorted(t).collect();
            assert!(v.windows(2).all(|w| w[0] < w[1]), "tile {t}: {v:?}");
            let mut u: Vec<usize> = f.neighbors(t).collect();
            u.sort_unstable();
            assert_eq!(v, u, "same adjacency, pinned order");
        }
    }

    #[test]
    fn port_tiles_are_on_edges() {
        let f = FabricSpec::default_revel();
        for gid in 0..8 {
            assert_eq!(f.xy(f.in_port_tile(gid)).1, 0);
            assert_eq!(f.xy(f.out_port_tile(gid)).1, f.height - 1);
        }
    }
}
