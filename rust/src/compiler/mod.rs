//! Spatial-architecture compiler (paper §8): placement + routing of
//! dataflow graphs onto the heterogeneous fabric, producing the per-DFG
//! timing summaries (II, pipeline depth) the cycle-level simulator uses.

pub mod fabric;
pub mod place;

pub use fabric::{FabricSpec, TileKind};
pub use place::{
    compile, CompileError, CompileOptions, DfgTiming, PlaceStrategy, Placement,
};

use crate::dataflow::LaneConfig;
use std::sync::Arc;

/// A lane configuration compiled onto a fabric — what the `Configure`
/// command broadcasts to a lane (config bits + the timing the simulator
/// derives from placement).
#[derive(Clone, Debug)]
pub struct Configured {
    pub config: LaneConfig,
    pub placement: Placement,
}

impl Configured {
    /// Compile `config` onto `fabric` and package it for `Cmd::Configure`.
    pub fn new(
        config: LaneConfig,
        fabric: &FabricSpec,
        opts: &CompileOptions,
    ) -> Result<Arc<Self>, CompileError> {
        let placement = compile(&config, fabric, opts)?;
        Ok(Arc::new(Self { config, placement }))
    }

    /// Cycles a lane spends applying this configuration once quiescent
    /// (config-bit broadcast over the 512-bit bus; proportional to mapped
    /// instructions — the paper's reconfiguration penalty, Q5).
    pub fn config_cycles(&self) -> u64 {
        let insts: usize = self.config.dfgs.iter().map(|d| d.insts()).sum();
        8 + 2 * insts as u64
    }
}
