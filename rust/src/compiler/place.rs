//! Spatial compiler (paper §8): map every dataflow's nodes onto fabric
//! tiles and route their edges on the circuit-switched mesh.
//!
//! Approach, as in the paper: stochastic placement (simulated annealing)
//! with a Pathfinder-style negotiated router — links start cheap, overuse
//! raises per-link history costs, and rerouting iterates until no link is
//! shared or the iteration budget is spent. Dedicated nodes claim a
//! FU-class-compatible tile each (vector nodes claim ceil(w/2) subword
//! tiles — modeled as one *placement* tile plus a width cost); temporal
//! nodes pack into temporal tiles up to the 32-inst capacity.

use std::collections::HashMap;

use super::fabric::{FabricSpec, TileKind};
use crate::dataflow::{Criticality, Dfg, FuClass, LaneConfig, Operand};
use crate::util::Rng;

/// Per-dataflow timing summary the simulator consumes.
#[derive(Clone, Debug)]
pub struct DfgTiming {
    /// Firing initiation interval (cycles between successive firings).
    pub ii: u64,
    /// Port-to-port pipeline depth (op latencies + routed hops).
    pub depth: u64,
    /// True if mapped onto the temporal region.
    pub temporal: bool,
    /// Static instruction count (temporal occupancy).
    pub insts: usize,
}

/// Result of compiling a LaneConfig onto a fabric.
#[derive(Clone, Debug)]
pub struct Placement {
    pub timing: Vec<DfgTiming>,
    /// node (dfg_idx, node_idx) -> tile index (dedicated-mapped nodes).
    pub tile_of: HashMap<(usize, usize), usize>,
    /// Total routed wirelength (hops) — annealing objective.
    pub wirelength: usize,
    /// Residual link overuse after negotiation (0 = legal routing).
    pub overuse: usize,
    /// Dedicated tiles consumed (for area/utilization reporting).
    pub tiles_used: usize,
    /// Temporal instructions placed.
    pub temporal_insts: usize,
}

#[derive(Clone, Debug)]
pub struct CompileOptions {
    /// Heterogeneous fabric enabled (paper Feature 5). When false,
    /// non-critical dataflows have no temporal region to live in and are
    /// serialized through shared dedicated resources (Fig 19's pre-het
    /// configurations; Q9's all-dedicated alternative costs 2.75x area).
    pub heterogeneous: bool,
    pub anneal_iters: usize,
    pub seed: u64,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self { heterogeneous: true, anneal_iters: 300, seed: 1 }
    }
}

#[derive(Debug)]
pub enum CompileError {
    Resources(String),
    Ports(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Resources(s) => write!(f, "resource overflow: {s}"),
            CompileError::Ports(s) => write!(f, "port error: {s}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// Compile a lane configuration onto the fabric.
pub fn compile(
    cfg: &LaneConfig,
    fabric: &FabricSpec,
    opts: &CompileOptions,
) -> Result<Placement, CompileError> {
    cfg.validate().map_err(CompileError::Ports)?;

    // ---- Partition dataflows: dedicated vs temporal -------------------
    let mut dedicated: Vec<usize> = Vec::new();
    let mut temporal: Vec<usize> = Vec::new();
    for (i, d) in cfg.dfgs.iter().enumerate() {
        match d.criticality {
            Criticality::Critical => dedicated.push(i),
            Criticality::NonCritical => {
                if opts.heterogeneous && fabric.temporal_tiles() > 0 {
                    temporal.push(i)
                } else {
                    dedicated.push(i) // forced onto dedicated substrate
                }
            }
        }
    }

    // ---- Resource check (subword-SIMD tile demand) ---------------------
    let mut demand: HashMap<FuClass, usize> = HashMap::new();
    for &i in &dedicated {
        // Non-critical dfgs forced onto the dedicated fabric when the
        // temporal region is absent share tiles by time-multiplexing
        // (their firing is serialized; see timing below), so only
        // *critical* dfgs contribute pipelined tile demand.
        if cfg.dfgs[i].criticality == Criticality::Critical {
            for (k, v) in cfg.dfgs[i].tile_demand() {
                *demand.entry(k).or_insert(0) += v;
            }
        }
    }
    for (cls, need) in &demand {
        let have = fabric.fu_count(*cls);
        if *need > have {
            return Err(CompileError::Resources(format!(
                "{cls:?}: need {need} tiles, fabric has {have} \
                 (narrow the vector width)"
            )));
        }
    }
    let temporal_insts: usize = temporal.iter().map(|&i| cfg.dfgs[i].insts()).sum();
    let temporal_cap = fabric.temporal_tiles() * fabric.temporal_capacity;
    if temporal_insts > temporal_cap {
        return Err(CompileError::Resources(format!(
            "temporal region: {temporal_insts} insts > capacity {temporal_cap}"
        )));
    }

    // ---- Placement + routing of dedicated nodes ------------------------
    // One placement tile per node (FU-class compatible); the subword width
    // is accounted in the resource check above and in the area model.
    let mut rng = Rng::new(opts.seed);
    let nodes: Vec<(usize, usize)> = dedicated
        .iter()
        .flat_map(|&di| (0..cfg.dfgs[di].nodes.len()).map(move |ni| (di, ni)))
        .collect();

    let mut free: HashMap<FuClass, Vec<usize>> = HashMap::new();
    for (t, kind) in fabric.tiles.iter().enumerate() {
        if let TileKind::Fu(c) = kind {
            free.entry(*c).or_default().push(t);
        }
    }
    // Initial greedy placement (first-fit per class, round-robin offsets).
    let mut tile_of: HashMap<(usize, usize), usize> = HashMap::new();
    let mut used: HashMap<usize, (usize, usize)> = HashMap::new();
    {
        let mut cursor: HashMap<FuClass, usize> = HashMap::new();
        for &(di, ni) in &nodes {
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let pool = free.get(&cls).cloned().unwrap_or_default();
            if pool.is_empty() {
                return Err(CompileError::Resources(format!("no {cls:?} tiles")));
            }
            let c = cursor.entry(cls).or_insert(0);
            let mut placed = false;
            for k in 0..pool.len() {
                let t = pool[(*c + k) % pool.len()];
                if !used.contains_key(&t) {
                    tile_of.insert((di, ni), t);
                    used.insert(t, (di, ni));
                    *c = (*c + k + 1) % pool.len();
                    placed = true;
                    break;
                }
            }
            if !placed {
                // Time-multiplex: share the least-loaded tile of the class
                // (legal only for non-critical dfgs forced dedicated).
                let t = pool[rng.below(pool.len())];
                tile_of.insert((di, ni), t);
            }
        }
    }

    // Net list: (src tile endpoint, dst tile endpoint) per DFG edge.
    let nets = |tile_of: &HashMap<(usize, usize), usize>| -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for &di in &dedicated {
            let d: &Dfg = &cfg.dfgs[di];
            for (ni, n) in d.nodes.iter().enumerate() {
                let dst = tile_of[&(di, ni)];
                for opnd in [Some(n.a), n.b, n.c].into_iter().flatten() {
                    match opnd {
                        Operand::Node(j) => v.push((tile_of[&(di, j)], dst)),
                        Operand::Port(p) => {
                            v.push((fabric.in_port_tile(d.in_ports[p].gid), dst))
                        }
                        Operand::Const(_) => {}
                    }
                }
            }
            for o in &d.outs {
                v.push((tile_of[&(di, o.node)], fabric.out_port_tile(o.gid)));
            }
        }
        v
    };

    // Annealing over swap moves, objective = negotiated routing cost.
    let mut best = tile_of.clone();
    let (mut best_wl, mut best_ou) = route_cost(fabric, &nets(&tile_of));
    let move_candidates: Vec<(usize, usize)> = nodes.clone();
    if !move_candidates.is_empty() {
        let mut cur = tile_of.clone();
        let (mut cur_wl, mut cur_ou) = (best_wl, best_ou);
        for it in 0..opts.anneal_iters {
            let temp = 1.0 - it as f64 / opts.anneal_iters as f64;
            let &(di, ni) = &move_candidates[rng.below(move_candidates.len())];
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let pool = free.get(&cls).cloned().unwrap_or_default();
            if pool.len() < 2 {
                continue;
            }
            let new_tile = pool[rng.below(pool.len())];
            let old_tile = cur[&(di, ni)];
            if new_tile == old_tile {
                continue;
            }
            let mut cand = cur.clone();
            // Swap if occupied by a same-class node.
            if let Some(&other) = cand
                .iter()
                .find(|(_, &t)| t == new_tile)
                .map(|(k, _)| k)
                .as_ref()
            {
                cand.insert(*other, old_tile);
            }
            cand.insert((di, ni), new_tile);
            let (wl, ou) = route_cost(fabric, &nets(&cand));
            let cost = wl as f64 + 50.0 * ou as f64;
            let cur_cost = cur_wl as f64 + 50.0 * cur_ou as f64;
            if cost < cur_cost || rng.f64() < 0.1 * temp {
                cur = cand;
                cur_wl = wl;
                cur_ou = ou;
                let best_cost = best_wl as f64 + 50.0 * best_ou as f64;
                if (wl as f64) + 50.0 * (ou as f64) < best_cost {
                    best = cur.clone();
                    best_wl = wl;
                    best_ou = ou;
                }
            }
        }
    }
    let tile_of = best;

    // ---- Per-dfg timing -------------------------------------------------
    let avg_hops = if nodes.is_empty() {
        0
    } else {
        (best_wl / nets(&tile_of).len().max(1)).max(1)
    };
    let mut timing = Vec::with_capacity(cfg.dfgs.len());
    for (i, d) in cfg.dfgs.iter().enumerate() {
        let is_temporal = temporal.contains(&i);
        let insts = d.insts();
        let t = if is_temporal {
            // Triggered-instruction region: `temporal_issue` insts retire
            // per cycle across the region; a firing executes the DFG's
            // dependence chain (latency ~ chain with 1-cycle FUs + queue).
            let issue = fabric.temporal_issue.max(1);
            DfgTiming {
                ii: ((insts + issue - 1) / issue).max(1) as u64,
                depth: insts as u64 + 4,
                temporal: true,
                insts,
            }
        } else if d.criticality == Criticality::NonCritical {
            // Het disabled: serialized through shared dedicated resources
            // — one inst per cycle issue, double-pumped latency.
            DfgTiming {
                ii: insts.max(1) as u64,
                depth: 2 * insts as u64 + 4,
                temporal: false,
                insts,
            }
        } else {
            // Dedicated, fully pipelined: II limited only by unpipelined
            // FUs (div/sqrt: 5); depth = op critical path + routed hops.
            let ii = d.nodes.iter().map(|n| n.op.ii()).max().unwrap_or(1);
            DfgTiming {
                ii,
                depth: d.critical_path() + avg_hops as u64 * 2 + 2,
                temporal: false,
                insts,
            }
        };
        timing.push(t);
    }

    Ok(Placement {
        timing,
        tiles_used: tile_of.values().collect::<std::collections::HashSet<_>>().len(),
        tile_of,
        wirelength: best_wl,
        overuse: best_ou,
        temporal_insts,
    })
}

/// Pathfinder-lite: route all nets by BFS with history costs; returns
/// (total wirelength, residual overuse).
fn route_cost(fabric: &FabricSpec, nets: &[(usize, usize)]) -> (usize, usize) {
    let n = fabric.num_tiles();
    let mut history = vec![0.0f64; n * n];
    let mut total_wl = 0;
    let mut overuse = 0;
    for round in 0..4 {
        let mut usage: HashMap<usize, usize> = HashMap::new();
        total_wl = 0;
        for &(s, t) in nets {
            let path = bfs_route(fabric, s, t, &history, &usage);
            total_wl += path.len();
            for w in path.windows(2) {
                *usage.entry(fabric.link_id(w[0], w[1])).or_insert(0) += 1;
            }
        }
        overuse = usage.values().filter(|&&u| u > 1).map(|&u| u - 1).sum();
        if overuse == 0 {
            break;
        }
        // Raise history cost on congested links.
        for (link, &u) in &usage {
            if u > 1 {
                history[*link] += (u - 1) as f64 * (round + 1) as f64;
            }
        }
    }
    (total_wl, overuse)
}

fn bfs_route(
    fabric: &FabricSpec,
    s: usize,
    t: usize,
    history: &[f64],
    usage: &HashMap<usize, usize>,
) -> Vec<usize> {
    if s == t {
        return vec![s];
    }
    // Dijkstra over link costs 1 + history + current-usage penalty.
    let n = fabric.num_tiles();
    let mut dist = vec![f64::INFINITY; n];
    let mut prev = vec![usize::MAX; n];
    let mut heap = std::collections::BinaryHeap::new();
    dist[s] = 0.0;
    heap.push((std::cmp::Reverse(0u64), s));
    while let Some((std::cmp::Reverse(dq), u)) = heap.pop() {
        let du = dq as f64 / 1024.0;
        if du > dist[u] + 1e-9 {
            continue;
        }
        if u == t {
            break;
        }
        for v in fabric.neighbors(u) {
            let link = fabric.link_id(u, v);
            let cost = 1.0
                + history[link]
                + 2.0 * usage.get(&link).copied().unwrap_or(0) as f64;
            let nd = dist[u] + cost;
            if nd < dist[v] - 1e-9 {
                dist[v] = nd;
                prev[v] = u;
                heap.push((std::cmp::Reverse((nd * 1024.0) as u64), v));
            }
        }
    }
    let mut path = vec![t];
    let mut cur = t;
    while prev[cur] != usize::MAX {
        cur = prev[cur];
        path.push(cur);
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Criticality, DfgBuilder, Op};

    fn cholesky_like_config() -> LaneConfig {
        // point (non-critical): sqrt + div
        let mut p = DfgBuilder::new("point", Criticality::NonCritical);
        let akk = p.in_port(0, 1);
        let d = p.node(Op::Sqrt, &[akk]);
        let inva = p.node(Op::Div, &[crate::dataflow::Operand::Const(1.0), d]);
        p.out(0, d, 1);
        p.out(1, inva, 1);
        // vector (critical): col * inva
        let mut v = DfgBuilder::new("vector", Criticality::Critical);
        let col = v.in_port(1, 4);
        let s = v.in_port(2, 1);
        let sc = v.node(Op::Mul, &[col, s]);
        v.out(2, sc, 4);
        // matrix (critical): a - ci*cj
        let mut m = DfgBuilder::new("matrix", Criticality::Critical);
        let a = m.in_port(3, 4);
        let ci = m.in_port(4, 1);
        let cj = m.in_port(5, 4);
        let prod = m.node(Op::Mul, &[ci, cj]);
        let upd = m.node(Op::Sub, &[a, prod]);
        m.out(3, upd, 4);
        LaneConfig {
            name: "cholesky".into(),
            dfgs: vec![p.build(), v.build(), m.build()],
        }
    }

    #[test]
    fn compiles_cholesky_config_heterogeneous() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let p = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        assert_eq!(p.timing.len(), 3);
        assert!(p.timing[0].temporal, "point region on temporal fabric");
        assert!(!p.timing[1].temporal && !p.timing[2].temporal);
        assert_eq!(p.timing[2].ii, 1, "critical matrix region fully pipelined");
        assert!(p.timing[2].depth >= cfg.dfgs[2].critical_path());
        assert_eq!(p.overuse, 0, "router must legalize");
        assert_eq!(p.temporal_insts, 2);
    }

    #[test]
    fn het_disabled_serializes_noncritical() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let opts = CompileOptions { heterogeneous: false, ..Default::default() };
        let p = compile(&cfg, &fabric, &opts).unwrap();
        assert!(!p.timing[0].temporal);
        assert!(p.timing[0].ii >= 2, "serialized point region");
        // Critical dataflow unaffected.
        assert_eq!(p.timing[2].ii, 1);
    }

    #[test]
    fn resource_overflow_is_reported() {
        // Width-32 multiply chain: 16 mul tiles needed > 9 available.
        let mut b = DfgBuilder::new("wide", Criticality::Critical);
        let x = b.in_port(0, 32);
        let y = b.in_port(1, 32);
        let m = b.node(Op::Mul, &[x, y]);
        b.out(0, m, 32);
        let cfg = LaneConfig { name: "w".into(), dfgs: vec![b.build()] };
        let err = compile(&cfg, &FabricSpec::default_revel(), &CompileOptions::default());
        assert!(matches!(err, Err(CompileError::Resources(_))));
    }

    #[test]
    fn temporal_capacity_enforced() {
        // 70-inst non-critical dfg > 2*32 capacity.
        let mut b = DfgBuilder::new("big", Criticality::NonCritical);
        let x = b.in_port(0, 1);
        let mut cur = b.node(Op::Add, &[x, crate::dataflow::Operand::Const(1.0)]);
        for _ in 0..69 {
            cur = b.node(Op::Add, &[cur, crate::dataflow::Operand::Const(1.0)]);
        }
        b.out(0, cur, 1);
        let cfg = LaneConfig { name: "b".into(), dfgs: vec![b.build()] };
        let err = compile(&cfg, &FabricSpec::default_revel(), &CompileOptions::default());
        assert!(matches!(err, Err(CompileError::Resources(_))));
    }

    #[test]
    fn bigger_temporal_region_lowers_noncritical_ii() {
        let cfg = cholesky_like_config();
        let small = compile(&cfg, &FabricSpec::revel(1, 1), &CompileOptions::default())
            .unwrap();
        let big = compile(&cfg, &FabricSpec::revel(4, 2), &CompileOptions::default())
            .unwrap();
        assert!(big.timing[0].ii <= small.timing[0].ii);
    }

    #[test]
    fn routing_is_deterministic_for_fixed_seed() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let a = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        let b = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        assert_eq!(a.wirelength, b.wirelength);
        assert_eq!(a.tile_of, b.tile_of);
    }
}
