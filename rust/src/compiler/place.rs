//! Spatial compiler (paper §8): map every dataflow's nodes onto fabric
//! tiles and route their edges on the circuit-switched mesh.
//!
//! Two placement engines live here, selected by
//! [`CompileOptions::strategy`]:
//!
//! * [`PlaceStrategy::Greedy`] — the original one-shot pipeline:
//!   first-fit greedy placement followed by simulated annealing over
//!   swap moves, scored by a Pathfinder-lite router. This path is kept
//!   **frozen** (same `Rng` stream, same duplicate-weighted routing
//!   metric) because every archived simulated-cycle baseline was
//!   produced by it.
//! * [`PlaceStrategy::Negotiated`] (default) — an iterative
//!   congestion-negotiated search in the PathFinder idiom: per-tile
//!   *present* costs (how contested a tile is right now) plus *historic*
//!   costs (how often it has been contested across rounds), rip-up and
//!   re-place every node each round until tile overuse hits zero or the
//!   [`CompileOptions::place_rounds`] budget expires. The final
//!   placement is the better of {negotiated, frozen greedy+anneal}
//!   under the frozen routing metric, so simulated cycles can only
//!   improve relative to the archived baselines. Fully deterministic:
//!   the only seed use is the initial round-robin offset, neighbor
//!   expansion is pinned to ascending tile index, and every cost tie
//!   breaks toward the lower tile index.
//!
//! Dedicated nodes claim a FU-class-compatible tile each (vector nodes
//! claim ceil(w/2) subword tiles — modeled as one *placement* tile plus
//! a width cost); temporal nodes pack into temporal tiles up to the
//! 32-inst capacity. Critical (pipelined) dataflows always own their
//! tiles exclusively; only non-critical nodes may time-multiplex, and
//! only onto tiles that hold no critical node.

use std::collections::{HashMap, HashSet};

use super::fabric::{FabricSpec, TileKind};
use crate::dataflow::{Criticality, Dfg, FuClass, LaneConfig, Operand};
use crate::util::Rng;

/// Per-dataflow timing summary the simulator consumes.
#[derive(Clone, Debug)]
pub struct DfgTiming {
    /// Firing initiation interval (cycles between successive firings).
    pub ii: u64,
    /// Port-to-port pipeline depth (op latencies + routed hops).
    pub depth: u64,
    /// True if mapped onto the temporal region.
    pub temporal: bool,
    /// Static instruction count (temporal occupancy).
    pub insts: usize,
}

/// Result of compiling a LaneConfig onto a fabric.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Per-dataflow timing summaries, indexed like `LaneConfig::dfgs`.
    pub timing: Vec<DfgTiming>,
    /// node (dfg_idx, node_idx) -> tile index (dedicated-mapped nodes).
    pub tile_of: HashMap<(usize, usize), usize>,
    /// Total routed wirelength (hops) over the *deduplicated* net list —
    /// the physical wiring metric reported in sweep artifacts.
    pub wirelength: usize,
    /// Residual link overuse after negotiation (0 = legal routing),
    /// over the deduplicated net list.
    pub overuse: usize,
    /// Dedicated tiles consumed (for area/utilization reporting).
    pub tiles_used: usize,
    /// Temporal instructions placed.
    pub temporal_insts: usize,
    /// True when the negotiated-congestion search won the portfolio
    /// selection (false = the frozen greedy+anneal candidate won, or
    /// [`PlaceStrategy::Greedy`] was requested).
    pub negotiated: bool,
    /// Rip-up-and-reroute rounds the negotiated search consumed
    /// (0 under [`PlaceStrategy::Greedy`]).
    pub rounds: usize,
    /// Final routed tile path per deduplicated net, aligned with the
    /// net-list order ([`Placement::nets`] entries).
    pub routes: Vec<Vec<usize>>,
    /// Deduplicated net count (distinct physical wires).
    pub nets: usize,
}

/// Placement engine selection (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaceStrategy {
    /// Frozen greedy + simulated-annealing pipeline — the pre-negotiation
    /// baseline the archived cycle artifacts were produced with.
    Greedy,
    /// Portfolio: iterative congestion-negotiated search, selected over
    /// the frozen candidate only when it is no worse under the frozen
    /// (overuse, wirelength) metric.
    Negotiated,
}

/// Spatial-compiler options.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    /// Heterogeneous fabric enabled (paper Feature 5). When false,
    /// non-critical dataflows have no temporal region to live in and are
    /// serialized through shared dedicated resources (Fig 19's pre-het
    /// configurations; Q9's all-dedicated alternative costs 2.75x area).
    pub heterogeneous: bool,
    /// Simulated-annealing iterations of the frozen greedy candidate.
    pub anneal_iters: usize,
    /// Deterministic seed: drives the annealer's `Rng` stream and the
    /// negotiated search's initial round-robin offset. Placements are
    /// bit-reproducible for a fixed (config, fabric, options) triple.
    pub seed: u64,
    /// Which placement engine produces the final mapping.
    pub strategy: PlaceStrategy,
    /// Round budget of the negotiated rip-up-and-re-place loop.
    pub place_rounds: usize,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self {
            heterogeneous: true,
            anneal_iters: 300,
            seed: 1,
            strategy: PlaceStrategy::Negotiated,
            place_rounds: 16,
        }
    }
}

/// Compile-time failure classes.
#[derive(Debug)]
pub enum CompileError {
    /// The fabric lacks tiles/capacity for the requested mapping.
    Resources(String),
    /// Port validation failed.
    Ports(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Resources(s) => write!(f, "resource overflow: {s}"),
            CompileError::Ports(s) => write!(f, "port error: {s}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// Compile a lane configuration onto the fabric.
pub fn compile(
    cfg: &LaneConfig,
    fabric: &FabricSpec,
    opts: &CompileOptions,
) -> Result<Placement, CompileError> {
    cfg.validate().map_err(CompileError::Ports)?;

    // ---- Partition dataflows: dedicated vs temporal -------------------
    let mut dedicated: Vec<usize> = Vec::new();
    let mut temporal: Vec<usize> = Vec::new();
    for (i, d) in cfg.dfgs.iter().enumerate() {
        match d.criticality {
            Criticality::Critical => dedicated.push(i),
            Criticality::NonCritical => {
                if opts.heterogeneous && fabric.temporal_tiles() > 0 {
                    temporal.push(i)
                } else {
                    dedicated.push(i) // forced onto dedicated substrate
                }
            }
        }
    }

    // ---- Resource check (subword-SIMD tile demand) ---------------------
    let mut demand: HashMap<FuClass, usize> = HashMap::new();
    for &i in &dedicated {
        // Non-critical dfgs forced onto the dedicated fabric when the
        // temporal region is absent share tiles by time-multiplexing
        // (their firing is serialized; see timing below), so only
        // *critical* dfgs contribute pipelined tile demand.
        if cfg.dfgs[i].criticality == Criticality::Critical {
            for (k, v) in cfg.dfgs[i].tile_demand() {
                *demand.entry(k).or_insert(0) += v;
            }
        }
    }
    for (cls, need) in &demand {
        let have = fabric.fu_count(*cls);
        if *need > have {
            return Err(CompileError::Resources(format!(
                "{cls:?}: need {need} tiles, fabric has {have} \
                 (narrow the vector width)"
            )));
        }
    }
    let temporal_insts: usize = temporal.iter().map(|&i| cfg.dfgs[i].insts()).sum();
    let temporal_cap = fabric.temporal_tiles() * fabric.temporal_capacity;
    if temporal_insts > temporal_cap {
        return Err(CompileError::Resources(format!(
            "temporal region: {temporal_insts} insts > capacity {temporal_cap}"
        )));
    }

    // ---- Placement + routing of dedicated nodes ------------------------
    // One placement tile per node (FU-class compatible); the subword width
    // is accounted in the resource check above and in the area model.
    //
    // Node order is the legacy flat order (dfg index, then node index):
    // the greedy cursor sequence and the annealer's Rng stream must stay
    // byte-identical to the pre-negotiation compiler whenever no overflow
    // occurs — which is every real workload config — so archived
    // placements and their simulated cycles reproduce exactly. The
    // time-multiplex aliasing fix lives entirely in the overflow branch
    // below, which legacy reached with an unrecorded rng pick.
    let mut rng = Rng::new(opts.seed);
    let nodes: Vec<(usize, usize)> = dedicated
        .iter()
        .flat_map(|&di| (0..cfg.dfgs[di].nodes.len()).map(move |ni| (di, ni)))
        .collect();

    let mut free: HashMap<FuClass, Vec<usize>> = HashMap::new();
    for (t, kind) in fabric.tiles.iter().enumerate() {
        if let TileKind::Fu(c) = kind {
            free.entry(*c).or_default().push(t);
        }
    }
    // Initial greedy placement (first-fit per class, round-robin offsets).
    let mut tile_of: HashMap<(usize, usize), usize> = HashMap::new();
    let mut used: HashMap<usize, (usize, usize)> = HashMap::new();
    let mut crit_tiles: HashSet<usize> = HashSet::new();
    let mut load: HashMap<usize, usize> = HashMap::new();
    {
        let mut cursor: HashMap<FuClass, usize> = HashMap::new();
        for &(di, ni) in &nodes {
            let critical = cfg.dfgs[di].criticality == Criticality::Critical;
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let pool = free.get(&cls).cloned().unwrap_or_default();
            if pool.is_empty() {
                return Err(CompileError::Resources(format!("no {cls:?} tiles")));
            }
            let c = cursor.entry(cls).or_insert(0);
            let mut placed = false;
            for k in 0..pool.len() {
                let t = pool[(*c + k) % pool.len()];
                if !used.contains_key(&t) {
                    tile_of.insert((di, ni), t);
                    used.insert(t, (di, ni));
                    if critical {
                        crit_tiles.insert(t);
                    }
                    *load.entry(t).or_insert(0) += 1;
                    *c = (*c + k + 1) % pool.len();
                    placed = true;
                    break;
                }
            }
            if placed {
                continue;
            }
            if critical {
                // A critical node aliasing an occupied tile is a
                // silent-corruption bug (pipelined dataflows fire every
                // cycle), never a fallback. Reachable despite the demand
                // check when earlier non-critical nodes consumed the
                // class's tiles — the case the old compiler papered over
                // with an unrecorded rng-chosen share.
                return Err(CompileError::Resources(format!(
                    "{cls:?}: no free tile for critical node {:?}.{ni}; \
                     critical dataflows cannot time-multiplex",
                    cfg.dfgs[di].name
                )));
            }
            // Time-multiplex fallback for non-critical overflow:
            // deterministic least-loaded tile of the class (ties break
            // toward the lower tile index), and never a tile a critical
            // node has pinned — those are pipelined every cycle.
            let shared = pool
                .iter()
                .copied()
                .filter(|t| !crit_tiles.contains(t))
                .min_by_key(|t| (load.get(t).copied().unwrap_or(0), *t));
            match shared {
                Some(t) => {
                    tile_of.insert((di, ni), t);
                    *load.entry(t).or_insert(0) += 1;
                }
                None => {
                    return Err(CompileError::Resources(format!(
                        "{cls:?}: every tile is pinned by a critical node; \
                         non-critical overflow has nowhere to time-multiplex"
                    )));
                }
            }
        }
    }

    // Frozen greedy+anneal candidate, scored on the duplicate-weighted
    // net list exactly as the pre-negotiation compiler did.
    let (greedy_best, greedy_wl, greedy_ou) = anneal(
        cfg,
        fabric,
        &dedicated,
        &nodes,
        &free,
        &mut rng,
        opts.anneal_iters,
        tile_of,
    );

    // Portfolio selection: the negotiated search must beat (or tie) the
    // frozen candidate under the frozen metric to be adopted — so the
    // duplicate-weighted wirelength that feeds the timing model below is
    // monotonically non-increasing versus archived baselines, and
    // simulated cycles can only improve.
    let (tile_of, metric_wl, negotiated, rounds) = match opts.strategy {
        PlaceStrategy::Greedy => (greedy_best, greedy_wl, false, 0),
        PlaceStrategy::Negotiated => {
            if nodes.is_empty() {
                (greedy_best, greedy_wl, false, 0)
            } else {
                let (neg, neg_rounds) =
                    negotiate(cfg, fabric, &dedicated, &nodes, &free, opts);
                let legal = tile_violations(cfg, &neg) == 0;
                let (nwl, nou) = route_cost(
                    fabric,
                    &collect_nets(cfg, fabric, &dedicated, &neg, false),
                );
                if legal && (nou, nwl) <= (greedy_ou, greedy_wl) {
                    (neg, nwl, true, neg_rounds)
                } else {
                    (greedy_best, greedy_wl, false, neg_rounds)
                }
            }
        }
    };

    // ---- Per-dfg timing -------------------------------------------------
    // The average-hop estimate stays calibrated on the duplicate-weighted
    // net list (its length is placement-independent), keeping the timing
    // model continuous with every archived cycle baseline.
    let avg_hops = if nodes.is_empty() {
        0
    } else {
        let dup_nets = collect_nets(cfg, fabric, &dedicated, &tile_of, false);
        (metric_wl / dup_nets.len().max(1)).max(1)
    };
    let mut timing = Vec::with_capacity(cfg.dfgs.len());
    for (i, d) in cfg.dfgs.iter().enumerate() {
        let is_temporal = temporal.contains(&i);
        let insts = d.insts();
        let t = if is_temporal {
            // Triggered-instruction region: `temporal_issue` insts retire
            // per cycle across the region; a firing executes the DFG's
            // dependence chain (latency ~ chain with 1-cycle FUs + queue).
            let issue = fabric.temporal_issue.max(1);
            DfgTiming {
                ii: ((insts + issue - 1) / issue).max(1) as u64,
                depth: insts as u64 + 4,
                temporal: true,
                insts,
            }
        } else if d.criticality == Criticality::NonCritical {
            // Het disabled: serialized through shared dedicated resources
            // — one inst per cycle issue, double-pumped latency.
            DfgTiming {
                ii: insts.max(1) as u64,
                depth: 2 * insts as u64 + 4,
                temporal: false,
                insts,
            }
        } else {
            // Dedicated, fully pipelined: II limited only by unpipelined
            // FUs (div/sqrt: 5); depth = op critical path + routed hops.
            let ii = d.nodes.iter().map(|n| n.op.ii()).max().unwrap_or(1);
            DfgTiming {
                ii,
                depth: d.critical_path() + avg_hops as u64 * 2 + 2,
                temporal: false,
                insts,
            }
        };
        timing.push(t);
    }

    // Physical report: route the *deduplicated* net list (one entry per
    // distinct wire — an input feeding two operand slots of one node is
    // a single routed value) through the negotiated router.
    let phys_nets = collect_nets(cfg, fabric, &dedicated, &tile_of, true);
    let (wirelength, overuse, routes) = negotiate_routes(fabric, &phys_nets, 8);

    Ok(Placement {
        timing,
        tiles_used: tile_of.values().collect::<HashSet<_>>().len(),
        tile_of,
        wirelength,
        overuse,
        temporal_insts,
        negotiated,
        rounds,
        routes,
        nets: phys_nets.len(),
    })
}

/// Net endpoints (src tile, dst tile) of the dedicated placement.
///
/// `dedupe = false` reproduces the historical per-operand list (an input
/// feeding two operand slots of one node appears twice) — the annealing
/// metric and the timing model are calibrated on it. `dedupe = true`
/// collapses duplicate operands of one node into the single physical
/// wire they actually are, which is what the negotiated router and the
/// reported wirelength/overuse use.
fn collect_nets(
    cfg: &LaneConfig,
    fabric: &FabricSpec,
    dedicated: &[usize],
    tile_of: &HashMap<(usize, usize), usize>,
    dedupe: bool,
) -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    for &di in dedicated {
        let d: &Dfg = &cfg.dfgs[di];
        for (ni, n) in d.nodes.iter().enumerate() {
            let dst = tile_of[&(di, ni)];
            let mut seen: Vec<(bool, usize)> = Vec::new();
            for opnd in [Some(n.a), n.b, n.c].into_iter().flatten() {
                if dedupe {
                    let key = match opnd {
                        Operand::Node(j) => Some((false, j)),
                        Operand::Port(p) => Some((true, p)),
                        Operand::Const(_) => None,
                    };
                    if let Some(k) = key {
                        if seen.contains(&k) {
                            continue;
                        }
                        seen.push(k);
                    }
                }
                match opnd {
                    Operand::Node(j) => v.push((tile_of[&(di, j)], dst)),
                    Operand::Port(p) => {
                        v.push((fabric.in_port_tile(d.in_ports[p].gid), dst))
                    }
                    Operand::Const(_) => {}
                }
            }
        }
        for o in &d.outs {
            v.push((tile_of[&(di, o.node)], fabric.out_port_tile(o.gid)));
        }
    }
    v
}

/// The frozen greedy+anneal candidate: simulated annealing over swap
/// moves, objective = negotiated routing cost on the duplicate-weighted
/// net list. Byte-for-byte the pre-negotiation behavior (same `Rng`
/// stream, same `route_cost` metric) — archived simulated-cycle
/// baselines were produced by exactly this path, so it anchors the
/// portfolio selection in `compile`.
#[allow(clippy::too_many_arguments)]
fn anneal(
    cfg: &LaneConfig,
    fabric: &FabricSpec,
    dedicated: &[usize],
    nodes: &[(usize, usize)],
    free: &HashMap<FuClass, Vec<usize>>,
    rng: &mut Rng,
    iters: usize,
    tile_of: HashMap<(usize, usize), usize>,
) -> (HashMap<(usize, usize), usize>, usize, usize) {
    let nets = |t: &HashMap<(usize, usize), usize>| {
        collect_nets(cfg, fabric, dedicated, t, false)
    };
    let mut best = tile_of.clone();
    let (mut best_wl, mut best_ou) = route_cost(fabric, &nets(&tile_of));
    if !nodes.is_empty() {
        let mut cur = tile_of;
        let (mut cur_wl, mut cur_ou) = (best_wl, best_ou);
        for it in 0..iters {
            let temp = 1.0 - it as f64 / iters as f64;
            let &(di, ni) = &nodes[rng.below(nodes.len())];
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let pool = free.get(&cls).cloned().unwrap_or_default();
            if pool.len() < 2 {
                continue;
            }
            let new_tile = pool[rng.below(pool.len())];
            let old_tile = cur[&(di, ni)];
            if new_tile == old_tile {
                continue;
            }
            let mut cand = cur.clone();
            // Swap if occupied by a same-class node.
            if let Some(&other) = cand
                .iter()
                .find(|(_, &t)| t == new_tile)
                .map(|(k, _)| k)
                .as_ref()
            {
                cand.insert(*other, old_tile);
            }
            cand.insert((di, ni), new_tile);
            let (wl, ou) = route_cost(fabric, &nets(&cand));
            let cost = wl as f64 + 50.0 * ou as f64;
            let cur_cost = cur_wl as f64 + 50.0 * cur_ou as f64;
            if cost < cur_cost || rng.f64() < 0.1 * temp {
                cur = cand;
                cur_wl = wl;
                cur_ou = ou;
                let best_cost = best_wl as f64 + 50.0 * best_ou as f64;
                if (wl as f64) + 50.0 * (ou as f64) < best_cost {
                    best = cur.clone();
                    best_wl = wl;
                    best_ou = ou;
                }
            }
        }
    }
    (best, best_wl, best_ou)
}

/// A placement anchor one node's nets attach to: another dedicated node
/// (its tile moves during the search) or a fixed port tile.
#[derive(Clone, Copy)]
enum Anchor {
    Node(usize, usize),
    Fixed(usize),
}

/// Count of illegally shared tiles: any tile holding more than one node
/// where at least one occupant is critical (pipelined dataflows own
/// their tile; only non-critical nodes may serialize onto one tile).
fn tile_violations(cfg: &LaneConfig, place: &HashMap<(usize, usize), usize>) -> usize {
    let mut occ: HashMap<usize, (usize, bool)> = HashMap::new();
    for (&(di, _), &t) in place {
        let e = occ.entry(t).or_insert((0, false));
        e.0 += 1;
        e.1 |= cfg.dfgs[di].criticality == Criticality::Critical;
    }
    occ.values()
        .filter(|&&(n, any_crit)| n > 1 && any_crit)
        .map(|&(n, _)| n - 1)
        .sum()
}

/// Iterative congestion-negotiated placement (PathFinder idiom, applied
/// to tiles): every round rips up and re-places every node greedily
/// against a cost that mixes estimated wirelength (all-pairs hop
/// distances to the node's placed neighbors and fixed port anchors), a
/// *present* sharing cost, and a *historic* cost that accumulates on
/// tiles that keep being contested. Rounds run until a round is both
/// legal (no critical tile sharing) and a fixed point, or the budget
/// expires; the best (violations, estimated wirelength) round wins.
///
/// Deterministic by construction: node order is fixed (the flat
/// dfg/node order), candidate tiles are scanned in ascending index with
/// strict-improve acceptance, and the seed only offsets the initial
/// round-robin.
fn negotiate(
    cfg: &LaneConfig,
    fabric: &FabricSpec,
    dedicated: &[usize],
    nodes: &[(usize, usize)],
    free: &HashMap<FuClass, Vec<usize>>,
    opts: &CompileOptions,
) -> (HashMap<(usize, usize), usize>, usize) {
    let dist = all_pairs_hops(fabric);
    let n_tiles = fabric.num_tiles();

    // Deduplicated edge anchors per node (both directions), mirroring
    // `collect_nets(dedupe = true)`.
    let mut anchors: HashMap<(usize, usize), Vec<Anchor>> = HashMap::new();
    for &di in dedicated {
        let d = &cfg.dfgs[di];
        for (ni, n) in d.nodes.iter().enumerate() {
            let mut seen: Vec<(bool, usize)> = Vec::new();
            for opnd in [Some(n.a), n.b, n.c].into_iter().flatten() {
                let key = match opnd {
                    Operand::Node(j) => Some((false, j)),
                    Operand::Port(p) => Some((true, p)),
                    Operand::Const(_) => None,
                };
                let Some(key) = key else { continue };
                if seen.contains(&key) {
                    continue;
                }
                seen.push(key);
                match opnd {
                    Operand::Node(j) => {
                        anchors.entry((di, ni)).or_default().push(Anchor::Node(di, j));
                        anchors.entry((di, j)).or_default().push(Anchor::Node(di, ni));
                    }
                    Operand::Port(p) => {
                        anchors
                            .entry((di, ni))
                            .or_default()
                            .push(Anchor::Fixed(fabric.in_port_tile(d.in_ports[p].gid)));
                    }
                    Operand::Const(_) => {}
                }
            }
        }
        for o in &d.outs {
            anchors
                .entry((di, o.node))
                .or_default()
                .push(Anchor::Fixed(fabric.out_port_tile(o.gid)));
        }
    }

    // Seed-offset round-robin initial placement per class.
    let mut place: HashMap<(usize, usize), usize> = HashMap::new();
    let mut occ: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_tiles];
    {
        let mut offs: HashMap<FuClass, usize> = HashMap::new();
        for &(di, ni) in nodes {
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let pool = &free[&cls];
            let o = offs.entry(cls).or_insert(opts.seed as usize % pool.len());
            let t = pool[*o % pool.len()];
            *o += 1;
            place.insert((di, ni), t);
            occ[t].push((di, ni));
        }
    }

    let est_wl = |place: &HashMap<(usize, usize), usize>| -> usize {
        collect_nets(cfg, fabric, dedicated, place, true)
            .iter()
            .map(|&(s, t)| dist[s][t] as usize)
            .sum()
    };

    let mut hist = vec![0.0f64; n_tiles];
    let mut best = place.clone();
    let mut best_cost = (tile_violations(cfg, &place), est_wl(&place));
    let mut rounds_used = 0;
    for _round in 0..opts.place_rounds {
        rounds_used += 1;
        let mut changed = false;
        for &(di, ni) in nodes {
            let critical = cfg.dfgs[di].criticality == Criticality::Critical;
            let cls = cfg.dfgs[di].nodes[ni].op.fu_class();
            let old = place[&(di, ni)];
            occ[old].retain(|&x| x != (di, ni));
            let mut best_t = old;
            let mut best_c = f64::INFINITY;
            // Ascending scan + strict improvement pins ties to the
            // lowest tile index.
            for &t in &free[&cls] {
                let others = &occ[t];
                let crit_other = others
                    .iter()
                    .any(|&(dj, _)| cfg.dfgs[dj].criticality == Criticality::Critical);
                let present = if others.is_empty() {
                    0.0
                } else if critical || crit_other {
                    1e6 * others.len() as f64
                } else {
                    8.0 * others.len() as f64 * (1.0 + hist[t])
                };
                let wire: f64 = anchors
                    .get(&(di, ni))
                    .map(|a| {
                        a.iter()
                            .map(|an| {
                                let at = match *an {
                                    Anchor::Node(dj, nj) => place[&(dj, nj)],
                                    Anchor::Fixed(ft) => ft,
                                };
                                dist[t][at] as f64
                            })
                            .sum()
                    })
                    .unwrap_or(0.0);
                let cost = wire + present;
                if cost < best_c {
                    best_c = cost;
                    best_t = t;
                }
            }
            if best_t != old {
                changed = true;
            }
            place.insert((di, ni), best_t);
            occ[best_t].push((di, ni));
        }
        // Raise historic cost on contested tiles so persistent sharing
        // spreads out across rounds (the PathFinder negotiation step).
        for (t, o) in occ.iter().enumerate() {
            if o.len() > 1 {
                let any_crit = o
                    .iter()
                    .any(|&(dj, _)| cfg.dfgs[dj].criticality == Criticality::Critical);
                hist[t] += (o.len() - 1) as f64 * if any_crit { 4.0 } else { 1.0 };
            }
        }
        let cost = (tile_violations(cfg, &place), est_wl(&place));
        if cost < best_cost {
            best_cost = cost;
            best = place.clone();
        }
        if cost.0 == 0 && !changed {
            break; // legal fixed point: converged
        }
    }
    (best, rounds_used)
}

/// All-pairs hop distances over the mesh (BFS per tile; the fabric is
/// tiny, so this is cheaper than memoizing per-net Dijkstra results).
fn all_pairs_hops(fabric: &FabricSpec) -> Vec<Vec<u32>> {
    let n = fabric.num_tiles();
    let mut dist = vec![vec![u32::MAX; n]; n];
    for s in 0..n {
        let d = &mut dist[s];
        d[s] = 0;
        let mut queue = std::collections::VecDeque::from([s]);
        while let Some(u) = queue.pop_front() {
            for v in fabric.neighbors_sorted(u) {
                if d[v] == u32::MAX {
                    d[v] = d[u] + 1;
                    queue.push_back(v);
                }
            }
        }
    }
    dist
}

/// Pathfinder-lite metric router (frozen): route all nets by shortest
/// path with history costs; returns (total wirelength, residual
/// overuse). This is the scoring function of the annealed candidate and
/// of the portfolio selection — its numbers must stay bit-identical to
/// the archived baselines, so its cost model is never edited.
fn route_cost(fabric: &FabricSpec, nets: &[(usize, usize)]) -> (usize, usize) {
    let n = fabric.num_tiles();
    let mut history = vec![0.0f64; n * n];
    let mut total_wl = 0;
    let mut overuse = 0;
    for round in 0..4 {
        let mut usage: HashMap<usize, usize> = HashMap::new();
        total_wl = 0;
        for &(s, t) in nets {
            let path = bfs_route(fabric, s, t, &history, &usage);
            total_wl += path.len();
            for w in path.windows(2) {
                *usage.entry(fabric.link_id(w[0], w[1])).or_insert(0) += 1;
            }
        }
        overuse = usage.values().filter(|&&u| u > 1).map(|&u| u - 1).sum();
        if overuse == 0 {
            break;
        }
        // Raise history cost on congested links.
        for (link, &u) in &usage {
            if u > 1 {
                history[*link] += (u - 1) as f64 * (round + 1) as f64;
            }
        }
    }
    (total_wl, overuse)
}

fn bfs_route(
    fabric: &FabricSpec,
    s: usize,
    t: usize,
    history: &[f64],
    usage: &HashMap<usize, usize>,
) -> Vec<usize> {
    if s == t {
        return vec![s];
    }
    // Dijkstra over link costs 1 + history + current-usage penalty.
    // Neighbor expansion is pinned to ascending tile index
    // (`neighbors_sorted`); the heap key (cost, tile) makes pop order —
    // and therefore the whole search — independent of insertion order.
    let n = fabric.num_tiles();
    let mut dist = vec![f64::INFINITY; n];
    let mut prev = vec![usize::MAX; n];
    let mut heap = std::collections::BinaryHeap::new();
    dist[s] = 0.0;
    heap.push((std::cmp::Reverse(0u64), s));
    while let Some((std::cmp::Reverse(dq), u)) = heap.pop() {
        let du = dq as f64 / 1024.0;
        if du > dist[u] + 1e-9 {
            continue;
        }
        if u == t {
            break;
        }
        for v in fabric.neighbors_sorted(u) {
            let link = fabric.link_id(u, v);
            let cost = 1.0
                + history[link]
                + 2.0 * usage.get(&link).copied().unwrap_or(0) as f64;
            let nd = dist[u] + cost;
            if nd < dist[v] - 1e-9 {
                dist[v] = nd;
                prev[v] = u;
                heap.push((std::cmp::Reverse((nd * 1024.0) as u64), v));
            }
        }
    }
    let mut path = vec![t];
    let mut cur = t;
    while prev[cur] != usize::MAX {
        cur = prev[cur];
        path.push(cur);
    }
    path.reverse();
    path
}

/// Negotiated-congestion link router (PathFinder): *present* cost grows
/// with a link's current sharing and with the round number, *historic*
/// cost accumulates on links that stay overused, and every round rips up
/// and re-routes every net. Returns (wirelength, residual overuse, one
/// routed tile path per net) for the best round. Fixed-point integer
/// costs and a (cost, tile-index) heap key make every tie explicit:
/// equal-cost routes resolve toward lower tile indices.
fn negotiate_routes(
    fabric: &FabricSpec,
    nets: &[(usize, usize)],
    rounds: usize,
) -> (usize, usize, Vec<Vec<usize>>) {
    let n = fabric.num_tiles();
    let mut hist = vec![0u64; n * n];
    let mut best: Option<(usize, usize, Vec<Vec<usize>>)> = None;
    for round in 0..rounds {
        let mut usage: HashMap<usize, usize> = HashMap::new();
        let mut paths = Vec::with_capacity(nets.len());
        let mut wl = 0;
        // Present-cost factor sharpens each round (1x, 2x, 3x ...): early
        // rounds explore, late rounds force nets off contested links.
        let present = (round as u64 + 1) * 2 * SCALE;
        for &(s, t) in nets {
            let path = route_one(fabric, s, t, &hist, &usage, present);
            wl += path.len();
            for w in path.windows(2) {
                *usage.entry(fabric.link_id(w[0], w[1])).or_insert(0) += 1;
            }
            paths.push(path);
        }
        let overuse: usize = usage.values().filter(|&&u| u > 1).map(|&u| u - 1).sum();
        let better = match &best {
            None => true,
            Some(&(bwl, bou, _)) => (overuse, wl) < (bou, bwl),
        };
        if better {
            best = Some((wl, overuse, paths));
        }
        if overuse == 0 {
            break;
        }
        for (link, &u) in &usage {
            if u > 1 {
                hist[*link] += (u as u64 - 1) * (round as u64 + 1) * SCALE;
            }
        }
    }
    best.unwrap_or((0, 0, Vec::new()))
}

/// Fixed-point cost scale of the negotiated router (integer costs make
/// tie-breaking exact — no epsilon comparisons).
const SCALE: u64 = 1024;

/// One net of the negotiated router: Dijkstra with integer costs
/// `SCALE + hist[link] + present * usage[link]`, ascending-index
/// neighbor expansion, and a min-heap keyed (cost, tile) so every
/// equal-cost tie resolves toward the lower tile index.
fn route_one(
    fabric: &FabricSpec,
    s: usize,
    t: usize,
    hist: &[u64],
    usage: &HashMap<usize, usize>,
    present: u64,
) -> Vec<usize> {
    if s == t {
        return vec![s];
    }
    let n = fabric.num_tiles();
    let mut dist = vec![u64::MAX; n];
    let mut prev = vec![usize::MAX; n];
    let mut heap = std::collections::BinaryHeap::new();
    dist[s] = 0;
    heap.push(std::cmp::Reverse((0u64, s)));
    while let Some(std::cmp::Reverse((du, u))) = heap.pop() {
        if du > dist[u] {
            continue;
        }
        if u == t {
            break;
        }
        for v in fabric.neighbors_sorted(u) {
            let link = fabric.link_id(u, v);
            let cost = SCALE
                + hist[link]
                + present * usage.get(&link).copied().unwrap_or(0) as u64;
            let nd = du + cost;
            if nd < dist[v] {
                dist[v] = nd;
                prev[v] = u;
                heap.push(std::cmp::Reverse((nd, v)));
            }
        }
    }
    let mut path = vec![t];
    let mut cur = t;
    while prev[cur] != usize::MAX {
        cur = prev[cur];
        path.push(cur);
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Criticality, DfgBuilder, Op};

    fn cholesky_like_config() -> LaneConfig {
        // point (non-critical): sqrt + div
        let mut p = DfgBuilder::new("point", Criticality::NonCritical);
        let akk = p.in_port(0, 1);
        let d = p.node(Op::Sqrt, &[akk]);
        let inva = p.node(Op::Div, &[crate::dataflow::Operand::Const(1.0), d]);
        p.out(0, d, 1);
        p.out(1, inva, 1);
        // vector (critical): col * inva
        let mut v = DfgBuilder::new("vector", Criticality::Critical);
        let col = v.in_port(1, 4);
        let s = v.in_port(2, 1);
        let sc = v.node(Op::Mul, &[col, s]);
        v.out(2, sc, 4);
        // matrix (critical): a - ci*cj
        let mut m = DfgBuilder::new("matrix", Criticality::Critical);
        let a = m.in_port(3, 4);
        let ci = m.in_port(4, 1);
        let cj = m.in_port(5, 4);
        let prod = m.node(Op::Mul, &[ci, cj]);
        let upd = m.node(Op::Sub, &[a, prod]);
        m.out(3, upd, 4);
        LaneConfig {
            name: "cholesky".into(),
            dfgs: vec![p.build(), v.build(), m.build()],
        }
    }

    #[test]
    fn compiles_cholesky_config_heterogeneous() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let p = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        assert_eq!(p.timing.len(), 3);
        assert!(p.timing[0].temporal, "point region on temporal fabric");
        assert!(!p.timing[1].temporal && !p.timing[2].temporal);
        assert_eq!(p.timing[2].ii, 1, "critical matrix region fully pipelined");
        assert!(p.timing[2].depth >= cfg.dfgs[2].critical_path());
        assert_eq!(p.overuse, 0, "router must legalize");
        assert_eq!(p.temporal_insts, 2);
        assert_eq!(p.routes.len(), p.nets, "one route per physical net");
    }

    #[test]
    fn het_disabled_serializes_noncritical() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let opts = CompileOptions { heterogeneous: false, ..Default::default() };
        let p = compile(&cfg, &fabric, &opts).unwrap();
        assert!(!p.timing[0].temporal);
        assert!(p.timing[0].ii >= 2, "serialized point region");
        // Critical dataflow unaffected.
        assert_eq!(p.timing[2].ii, 1);
    }

    #[test]
    fn resource_overflow_is_reported() {
        // Width-32 multiply chain: 16 mul tiles needed > 9 available.
        let mut b = DfgBuilder::new("wide", Criticality::Critical);
        let x = b.in_port(0, 32);
        let y = b.in_port(1, 32);
        let m = b.node(Op::Mul, &[x, y]);
        b.out(0, m, 32);
        let cfg = LaneConfig { name: "w".into(), dfgs: vec![b.build()] };
        let err = compile(&cfg, &FabricSpec::default_revel(), &CompileOptions::default());
        assert!(matches!(err, Err(CompileError::Resources(_))));
    }

    #[test]
    fn temporal_capacity_enforced() {
        // 70-inst non-critical dfg > 2*32 capacity.
        let mut b = DfgBuilder::new("big", Criticality::NonCritical);
        let x = b.in_port(0, 1);
        let mut cur = b.node(Op::Add, &[x, crate::dataflow::Operand::Const(1.0)]);
        for _ in 0..69 {
            cur = b.node(Op::Add, &[cur, crate::dataflow::Operand::Const(1.0)]);
        }
        b.out(0, cur, 1);
        let cfg = LaneConfig { name: "b".into(), dfgs: vec![b.build()] };
        let err = compile(&cfg, &FabricSpec::default_revel(), &CompileOptions::default());
        assert!(matches!(err, Err(CompileError::Resources(_))));
    }

    #[test]
    fn bigger_temporal_region_lowers_noncritical_ii() {
        let cfg = cholesky_like_config();
        let small = compile(&cfg, &FabricSpec::revel(1, 1), &CompileOptions::default())
            .unwrap();
        let big = compile(&cfg, &FabricSpec::revel(4, 2), &CompileOptions::default())
            .unwrap();
        assert!(big.timing[0].ii <= small.timing[0].ii);
    }

    #[test]
    fn routing_is_deterministic_for_fixed_seed() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let a = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        let b = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        assert_eq!(a.wirelength, b.wirelength);
        assert_eq!(a.tile_of, b.tile_of);
        // Full routes, not just totals: the router's tie-breaking is
        // pinned (ascending neighbor order, lowest-tile-index ties), so
        // every path must reproduce hop for hop.
        assert_eq!(a.routes, b.routes);
        assert_eq!(a.overuse, b.overuse);
        assert_eq!((a.negotiated, a.rounds), (b.negotiated, b.rounds));
    }

    /// A config with more sqrt/div work than the 3 SqrtDiv tiles: a
    /// critical dfg pinning all three plus a non-critical div forced
    /// onto the dedicated fabric (het off).
    fn sqrtdiv_oversubscribed_config() -> LaneConfig {
        // Non-critical first: pre-fix, its div grabbed a tile ahead of
        // the critical dfg, and a critical node then fell into the
        // rng-chosen time-multiplex fallback and aliased a pipelined
        // tile without ever being recorded as sharing it.
        let mut nc = DfgBuilder::new("scalar", Criticality::NonCritical);
        let a = nc.in_port(0, 1);
        let b = nc.in_port(1, 1);
        let q = nc.node(Op::Div, &[a, b]);
        nc.out(0, q, 1);
        let mut cr = DfgBuilder::new("pipes", Criticality::Critical);
        let x = cr.in_port(2, 1);
        let y = cr.in_port(3, 1);
        let d1 = cr.node(Op::Div, &[x, y]);
        let d2 = cr.node(Op::Sqrt, &[d1]);
        let d3 = cr.node(Op::Div, &[d2, y]);
        cr.out(1, d3, 1);
        LaneConfig { name: "oversub".into(), dfgs: vec![nc.build(), cr.build()] }
    }

    #[test]
    fn critical_nodes_never_share_and_overflow_is_hard_error() {
        // Regression for the time-multiplex aliasing bug: with every
        // SqrtDiv tile pinned by the critical dfg, the non-critical
        // overflow has nowhere legal to time-multiplex. The pre-fix
        // compiler silently placed a *critical* node onto an occupied
        // rng-chosen tile; now this is a hard resource error.
        let cfg = sqrtdiv_oversubscribed_config();
        let opts = CompileOptions { heterogeneous: false, ..Default::default() };
        let err = compile(&cfg, &FabricSpec::default_revel(), &opts);
        assert!(
            matches!(err, Err(CompileError::Resources(_))),
            "critical overflow must be a hard error, got {err:?}"
        );
    }

    #[test]
    fn noncritical_overflow_time_multiplexes_least_loaded() {
        // Two critical divs pin two SqrtDiv tiles; two non-critical divs
        // need the third plus one shared slot. The fallback must pick
        // deterministically (least-loaded, lowest index), must record
        // the sharing, and must never touch a critical tile.
        let mut cr = DfgBuilder::new("pipes", Criticality::Critical);
        let x = cr.in_port(0, 1);
        let y = cr.in_port(1, 1);
        let d1 = cr.node(Op::Div, &[x, y]);
        let d2 = cr.node(Op::Div, &[d1, y]);
        cr.out(0, d2, 1);
        let mut nc = DfgBuilder::new("scalar", Criticality::NonCritical);
        let a = nc.in_port(2, 1);
        let b = nc.in_port(3, 1);
        let q1 = nc.node(Op::Div, &[a, b]);
        let q2 = nc.node(Op::Div, &[q1, b]);
        nc.out(1, q2, 1);
        let cfg = LaneConfig { name: "share".into(), dfgs: vec![cr.build(), nc.build()] };
        // anneal_iters: 0 — the frozen annealer's swap moves predate
        // tile sharing and are not sharing-aware; with a shared tile in
        // play its HashMap-backed occupant lookup is the one legacy
        // code path that is not order-stable. The fallback itself (the
        // code under test) and the negotiated engine are deterministic.
        let opts = CompileOptions {
            heterogeneous: false,
            anneal_iters: 0,
            ..Default::default()
        };
        let p = compile(&cfg, &FabricSpec::default_revel(), &opts).unwrap();
        // Critical nodes (dfg 0) own their tiles exclusively.
        let crit_tiles: Vec<usize> =
            (0..2).map(|ni| p.tile_of[&(0, ni)]).collect();
        for (&(di, _), &t) in &p.tile_of {
            if di != 0 {
                assert!(
                    !crit_tiles.contains(&t),
                    "non-critical node aliases a pipelined tile {t}"
                );
            }
        }
        // And repeated compiles agree exactly (no rng in the fallback).
        let q = compile(&cfg, &FabricSpec::default_revel(), &opts).unwrap();
        assert_eq!(p.tile_of, q.tile_of);
    }

    #[test]
    fn duplicate_operand_nets_are_deduped() {
        // x*x: one input feeding both operand slots of one node is a
        // single physical wire. Pre-fix, the net list counted it twice,
        // inflating wirelength/overuse before they fed the router.
        let mut b = DfgBuilder::new("sq", Criticality::Critical);
        let x = b.in_port(0, 1);
        let m = b.node(Op::Mul, &[x, x]);
        b.out(0, m, 1);
        let cfg = LaneConfig { name: "sq".into(), dfgs: vec![b.build()] };
        let fabric = FabricSpec::default_revel();
        let p = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        // Physical nets: port->mul (once, deduped) + mul->out.
        assert_eq!(p.nets, 2, "duplicate operand must collapse to one wire");
        let dup = collect_nets(&cfg, &fabric, &[0], &p.tile_of, false);
        let phys = collect_nets(&cfg, &fabric, &[0], &p.tile_of, true);
        assert_eq!(dup.len(), 3, "historical metric list keeps the duplicate");
        assert_eq!(phys.len(), 2);
    }

    #[test]
    fn negotiated_router_prefers_low_index_paths() {
        // Two equal-cost L-shaped routes exist from (0,0) to (1,1); the
        // pinned tie-break (min-heap keyed (cost, tile), ascending
        // neighbor order) must pick the one through the lower tile index.
        let fabric = FabricSpec::default_revel();
        let s = fabric.idx(0, 0);
        let t = fabric.idx(1, 1);
        let (wl, ou, routes) = negotiate_routes(&fabric, &[(s, t)], 8);
        assert_eq!(ou, 0);
        assert_eq!(wl, 3);
        assert_eq!(routes, vec![vec![s, fabric.idx(1, 0), t]]);
    }

    #[test]
    fn negotiated_never_loses_to_greedy_on_frozen_metric() {
        let cfg = cholesky_like_config();
        let fabric = FabricSpec::default_revel();
        let greedy = compile(
            &cfg,
            &fabric,
            &CompileOptions { strategy: PlaceStrategy::Greedy, ..Default::default() },
        )
        .unwrap();
        let neg = compile(&cfg, &fabric, &CompileOptions::default()).unwrap();
        // The portfolio selection keys timing off the frozen metric, so
        // the pipeline depth the simulator sees can only shrink.
        for (a, b) in neg.timing.iter().zip(&greedy.timing) {
            assert!(a.depth <= b.depth, "negotiated depth regressed");
            assert_eq!(a.ii, b.ii);
        }
    }
}
