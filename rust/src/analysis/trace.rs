//! Shadow-memory dynamic dependence tracer (paper Fig 7 methodology:
//! "We use LLVM to instrument programs to track dynamic memory
//! dependences"). Instrumented kernels call `load`/`store`/`arith`/
//! `site`/`region`; the tracer derives:
//!
//! * **granularity** — arithmetic-instruction distance of each
//!   inter-region RAW dependence (Fig 7a);
//! * **orderedness** — fraction of dependences whose consumption order
//!   matches production order per (producer site, consumer site) pair
//!   (Fig 7b);
//! * **inductive access fraction** — fraction of dynamic accesses made
//!   by sites whose address stream is affine with a linearly varying
//!   inner trip count (Fig 7c);
//! * **region imbalance** — max/min arithmetic work across regions
//!   (Fig 7d).

use std::collections::HashMap;

/// A static instruction site (kernel-assigned id).
pub type Site = u32;

#[derive(Clone, Debug, Default)]
struct SiteTrace {
    /// (outer iteration, inner index, address) samples.
    rows: Vec<(i64, i64, i64)>,
    accesses: u64,
}

pub struct Tracer {
    /// addr -> (producing site, production seq, region, arith clock).
    last_write: HashMap<i64, (Site, u64, u32, u64)>,
    arith_clock: u64,
    produce_seq: u64,
    region: u32,
    /// Per (src site, dst site): consumer outer iteration + last
    /// consumed production seq. Rescans (consumer outer loop advances
    /// and re-reads from the start — the stream-reuse pattern) count as
    /// ordered; backwards consumption within one scan does not.
    pair_last: HashMap<(Site, Site), (i64, u64)>,
    dep_total: u64,
    dep_ordered: u64,
    /// Inter-region dependence distances (arith insts).
    distances: Vec<u64>,
    /// Per-region arithmetic counts.
    region_arith: HashMap<u32, u64>,
    sites: HashMap<Site, SiteTrace>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self {
            last_write: HashMap::new(),
            arith_clock: 0,
            produce_seq: 0,
            region: 0,
            pair_last: HashMap::new(),
            dep_total: 0,
            dep_ordered: 0,
            distances: Vec::new(),
            region_arith: HashMap::new(),
            sites: HashMap::new(),
        }
    }

    /// Enter computation region `r` (paper: point/vector/matrix etc.).
    pub fn region(&mut self, r: u32) {
        self.region = r;
    }

    /// Count `k` arithmetic instructions.
    pub fn arith(&mut self, k: u64) {
        self.arith_clock += k;
        *self.region_arith.entry(self.region).or_insert(0) += k;
    }

    /// A load by static site `s` at (outer, inner) loop coordinates.
    pub fn load(&mut self, s: Site, j: i64, i: i64, addr: i64) {
        let st = self.sites.entry(s).or_default();
        st.rows.push((j, i, addr));
        st.accesses += 1;
        if let Some(&(src, seq, reg, clk)) = self.last_write.get(&addr) {
            self.dep_total += 1;
            let key = (src, s);
            let last = self.pair_last.entry(key).or_insert((j, 0));
            if seq >= last.1 || last.0 != j {
                self.dep_ordered += 1;
            }
            *last = (j, seq);
            if reg != self.region {
                self.distances.push(self.arith_clock - clk);
            }
        }
    }

    /// A store by static site `s`.
    pub fn store(&mut self, s: Site, j: i64, i: i64, addr: i64) {
        let st = self.sites.entry(s).or_default();
        st.rows.push((j, i, addr));
        st.accesses += 1;
        self.produce_seq += 1;
        self.last_write
            .insert(addr, (s, self.produce_seq, self.region, self.arith_clock));
    }

    /// Classify a site as inductive: the address is affine in (j, i)
    /// and the per-j inner trip count varies linearly with j (a
    /// non-zero stretch). Rectangular affine sites are not inductive.
    fn site_inductive(tr: &SiteTrace) -> bool {
        // Group by outer j; collect trip counts and per-row starts.
        let mut rows: HashMap<i64, Vec<(i64, i64)>> = HashMap::new();
        for &(j, i, a) in &tr.rows {
            rows.entry(j).or_default().push((i, a));
        }
        if rows.len() < 3 {
            return false;
        }
        let mut keys: Vec<i64> = rows.keys().copied().collect();
        keys.sort_unstable();
        // Affinity: within each row, address must be affine in i.
        let mut trips = Vec::new();
        for &j in &keys {
            let r = &rows[&j];
            if r.len() >= 2 {
                let stride = r[1].1 - r[0].1;
                for w in r.windows(2) {
                    if w[1].1 - w[0].1 != stride {
                        return false;
                    }
                }
            }
            trips.push(r.len() as i64);
        }
        // Trip counts: induction-variable dependent. Outer loops may
        // restart the sequence (e.g. the k loop around a triangular j/i
        // nest), so require the *dominant* trip-count delta to be a
        // common non-zero value rather than global linearity.
        let deltas: Vec<i64> = trips.windows(2).map(|w| w[1] - w[0]).collect();
        if deltas.is_empty() {
            return false;
        }
        let mut freq: HashMap<i64, usize> = HashMap::new();
        for &d in &deltas {
            *freq.entry(d).or_insert(0) += 1;
        }
        let (&best, &cnt) = freq.iter().max_by_key(|(_, &c)| c).unwrap();
        best != 0 && cnt * 2 >= deltas.len()
    }

    pub fn finish(self) -> FgopStats {
        let total_access: u64 = self.sites.values().map(|s| s.accesses).sum();
        let inductive_access: u64 = self
            .sites
            .values()
            .filter(|s| Self::site_inductive(s))
            .map(|s| s.accesses)
            .sum();
        let mut arith: Vec<u64> = self.region_arith.values().copied().collect();
        arith.sort_unstable();
        let imbalance = if arith.len() >= 2 && arith[0] > 0 {
            *arith.last().unwrap() as f64 / arith[0] as f64
        } else {
            1.0
        };
        FgopStats {
            dep_distances: self.distances,
            ordered_fraction: if self.dep_total == 0 {
                1.0
            } else {
                self.dep_ordered as f64 / self.dep_total as f64
            },
            inductive_fraction: if total_access == 0 {
                0.0
            } else {
                inductive_access as f64 / total_access as f64
            },
            region_imbalance: imbalance,
            regions: self.region_arith.len(),
        }
    }
}

/// The four FGOP properties of one traced kernel run (paper Fig 7).
#[derive(Clone, Debug)]
pub struct FgopStats {
    /// Inter-region RAW dependence distances in arithmetic insts.
    pub dep_distances: Vec<u64>,
    /// Fraction of ordered dependences.
    pub ordered_fraction: f64,
    /// Fraction of dynamic accesses from inductive sites.
    pub inductive_fraction: f64,
    /// max/min arithmetic work across regions.
    pub region_imbalance: f64,
    pub regions: usize,
}

impl FgopStats {
    /// Paper threshold: a workload "has imbalanced regions".
    pub fn imbalanced(&self) -> bool {
        self.regions >= 2 && self.region_imbalance >= 4.0
    }

    pub fn median_distance(&self) -> u64 {
        if self.dep_distances.is_empty() {
            return 0;
        }
        let mut d = self.dep_distances.clone();
        d.sort_unstable();
        d[d.len() / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_dependence_distance_and_order() {
        let mut t = Tracer::new();
        t.region(0);
        t.store(0, 0, 0, 100);
        t.arith(50);
        t.region(1);
        t.load(1, 0, 0, 100); // inter-region RAW at distance 50
        let s = t.finish();
        assert_eq!(s.dep_distances, vec![50]);
        assert!((s.ordered_fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unordered_consumption_detected() {
        let mut t = Tracer::new();
        t.store(0, 0, 0, 1); // seq 1
        t.store(0, 0, 1, 2); // seq 2
        t.load(1, 0, 0, 2); // consumes seq 2
        t.load(1, 0, 1, 1); // then seq 1: backwards
        let s = t.finish();
        assert!(s.ordered_fraction < 1.0);
    }

    #[test]
    fn inductive_site_classified() {
        let mut t = Tracer::new();
        // Triangular: row j has 8-j elements (stretch -1).
        for j in 0..8i64 {
            for i in 0..(8 - j) {
                t.load(7, j, i, 100 + j * 9 + i);
            }
        }
        // Rectangular site.
        for j in 0..8i64 {
            for i in 0..4 {
                t.load(8, j, i, 500 + j * 4 + i);
            }
        }
        let s = t.finish();
        // 36 of 68 accesses inductive.
        assert!((s.inductive_fraction - 36.0 / 68.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_across_regions() {
        let mut t = Tracer::new();
        t.region(0);
        t.arith(10);
        t.region(1);
        t.arith(100);
        let s = t.finish();
        assert!(s.imbalanced());
        assert!((s.region_imbalance - 10.0).abs() < 1e-12);
    }
}
