//! FGOP characterization (paper §3, Fig 7) and stream-capability
//! analysis (paper Q10, Figs 21/22).
//!
//! `trace` is a shadow-memory dynamic dependence tracer: instrumented
//! kernels report loads/stores/arithmetic/region transitions and the
//! tracer measures the four FGOP properties exactly as the paper's
//! LLVM instrumentation does. `kernels` holds instrumented versions of
//! the 7 DSP kernels plus a PolyBench subset. `streams` runs the
//! closed-form (scalar-evolution-style) stream-length analysis over a
//! declarative loop-nest IR of each kernel's memory accesses.

pub mod kernels;
pub mod streams;
pub mod trace;

pub use trace::{FgopStats, Tracer};
