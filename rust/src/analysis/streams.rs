//! Stream-capability analysis (paper Q10, Figs 21/22): closed-form
//! stream lengths and control overhead for address-generation
//! capabilities V / R / RR / RI / RRR / RII, over a declarative
//! loop-nest IR of each kernel's memory access sites (the stand-in for
//! the paper's LLVM scalar-evolution analysis — affine SCEVs are
//! exactly what this IR encodes).

use crate::isa::Capability;

/// A (up to) 3-deep affine loop nest for one access site, outer to
/// inner: trips t0; t1 = b1 + s10*j0; t2 = b2 + s20*j0 + s21*j1.
#[derive(Clone, Copy, Debug)]
pub struct Nest {
    pub t0: i64,
    pub b1: i64,
    pub s10: i64,
    pub b2: i64,
    pub s20: i64,
    pub s21: i64,
    /// Elements each inner iteration touches via port-level reuse
    /// (broadcast scalars): with stream-reuse disabled the site needs
    /// one extra command per reuse run (Fig 22's stacked bars).
    pub reuse_runs: i64,
}

impl Nest {
    pub fn rect3(t0: i64, t1: i64, t2: i64) -> Self {
        Self { t0, b1: t1, s10: 0, b2: t2, s20: 0, s21: 0, reuse_runs: 0 }
    }

    pub fn tri2(t0: i64, b2: i64, s20: i64) -> Self {
        // 2D site hoisted under a trivial outer dim: trips (t0, 1, ...).
        Self { t0, b1: 1, s10: 0, b2, s20, s21: 0, reuse_runs: 0 }
    }

    fn t1(&self, j0: i64) -> i64 {
        (self.b1 + self.s10 * j0).max(0)
    }

    fn t2(&self, j0: i64, j1: i64) -> i64 {
        (self.b2 + self.s20 * j0 + self.s21 * j1).max(0)
    }

    /// Total elements.
    pub fn elems(&self) -> i64 {
        let mut e = 0;
        for j0 in 0..self.t0 {
            for j1 in 0..self.t1(j0) {
                e += self.t2(j0, j1);
            }
        }
        e
    }

    /// Innermost rows.
    fn rows(&self) -> i64 {
        (0..self.t0).map(|j0| self.t1(j0)).sum()
    }

    /// Commands needed under a capability.
    pub fn commands(&self, cap: Capability) -> i64 {
        match cap {
            Capability::V(w) => {
                let mut c = 0;
                for j0 in 0..self.t0 {
                    for j1 in 0..self.t1(j0) {
                        c += (self.t2(j0, j1) + w as i64 - 1) / w as i64;
                    }
                }
                c.max(1)
            }
            Capability::R => self.rows().max(1),
            Capability::RR => {
                // Covers (j1, j2) when the inner trip is rectangular in
                // j1; otherwise decompose to rows.
                if self.s21 == 0 {
                    self.t0.max(1)
                } else {
                    self.rows().max(1)
                }
            }
            Capability::RI => self.t0.max(1),
            Capability::RRR => {
                if self.s10 == 0 && self.s20 == 0 && self.s21 == 0 {
                    1
                } else if self.s21 == 0 {
                    self.t0.max(1)
                } else {
                    self.rows().max(1)
                }
            }
            Capability::RII => 1,
        }
    }

    /// Extra commands when port-level stream reuse is unavailable.
    pub fn reuse_penalty(&self) -> i64 {
        self.reuse_runs
    }
}

/// One kernel's access-site inventory + inner-iteration count.
pub struct KernelStreams {
    pub name: &'static str,
    pub sites: Vec<Nest>,
    pub inner_iters: i64,
}

/// Build the stream inventory for a kernel at size n (the dominant
/// access sites of the inner loops).
pub fn kernel_streams(name: &str, n: usize) -> KernelStreams {
    let n_i = n as i64;
    let sites = match name {
        // Trailing update: a, ci (reused scalar), cj — triangular in
        // both outer dims.
        "cholesky" => vec![
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i - 1, s20: -1, s21: -1, reuse_runs: n_i * (n_i - 1) / 2 },
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i - 1, s20: -1, s21: -1, reuse_runs: 0 },
        ],
        // Square trailing block shrinking across k; the L column is
        // re-read (rewound) per trailing column.
        "lu" => vec![
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i - 1, s20: -1, s21: 0, reuse_runs: n_i * (n_i - 1) / 2 },
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i - 1, s20: -1, s21: 0, reuse_runs: 0 },
        ],
        // Per-k rectangular trailing block, shrinking across k.
        "qr" => vec![
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i, s20: -1, s21: 0, reuse_runs: n_i },
            Nest { t0: n_i, b1: n_i - 1, s10: -1, b2: n_i, s20: -1, s21: 0, reuse_runs: 0 },
        ],
        // Column pairs, fixed-length columns.
        "svd" => vec![
            Nest::rect3(n_i * (n_i - 1) / 2, 2, n_i),
            Nest::rect3(n_i * (n_i - 1) / 2, 2, n_i),
        ],
        // The triangular b/a streams (Fig 11's example).
        "solver" => vec![
            Nest { t0: 1, b1: n_i - 1, s10: 0, b2: n_i - 1, s20: 0, s21: -1, reuse_runs: n_i },
            Nest { t0: 1, b1: n_i - 1, s10: 0, b2: n_i - 1, s20: 0, s21: -1, reuse_runs: 0 },
        ],
        // Stages x groups x butterflies (rectangular; twiddles reused).
        "fft" => {
            let stages = (n_i as f64).log2() as i64;
            vec![
                Nest::rect3(stages, 2, n_i / 2),
                Nest {
                    t0: stages,
                    b1: 2,
                    s10: 0,
                    b2: n_i / 2,
                    s20: 0,
                    s21: 0,
                    reuse_runs: stages,
                },
            ]
        }
        // (i, k) x 64-wide rows: pure rectangular.
        "gemm" => vec![
            Nest::rect3(n_i, 16, 64),
            Nest { t0: n_i, b1: 16, s10: 0, b2: 64, s20: 0, s21: 0, reuse_runs: n_i * 16 },
        ],
        // Output windows x taps.
        "fir" => vec![
            Nest::rect3(1, 64, n_i / 2),
            Nest { t0: 1, b1: 64, s10: 0, b2: n_i / 2, s20: 0, s21: 0, reuse_runs: 64 },
        ],
        _ => panic!("unknown kernel {name}"),
    };
    let inner_iters = sites.iter().map(|s| s.elems()).max().unwrap();
    KernelStreams { name: Box::leak(name.to_string().into_boxed_str()), sites, inner_iters }
}

/// Fig 21: average stream length (elements per command) under a
/// capability, aggregated over the kernel's sites.
pub fn avg_stream_length(ks: &KernelStreams, cap: Capability) -> f64 {
    let elems: i64 = ks.sites.iter().map(|s| s.elems()).sum();
    let cmds: i64 = ks.sites.iter().map(|s| s.commands(cap)).sum();
    elems as f64 / cmds.max(1) as f64
}

/// Fig 22: control (memory) instructions per inner-loop iteration;
/// `with_reuse=false` adds the stacked reuse-disabled overhead.
pub fn insts_per_iter(ks: &KernelStreams, cap: Capability, with_reuse: bool) -> f64 {
    let mut cmds: i64 = ks.sites.iter().map(|s| s.commands(cap)).sum();
    if !with_reuse {
        cmds += ks.sites.iter().map(|s| s.reuse_penalty()).sum::<i64>();
    }
    cmds as f64 / ks.inner_iters.max(1) as f64
}

/// The capability ladder of Figs 21/22.
pub fn capabilities() -> [Capability; 6] {
    [
        Capability::V(4),
        Capability::R,
        Capability::RR,
        Capability::RI,
        Capability::RRR,
        Capability::RII,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fgop_kernels_need_ri_for_long_streams() {
        // Paper Fig 21: FGOP workloads show much higher lengths only
        // with inductive capability.
        for k in ["cholesky", "solver"] {
            let ks = kernel_streams(k, 32);
            let rr = avg_stream_length(&ks, Capability::RR);
            let ri = avg_stream_length(&ks, Capability::RI);
            assert!(ri > 3.0 * rr, "{k}: RI {ri} vs RR {rr}");
        }
    }

    #[test]
    fn gemm_satisfied_by_rr() {
        let ks = kernel_streams("gemm", 24);
        let rr = avg_stream_length(&ks, Capability::RR);
        let ri = avg_stream_length(&ks, Capability::RI);
        assert!((rr - ri).abs() < 1e-9, "RI adds nothing for gemm");
    }

    #[test]
    fn ri_keeps_control_overhead_below_one_inst_per_iter() {
        // Paper: "the RI capability always either achieves a control
        // overhead below 1 inst/iter or matches the least overhead".
        for k in crate::workloads::NAMES {
            let ks = kernel_streams(k, 32);
            let ri = insts_per_iter(&ks, Capability::RI, true);
            let best = capabilities()
                .iter()
                .map(|&c| insts_per_iter(&ks, c, true))
                .fold(f64::INFINITY, f64::min);
            assert!(ri < 1.0 || (ri - best).abs() < 1e-9, "{k}: RI {ri} best {best}");
        }
    }

    #[test]
    fn reuse_disabled_costs_more() {
        let ks = kernel_streams("solver", 32);
        let with = insts_per_iter(&ks, Capability::RI, true);
        let without = insts_per_iter(&ks, Capability::RI, false);
        assert!(without > with);
    }

    #[test]
    fn rii_never_worse_than_ri() {
        for k in crate::workloads::NAMES {
            let ks = kernel_streams(k, 32);
            for s in &ks.sites {
                assert!(s.commands(Capability::RII) <= s.commands(Capability::RI));
            }
        }
    }
}
