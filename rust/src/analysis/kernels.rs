//! Instrumented kernels for the FGOP characterization (paper Fig 7):
//! the 7 DSP kernels plus a PolyBench subset, each reporting loads,
//! stores, arithmetic and region transitions to the tracer. Addresses
//! are logical word indices (the tracer only needs identity + order).

use super::trace::{FgopStats, Tracer};

/// DSP kernel names (paper Fig 7 left, plus Table 4's LU).
pub const DSP: [&str; 8] =
    ["cholesky", "lu", "qr", "svd", "solver", "fft", "gemm", "fir"];

/// PolyBench subset (paper Fig 7 right).
pub const POLYBENCH: [&str; 8] =
    ["2mm", "3mm", "atax", "bicg", "gesummv", "mvt", "syrk", "trisolv"];

/// Trace a kernel at size n.
pub fn trace(name: &str, n: usize) -> FgopStats {
    let mut t = Tracer::new();
    match name {
        "cholesky" => cholesky(&mut t, n),
        "lu" => lu(&mut t, n),
        "qr" => qr(&mut t, n),
        "svd" => svd(&mut t, n),
        "solver" => solver(&mut t, n),
        "fft" => fft(&mut t, n),
        "gemm" => gemm(&mut t, n),
        "fir" => fir(&mut t, n),
        "2mm" => mm2(&mut t, n),
        "3mm" => mm3(&mut t, n),
        "atax" => atax(&mut t, n),
        "bicg" => bicg(&mut t, n),
        "gesummv" => gesummv(&mut t, n),
        "mvt" => mvt(&mut t, n),
        "syrk" => syrk(&mut t, n),
        "trisolv" => trisolv(&mut t, n),
        _ => panic!("unknown kernel {name}"),
    }
    t.finish()
}

// Address-space bases keep arrays distinct.
const A: i64 = 0;
const B: i64 = 1 << 20;
const C: i64 = 2 << 20;
const D: i64 = 3 << 20;

fn idx(base: i64, n: usize, i: i64, j: i64) -> i64 {
    base + i * n as i64 + j
}

fn cholesky(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    for k in 0..n_i {
        t.region(0); // point
        t.load(0, k, 0, idx(A, n, k, k));
        t.arith(2); // sqrt + div
        t.store(1, k, 0, idx(A, n, k, k));
        t.region(1); // vector
        for i in k + 1..n_i {
            t.load(2, k, i - k - 1, idx(A, n, i, k));
            t.arith(1);
            t.store(3, k, i - k - 1, idx(A, n, i, k));
        }
        t.region(2); // matrix
        for j in k + 1..n_i {
            let row = k * (n_i + 1) + j; // globally unique row key
            for i in j..n_i {
                t.load(4, row, i - j, idx(A, n, i, k));
                t.load(5, row, i - j, idx(A, n, j, k));
                t.load(6, row, i - j, idx(A, n, i, j));
                t.arith(2);
                t.store(7, row, i - j, idx(A, n, i, j));
            }
        }
    }
}

fn lu(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    for k in 0..n_i {
        t.region(0); // point: reciprocal of the pivot
        t.load(0, k, 0, idx(A, n, k, k));
        t.arith(1);
        t.region(1); // vector: scale column k
        for i in k + 1..n_i {
            t.load(1, k, i - k - 1, idx(A, n, i, k));
            t.arith(1);
            t.store(2, k, i - k - 1, idx(A, n, i, k));
        }
        t.region(2); // matrix: square trailing update
        for j in k + 1..n_i {
            let row = k * n_i + j; // globally unique row key
            for i in k + 1..n_i {
                t.load(3, row, i - k - 1, idx(A, n, i, k));
                t.load(4, row, i - k - 1, idx(A, n, k, j));
                t.load(5, row, i - k - 1, idx(A, n, i, j));
                t.arith(2);
                t.store(6, row, i - k - 1, idx(A, n, i, j));
            }
        }
    }
}

fn qr(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    for k in 0..n_i {
        t.region(0); // norm + householder scalar chain
        for i in k..n_i {
            t.load(0, k, i - k, idx(A, n, i, k));
            t.arith(2);
        }
        t.arith(8);
        t.store(1, k, 0, idx(A, n, k, k));
        for j in k + 1..n_i {
            let row = k * (n_i + 1) + j;
            t.region(1); // w_j dot
            for i in k..n_i {
                t.load(2, row, i - k, idx(A, n, i, k));
                t.load(3, row, i - k, idx(A, n, i, j));
                t.arith(2);
            }
            t.store(4, row, 0, idx(B, n, 0, j));
            t.region(2); // update
            for i in k..n_i {
                t.load(5, row, i - k, idx(B, n, 0, j));
                t.load(6, row, i - k, idx(A, n, i, k));
                t.load(7, row, i - k, idx(A, n, i, j));
                t.arith(2);
                t.store(8, row, i - k, idx(A, n, i, j));
            }
        }
    }
}

fn svd(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    let mut pair = 0i64;
    for p in 0..n_i - 1 {
        for q in p + 1..n_i {
            // `pair` is the tracer's outer coordinate (globally unique);
            // `q` stays the real column index for addresses.
            pair += 1;
            t.region(0); // dots
            for i in 0..n_i {
                t.load(0, pair, i, idx(A, n, i, p));
                t.load(1, pair, i, idx(A, n, i, q));
                t.arith(6);
            }
            t.region(1); // rotation params
            t.arith(12);
            t.store(2, pair, 0, idx(B, n, 0, 0));
            t.region(2); // rotate
            for i in 0..n_i {
                t.load(3, pair, i, idx(B, n, 0, 0));
                t.load(4, pair, i, idx(A, n, i, p));
                t.load(5, pair, i, idx(A, n, i, q));
                t.arith(6);
                t.store(6, pair, i, idx(A, n, i, p));
                t.store(7, pair, i, idx(A, n, i, q));
            }
        }
    }
}

fn solver(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    for j in 0..n_i {
        t.region(0); // divide
        t.load(0, j, 0, B + j);
        t.load(1, j, 0, idx(A, n, j, j));
        t.arith(1);
        t.store(2, j, 0, C + j);
        t.region(1); // update
        for i in j + 1..n_i {
            t.load(3, j, i - j - 1, C + j);
            t.load(4, j, i - j - 1, idx(A, n, i, j));
            t.load(5, j, i - j - 1, B + i);
            t.arith(2);
            t.store(6, j, i - j - 1, B + i);
        }
    }
}

fn fft(t: &mut Tracer, n: usize) {
    let mut len = 2i64;
    let n_i = n as i64;
    let mut stage = 0;
    while len <= n_i {
        t.region(stage % 2); // alternating stages
        let half = len / 2;
        for s in (0..n_i).step_by(len as usize) {
            let row = stage as i64 * n_i + s / len;
            for k in 0..half {
                t.load(0, row, k, A + s + k);
                t.load(1, row, k, A + s + k + half);
                t.load(2, row, k, B + k * (n_i / len));
                t.arith(10);
                t.store(3, row, k, A + s + k);
                t.store(4, row, k, A + s + k + half);
            }
        }
        len *= 2;
        stage += 1;
    }
}

fn gemm(t: &mut Tracer, m: usize) {
    let (k_dim, p_dim) = (16i64, 64i64);
    for i in 0..m as i64 {
        for j in 0..p_dim {
            for k in 0..k_dim {
                t.load(0, i * p_dim + j, k, idx(A, 16, i, k));
                t.load(1, i * p_dim + j, k, idx(B, 64, k, j));
                t.arith(2);
            }
            t.store(2, i, j, idx(C, 64, i, j));
        }
    }
}

fn fir(t: &mut Tracer, m: usize) {
    let n_out = 64i64;
    for i in 0..n_out {
        for j in 0..(m / 2) as i64 {
            t.load(0, i, j, A + i + j);
            t.load(1, i, j, A + i + m as i64 - 1 - j);
            t.load(2, i, j, B + j);
            t.arith(3);
        }
        t.store(3, i, 0, C + i);
    }
}

// ---- PolyBench subset (rectangular, mostly non-FGOP) -----------------

fn mm_nn(t: &mut Tracer, n: usize, a: i64, b: i64, c: i64, s0: u32) {
    let n_i = n as i64;
    for i in 0..n_i {
        for j in 0..n_i {
            for k in 0..n_i {
                t.load(s0, i * n_i + j, k, idx(a, n, i, k));
                t.load(s0 + 1, i * n_i + j, k, idx(b, n, k, j));
                t.arith(2);
            }
            t.store(s0 + 2, i, j, idx(c, n, i, j));
        }
    }
}

fn mm2(t: &mut Tracer, n: usize) {
    t.region(0);
    mm_nn(t, n, A, B, C, 0);
    t.region(1);
    mm_nn(t, n, C, D, A, 10);
}

fn mm3(t: &mut Tracer, n: usize) {
    t.region(0);
    mm_nn(t, n, A, B, C, 0);
    t.region(1);
    mm_nn(t, n, B, D, A, 10);
    t.region(2);
    mm_nn(t, n, C, A, D, 20);
}

fn atax(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    t.region(0);
    for i in 0..n_i {
        for j in 0..n_i {
            t.load(0, i, j, idx(A, n, i, j));
            t.load(1, i, j, B + j);
            t.arith(2);
        }
        t.store(2, i, 0, C + i);
    }
    t.region(1);
    for i in 0..n_i {
        for j in 0..n_i {
            t.load(3, i, j, idx(A, n, j, i));
            t.load(4, i, j, C + j);
            t.arith(2);
        }
        t.store(5, i, 0, D + i);
    }
}

fn bicg(t: &mut Tracer, n: usize) {
    atax(t, n); // structurally identical two-phase mat-vec pair
}

fn gesummv(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    t.region(0);
    for i in 0..n_i {
        for j in 0..n_i {
            t.load(0, i, j, idx(A, n, i, j));
            t.load(1, i, j, idx(B, n, i, j));
            t.load(2, i, j, C + j);
            t.arith(4);
        }
        t.store(3, i, 0, D + i);
    }
}

fn mvt(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    t.region(0);
    for i in 0..n_i {
        for j in 0..n_i {
            t.load(0, i, j, idx(A, n, i, j));
            t.load(1, i, j, B + j);
            t.arith(2);
        }
        t.store(2, i, 0, C + i);
    }
    t.region(1);
    for i in 0..n_i {
        for j in 0..n_i {
            t.load(3, i, j, idx(A, n, j, i));
            t.load(4, i, j, D + j);
            t.arith(2);
        }
        t.store(5, i, 0, B + i);
    }
}

fn syrk(t: &mut Tracer, n: usize) {
    let n_i = n as i64;
    t.region(0);
    for i in 0..n_i {
        for j in 0..=i {
            for k in 0..n_i {
                t.load(0, i * n_i + j, k, idx(A, n, i, k));
                t.load(1, i * n_i + j, k, idx(A, n, j, k));
                t.arith(2);
            }
            t.store(2, i, j, idx(C, n, i, j));
        }
    }
}

fn trisolv(t: &mut Tracer, n: usize) {
    // PolyBench's triangular solve — the FGOP member of the suite.
    solver(t, n);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_kernels_show_fgop_properties() {
        // Factorizations: highly ordered, inductive, imbalanced.
        for k in ["cholesky", "solver"] {
            let s = trace(k, 16);
            assert!(s.ordered_fraction > 0.8, "{k} ordered {}", s.ordered_fraction);
            assert!(
                s.inductive_fraction > 0.5,
                "{k} inductive {}",
                s.inductive_fraction
            );
            assert!(s.imbalanced(), "{k} imbalance {}", s.region_imbalance);
            assert!(!s.dep_distances.is_empty(), "{k} has inter-region deps");
        }
    }

    #[test]
    fn gemm_is_regular() {
        let s = trace("gemm", 24);
        assert!(s.inductive_fraction < 0.1, "{}", s.inductive_fraction);
        assert!(s.dep_distances.is_empty(), "no inter-region deps in gemm");
    }

    #[test]
    fn dependence_distances_in_paper_band() {
        // Paper: most dependences between ~75 and ~1000 arith insts.
        let s = trace("cholesky", 16);
        let med = s.median_distance();
        assert!(
            (10..=2000).contains(&med),
            "median distance {med} out of plausible band"
        );
    }

    #[test]
    fn polybench_less_inductive_than_dsp() {
        let poly_avg: f64 = POLYBENCH
            .iter()
            .map(|k| trace(k, 16).inductive_fraction)
            .sum::<f64>()
            / POLYBENCH.len() as f64;
        let dsp_avg: f64 = ["cholesky", "qr", "svd", "solver"]
            .iter()
            .map(|k| trace(k, 16).inductive_fraction)
            .sum::<f64>()
            / 4.0;
        assert!(dsp_avg > poly_avg, "dsp {dsp_avg} vs poly {poly_avg}");
    }

    #[test]
    fn all_kernels_traceable_at_fig7_sizes() {
        for k in DSP.iter().chain(POLYBENCH.iter()) {
            for n in [16, 32] {
                let s = trace(k, n);
                assert!(s.regions >= 1, "{k}");
            }
        }
    }
}
