//! Dense reference linear algebra (f64) used as the in-process oracle for
//! the simulator's functional outputs. The AOT/PJRT golden path
//! (runtime::Engine) is the cross-language oracle; this module is the fast
//! in-crate one used inside unit/property tests.

/// Row-major square/rectangular matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Deterministic well-conditioned SPD matrix; matches
    /// python ref.make_spd structurally (not bit-identical — tests use it
    /// only as an SPD generator, cross-checks pass explicit data).
    pub fn spd(n: usize, seed: f64) -> Self {
        let g = Self::from_fn(n, n, |i, j| {
            (((i + 1) as f64) * ((j + 2) as f64) * 0.05 + seed).sin() * 0.9
        });
        let mut m = g.matmul(&g.transpose());
        for i in 0..n {
            m[(i, i)] += n as f64;
        }
        m
    }

    pub fn transpose(&self) -> Self {
        Self::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    pub fn matmul(&self, other: &Self) -> Self {
        assert_eq!(self.cols, other.rows);
        let mut out = Self::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factor L (lower) of SPD `a`. Panics on non-SPD input.
pub fn cholesky(a: &Mat) -> Mat {
    let n = a.rows;
    let mut l = a.clone();
    for k in 0..n {
        let d = l[(k, k)].sqrt();
        assert!(d.is_finite() && d > 0.0, "matrix not SPD at pivot {k}");
        l[(k, k)] = d;
        for i in k + 1..n {
            l[(i, k)] /= d;
        }
        for j in k + 1..n {
            let ljk = l[(j, k)];
            for i in j..n {
                let v = l[(i, k)] * ljk;
                l[(i, j)] -= v;
            }
        }
    }
    for i in 0..n {
        for j in i + 1..n {
            l[(i, j)] = 0.0;
        }
    }
    l
}

/// Doolittle LU without pivoting: returns the combined L\U factor
/// (U in the upper triangle + diagonal, unit-diagonal L strictly
/// below). Panics on a zero pivot — callers factor diagonally dominant
/// matrices.
pub fn lu(a: &Mat) -> Mat {
    let n = a.rows;
    let mut m = a.clone();
    for k in 0..n {
        let piv = m[(k, k)];
        assert!(piv.abs() > 1e-300, "zero pivot at {k}");
        for i in k + 1..n {
            m[(i, k)] /= piv;
        }
        for j in k + 1..n {
            let akj = m[(k, j)];
            for i in k + 1..n {
                let l = m[(i, k)];
                m[(i, j)] -= l * akj;
            }
        }
    }
    m
}

/// Forward substitution: solve L x = b for lower-triangular L.
pub fn fwd_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for j in 0..n {
        let mut s = b[j];
        for k in 0..j {
            s -= l[(j, k)] * x[k];
        }
        x[j] = s / l[(j, j)];
    }
    x
}

/// Householder QR: returns (q, r).
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let n = a.rows;
    let mut r = a.clone();
    let mut q = Mat::eye(n);
    for k in 0..n {
        let mut v = vec![0.0; n];
        let mut norm2 = 0.0;
        for i in k..n {
            v[i] = r[(i, k)];
            norm2 += v[i] * v[i];
        }
        let norm = norm2.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let sign = if v[k] >= 0.0 { 1.0 } else { -1.0 };
        v[k] += sign * norm;
        let vn2: f64 = v.iter().map(|x| x * x).sum();
        if vn2 < 1e-300 {
            continue;
        }
        let inv = 2.0 / vn2;
        // r -= inv * v (v^T r); q -= inv * (q v) v^T
        for j in 0..n {
            let dot: f64 = (k..n).map(|i| v[i] * r[(i, j)]).sum();
            for i in k..n {
                r[(i, j)] -= inv * v[i] * dot;
            }
        }
        for i in 0..n {
            let dot: f64 = (k..n).map(|j| q[(i, j)] * v[j]).sum();
            for j in k..n {
                q[(i, j)] -= inv * dot * v[j];
            }
        }
    }
    (q, r)
}

/// Singular values via one-sided Jacobi (descending).
pub fn svd_values(a: &Mat, sweeps: usize) -> Vec<f64> {
    let n = a.rows;
    let mut m = a.clone();
    for _ in 0..sweeps {
        for p in 0..n - 1 {
            for q in p + 1..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    app += m[(i, p)] * m[(i, p)];
                    aqq += m[(i, q)] * m[(i, q)];
                    apq += m[(i, p)] * m[(i, q)];
                }
                if apq.abs() <= 1e-14 * (app * aqq).sqrt() + 1e-300 {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let vp = m[(i, p)];
                    let vq = m[(i, q)];
                    m[(i, p)] = c * vp - s * vq;
                    m[(i, q)] = s * vp + c * vq;
                }
            }
        }
    }
    let mut vals: Vec<f64> = (0..n)
        .map(|j| (0..n).map(|i| m[(i, j)] * m[(i, j)]).sum::<f64>().sqrt())
        .collect();
    vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
    vals
}

/// Correlation-form FIR: `y[i] = sum_j h[j] x[i+j]`.
pub fn fir(x: &[f64], h: &[f64]) -> Vec<f64> {
    let n_out = x.len() + 1 - h.len();
    (0..n_out)
        .map(|i| h.iter().enumerate().map(|(j, &hj)| hj * x[i + j]).sum())
        .collect()
}

/// Radix-2 DIT FFT, in-place on (re, im). len must be a power of two.
pub fn fft(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert!(n.is_power_of_two());
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        for start in (0..n).step_by(len) {
            for k in 0..len / 2 {
                let (wr, wi) = ((ang * k as f64).cos(), (ang * k as f64).sin());
                let (ur, ui) = (re[start + k], im[start + k]);
                let (vr0, vi0) = (re[start + k + len / 2], im[start + k + len / 2]);
                let vr = vr0 * wr - vi0 * wi;
                let vi = vr0 * wi + vi0 * wr;
                re[start + k] = ur + vr;
                im[start + k] = ui + vi;
                re[start + k + len / 2] = ur - vr;
                im[start + k + len / 2] = ui - vi;
            }
        }
        len <<= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_reconstructs() {
        for n in [4, 12, 16, 32] {
            let a = Mat::spd(n, 0.0);
            let l = cholesky(&a);
            let llt = l.matmul(&l.transpose());
            assert!(llt.max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn lu_reconstructs() {
        for n in [4, 8, 16] {
            let a = Mat::spd(n, 0.4);
            let f = lu(&a);
            // Rebuild A = L * U from the combined factor.
            let mut l = Mat::eye(n);
            let mut u = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    if i > j {
                        l[(i, j)] = f[(i, j)];
                    } else {
                        u[(i, j)] = f[(i, j)];
                    }
                }
            }
            assert!(l.matmul(&u).max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solver_solves() {
        let a = Mat::spd(8, 1.0);
        let l = cholesky(&a);
        let b: Vec<f64> = (0..8).map(|i| (i as f64 * 0.3).sin()).collect();
        let x = fwd_solve(&l, &b);
        for j in 0..8 {
            let got: f64 = (0..8).map(|k| l[(j, k)] * x[k]).sum();
            assert!((got - b[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn qr_orthogonal_and_reconstructs() {
        let a = Mat::spd(12, 2.0);
        let (q, r) = qr(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-9);
        assert!(q.transpose().matmul(&q).max_abs_diff(&Mat::eye(12)) < 1e-9);
        for i in 0..12 {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-9);
            }
        }
    }

    #[test]
    fn svd_matches_eigen_of_gram() {
        // For SPD a, singular values == eigenvalues; check via trace/frobenius.
        let a = Mat::spd(8, 0.5);
        let vals = svd_values(&a, 20);
        let trace: f64 = (0..8).map(|i| a[(i, i)]).sum();
        let fro2: f64 = a.data.iter().map(|x| x * x).sum();
        let s1: f64 = vals.iter().sum();
        let s2: f64 = vals.iter().map(|v| v * v).sum();
        assert!((s1 - trace).abs() < 1e-6 * trace);
        assert!((s2 - fro2).abs() < 1e-6 * fro2);
        assert!(vals.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn fft_impulse_and_parseval() {
        let n = 64;
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[0] = 1.0;
        fft(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - 1.0).abs() < 1e-12 && im[i].abs() < 1e-12);
        }
        // Parseval on a random-ish signal.
        let mut re: Vec<f64> = (0..n).map(|i| ((i * 7) as f64 * 0.13).sin()).collect();
        let mut im = vec![0.0; n];
        let t2: f64 = re.iter().map(|x| x * x).sum();
        fft(&mut re, &mut im);
        let f2: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum();
        assert!((f2 / n as f64 - t2).abs() < 1e-9 * t2.max(1.0));
    }

    #[test]
    fn fir_matches_manual() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let h = vec![0.5, 0.25];
        let y = fir(&x, &h);
        assert_eq!(y.len(), 4);
        assert!((y[0] - (0.5 + 0.5)).abs() < 1e-12);
        assert!((y[3] - (2.0 + 1.25)).abs() < 1e-12);
    }
}
