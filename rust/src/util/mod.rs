//! Small shared utilities: deterministic RNG, statistics, linear algebra
//! references used to validate the simulator's functional outputs.

pub mod linalg;
pub mod rng;
pub mod stats;

pub use rng::Rng;

/// Geometric mean of positive values (paper reports geomeans throughout).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Ceiling division for unsigned sizes.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0, 16.0]) - 8.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 4), 3);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }
}
