//! Summary statistics + tiny text rendering for the bench harnesses
//! (criterion is unavailable offline; benches print paper-style tables).

/// Percentile of a sample (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Empirical CDF evaluated at sorted sample points: returns (x, F(x)) pairs.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Render an ASCII sparkline-style CDF row sampled at given x gridpoints.
pub fn cdf_at(points: &[(f64, f64)], x: f64) -> f64 {
    let mut f = 0.0;
    for &(px, pf) in points {
        if px <= x {
            f = pf;
        } else {
            break;
        }
    }
    f
}

/// Fixed-width table printer used by every bench/report harness.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a speedup like the paper ("4.6x").
pub fn fx(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_and_mean() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let points = cdf(&[3.0, 1.0, 2.0]);
        assert_eq!(points.len(), 3);
        assert!((cdf_at(&points, 0.5) - 0.0).abs() < 1e-12);
        assert!((cdf_at(&points, 1.5) - 1.0 / 3.0).abs() < 1e-12);
        assert!((cdf_at(&points, 99.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("a") && s.contains("bb") && s.contains("1"));
    }
}
