//! Deterministic xoshiro256** RNG — no external `rand` crate is available
//! offline, and the simulator/compiler need reproducible randomness anyway.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate lambda (inter-arrival times in the coordinator).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_uniformish() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut r = Rng::new(7);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_in_range_and_normal_moments() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let v = r.int(-3, 3);
            assert!((-3..=3).contains(&v));
        }
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }
}
