//! Dataflow-graph IR (paper §5, Fig 13b): computation is expressed as
//! multiple independently-triggered dataflow graphs whose inputs/outputs
//! are named ports; streams describe their communication and reuse.
//!
//! A DFG fires when every input port holds one (vector) instance and every
//! output binding has FIFO space; firing consumes/peeks inputs per the
//! port reuse config, evaluates all nodes, and pushes gated outputs.
//! Criticality (paper Feature 5) selects dedicated vs temporal mapping.

pub mod exec;

pub use exec::{exec_dfg, new_acc_state, AccState, VecVal};

/// Functional-unit classes of the heterogeneous fabric (paper Table 3:
/// 14 add, 9 mult, 3 sqrt/div per lane).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FuClass {
    Add,
    Mul,
    SqrtDiv,
}

/// Dataflow node operations. `Acc*` nodes carry cross-firing state —
/// REVEL's mechanism for production rates > 1 (reduction edges).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
    Sqrt,
    /// 1/sqrt(x) — the point region of Cholesky/QR.
    Rsqrt,
    Neg,
    Abs,
    Max,
    Min,
    /// a >= b ? 1.0 : 0.0
    CmpGe,
    /// cond (a) != 0 ? b : c
    Select,
    /// Per-lane accumulator: state += a; emits (via gated out-binding)
    /// and resets when gate (b) >= 0.5.
    Acc,
    /// Cross-lane reduction accumulator: state += sum(active lanes of a);
    /// output is the scalar state broadcast; resets when gate (b) >= 0.5.
    AccReduce,
    /// Identity (port forwarding / fan-out staging).
    Copy,
}

impl Op {
    pub fn fu_class(&self) -> FuClass {
        match self {
            Op::Mul => FuClass::Mul,
            Op::Div | Op::Sqrt | Op::Rsqrt => FuClass::SqrtDiv,
            _ => FuClass::Add,
        }
    }

    /// Pipeline latency in cycles (paper Table 3: div/sqrt lat 12; simple
    /// ALU ops modeled at 2, multiply at 3).
    pub fn latency(&self) -> u64 {
        match self.fu_class() {
            FuClass::Add => 2,
            FuClass::Mul => 3,
            FuClass::SqrtDiv => 12,
        }
    }

    /// Initiation interval of the FU (div/sqrt throughput 5, others 1).
    pub fn ii(&self) -> u64 {
        match self.fu_class() {
            FuClass::SqrtDiv => 5,
            _ => 1,
        }
    }

    pub fn arity(&self) -> usize {
        match self {
            Op::Sqrt | Op::Rsqrt | Op::Neg | Op::Abs | Op::Copy => 1,
            Op::Select => 3,
            _ => 2,
        }
    }
}

/// A node operand: an input port (by local index), another node, or an
/// immediate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Operand {
    Port(usize),
    Node(usize),
    Const(f64),
}

#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub a: Operand,
    pub b: Option<Operand>,
    pub c: Option<Operand>,
}

/// Input-port declaration: a *global* lane port id plus vector width
/// (in 32-bit words). Width-1 ports broadcast their scalar across the
/// DFG's vector lanes.
#[derive(Clone, Copy, Debug)]
pub struct InPort {
    pub gid: usize,
    pub width: usize,
}

/// Output binding: which node value leaves on which global port. `gate`
/// (an input-port local index carrying a 0/1 Const stream) implements
/// inductive production rates: the value is pushed only on gate==1
/// firings (e.g. accumulator emit, or "first element of each row").
#[derive(Clone, Copy, Debug)]
pub struct OutBinding {
    pub gid: usize,
    pub node: usize,
    pub gate: Option<usize>,
    /// Width of the produced instance (usually the DFG width, or 1 for
    /// scalar taps like reduction results).
    pub width: usize,
}

/// Criticality classification (paper Feature 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criticality {
    /// Mapped to the dedicated (fully pipelined) fabric region.
    Critical,
    /// Mapped to the temporal (time-multiplexed) region.
    NonCritical,
}

/// A dataflow graph.
#[derive(Clone, Debug)]
pub struct Dfg {
    pub name: String,
    pub criticality: Criticality,
    pub nodes: Vec<Node>,
    pub in_ports: Vec<InPort>,
    pub outs: Vec<OutBinding>,
}

impl Dfg {
    /// Vector width of the DFG = max input/output width.
    pub fn width(&self) -> usize {
        self.in_ports
            .iter()
            .map(|p| p.width)
            .chain(self.outs.iter().map(|o| o.width))
            .max()
            .unwrap_or(1)
    }

    /// Instruction count (temporal-region occupancy; paper Q8/Q9).
    pub fn insts(&self) -> usize {
        self.nodes.len()
    }

    /// Dedicated-fabric tile demand per FU class: a width-w vector node
    /// needs ceil(w/2) subword-SIMD tiles (Table 3: 2-way FP per tile);
    /// sqrt/div tiles are not subword and need w tiles.
    pub fn tile_demand(&self) -> std::collections::HashMap<FuClass, usize> {
        let w = self.width();
        let mut m = std::collections::HashMap::new();
        for n in &self.nodes {
            let cls = n.op.fu_class();
            let need = match cls {
                FuClass::SqrtDiv => self.node_width(n).min(w),
                _ => (self.node_width(n) + 1) / 2,
            };
            *m.entry(cls).or_insert(0) += need;
        }
        m
    }

    /// Effective width of a node (scalar chains stay width 1).
    fn node_width(&self, _n: &Node) -> usize {
        // Conservative: nodes run at the DFG width. (The compiler narrows
        // scalar subgraphs; this bound is what placement validates.)
        self.width()
    }

    /// Longest op-latency path from any port to any output node, in
    /// cycles — the DFG contribution to pipeline depth (routing adds
    /// hops on top; see compiler::Placement).
    pub fn critical_path(&self) -> u64 {
        let mut depth = vec![0u64; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            let mut d = 0;
            for opnd in [Some(n.a), n.b, n.c].into_iter().flatten() {
                if let Operand::Node(j) = opnd {
                    assert!(j < i, "DFG must be topologically ordered");
                    d = d.max(depth[j]);
                }
            }
            depth[i] = d + n.op.latency();
        }
        depth.into_iter().max().unwrap_or(0)
    }

    /// Validate topological order and operand arity/ranges.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            let ops = [Some(n.a), n.b, n.c];
            let arity = ops.iter().flatten().count();
            if arity != n.op.arity() {
                return Err(format!(
                    "{}: node {i} {:?} arity {} != {}",
                    self.name,
                    n.op,
                    arity,
                    n.op.arity()
                ));
            }
            for opnd in ops.into_iter().flatten() {
                match opnd {
                    Operand::Node(j) if j >= i => {
                        return Err(format!(
                            "{}: node {i} references later node {j}",
                            self.name
                        ))
                    }
                    Operand::Port(p) if p >= self.in_ports.len() => {
                        return Err(format!(
                            "{}: node {i} references missing port {p}",
                            self.name
                        ))
                    }
                    _ => {}
                }
            }
        }
        for o in &self.outs {
            if o.node >= self.nodes.len() {
                return Err(format!("{}: out binding to missing node", self.name));
            }
            if let Some(g) = o.gate {
                if g >= self.in_ports.len() {
                    return Err(format!("{}: gate references missing port", self.name));
                }
            }
        }
        Ok(())
    }
}

/// A full lane configuration: up to 4 concurrently-firing dataflows
/// (paper Table 3 "Data Firing: 4 Independent Dataflows").
#[derive(Clone, Debug)]
pub struct LaneConfig {
    pub name: String,
    pub dfgs: Vec<Dfg>,
}

pub const MAX_DFGS: usize = 4;

impl LaneConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.dfgs.len() > MAX_DFGS {
            return Err(format!(
                "{}: {} dataflows > {MAX_DFGS}",
                self.name,
                self.dfgs.len()
            ));
        }
        let mut in_seen = std::collections::HashSet::new();
        let mut out_seen = std::collections::HashSet::new();
        for d in &self.dfgs {
            d.validate()?;
            for p in &d.in_ports {
                if !in_seen.insert(p.gid) {
                    return Err(format!("{}: input port {} bound twice", self.name, p.gid));
                }
            }
            for o in &d.outs {
                if !out_seen.insert(o.gid) {
                    return Err(format!("{}: output port {} bound twice", self.name, o.gid));
                }
            }
        }
        Ok(())
    }

    /// (dfg index, local in-port index) for a global input port id.
    pub fn find_in_port(&self, gid: usize) -> Option<(usize, usize)> {
        for (di, d) in self.dfgs.iter().enumerate() {
            for (pi, p) in d.in_ports.iter().enumerate() {
                if p.gid == gid {
                    return Some((di, pi));
                }
            }
        }
        None
    }

    /// (dfg index, out-binding index) for a global output port id.
    pub fn find_out_port(&self, gid: usize) -> Option<(usize, usize)> {
        for (di, d) in self.dfgs.iter().enumerate() {
            for (oi, o) in d.outs.iter().enumerate() {
                if o.gid == gid {
                    return Some((di, oi));
                }
            }
        }
        None
    }
}

/// Builder for ergonomic DFG construction in workload code.
pub struct DfgBuilder {
    dfg: Dfg,
}

impl DfgBuilder {
    pub fn new(name: &str, criticality: Criticality) -> Self {
        Self {
            dfg: Dfg {
                name: name.to_string(),
                criticality,
                nodes: vec![],
                in_ports: vec![],
                outs: vec![],
            },
        }
    }

    /// Declare an input port; returns its local index (usable as Operand).
    pub fn in_port(&mut self, gid: usize, width: usize) -> Operand {
        self.dfg.in_ports.push(InPort { gid, width });
        Operand::Port(self.dfg.in_ports.len() - 1)
    }

    pub fn node(&mut self, op: Op, operands: &[Operand]) -> Operand {
        assert_eq!(operands.len(), op.arity(), "{:?}", op);
        self.dfg.nodes.push(Node {
            op,
            a: operands[0],
            b: operands.get(1).copied(),
            c: operands.get(2).copied(),
        });
        Operand::Node(self.dfg.nodes.len() - 1)
    }

    pub fn out(&mut self, gid: usize, node: Operand, width: usize) {
        self.out_gated(gid, node, width, None);
    }

    pub fn out_gated(
        &mut self,
        gid: usize,
        node: Operand,
        width: usize,
        gate: Option<Operand>,
    ) {
        let node = match node {
            Operand::Node(i) => i,
            Operand::Port(_) | Operand::Const(_) => {
                // Wrap through a Copy node so outs always name nodes.
                self.dfg.nodes.push(Node { op: Op::Copy, a: node, b: None, c: None });
                self.dfg.nodes.len() - 1
            }
        };
        let gate = gate.map(|g| match g {
            Operand::Port(p) => p,
            _ => panic!("gate must be an input port"),
        });
        self.dfg.outs.push(OutBinding { gid, node, gate, width });
    }

    pub fn build(self) -> Dfg {
        self.dfg.validate().expect("invalid DFG");
        self.dfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point_dfg() -> Dfg {
        // Cholesky point region: d = sqrt(a_kk); inva = 1/d.
        let mut b = DfgBuilder::new("point", Criticality::NonCritical);
        let akk = b.in_port(0, 1);
        let d = b.node(Op::Sqrt, &[akk]);
        let inva = b.node(Op::Div, &[Operand::Const(1.0), d]);
        b.out(0, d, 1);
        b.out(1, inva, 1);
        b.build()
    }

    #[test]
    fn builder_produces_valid_dfg() {
        let d = point_dfg();
        assert_eq!(d.insts(), 2);
        assert_eq!(d.width(), 1);
        assert!(d.critical_path() >= 24, "sqrt+div chain");
        assert!(d.validate().is_ok());
    }

    #[test]
    fn tile_demand_counts_subword_simd() {
        let mut b = DfgBuilder::new("vec", Criticality::Critical);
        let x = b.in_port(0, 8);
        let y = b.in_port(1, 8);
        let m = b.node(Op::Mul, &[x, y]);
        let s = b.node(Op::Sub, &[x, m]);
        b.out(0, s, 8);
        let d = b.build();
        let t = d.tile_demand();
        assert_eq!(t[&FuClass::Mul], 4); // width 8 / 2-way SIMD
        assert_eq!(t[&FuClass::Add], 4);
    }

    #[test]
    fn lane_config_rejects_port_clash_and_too_many_dfgs() {
        let d = point_dfg();
        let cfg = LaneConfig { name: "x".into(), dfgs: vec![d.clone(), d.clone()] };
        assert!(cfg.validate().is_err()); // same gids twice
        let cfg5 = LaneConfig {
            name: "y".into(),
            dfgs: (0..5)
                .map(|i| {
                    let mut b =
                        DfgBuilder::new(&format!("d{i}"), Criticality::Critical);
                    let x = b.in_port(10 + i, 1);
                    let y = b.node(Op::Copy, &[x]);
                    b.out(10 + i, y, 1);
                    b.build()
                })
                .collect(),
        };
        assert!(cfg5.validate().is_err());
    }

    #[test]
    fn find_ports_resolves_global_ids() {
        let cfg = LaneConfig { name: "c".into(), dfgs: vec![point_dfg()] };
        assert_eq!(cfg.find_in_port(0), Some((0, 0)));
        assert_eq!(cfg.find_out_port(1), Some((0, 1)));
        assert_eq!(cfg.find_in_port(9), None);
    }

    #[test]
    fn validate_catches_bad_arity_and_order() {
        let bad = Dfg {
            name: "bad".into(),
            criticality: Criticality::Critical,
            nodes: vec![Node { op: Op::Add, a: Operand::Const(1.0), b: None, c: None }],
            in_ports: vec![],
            outs: vec![],
        };
        assert!(bad.validate().is_err());
    }
}
