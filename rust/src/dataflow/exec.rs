//! Functional evaluation of a dataflow graph for one firing.
//!
//! The simulator is functional **and** timing-accurate: ports carry real
//! values, so every workload's simulated output can be checked against the
//! in-crate reference (util::linalg) and the PJRT golden (runtime).
//!
//! Vector semantics: the DFG evaluates at width `w = dfg.width()`; width-1
//! input instances broadcast across lanes. Predication (implicit vector
//! masking) deactivates lanes: masked lanes keep accumulator state
//! unchanged and their stored outputs are suppressed downstream.

use super::{Dfg, Op, Operand};

/// One vector instance travelling through a port: values + active-lane
/// predicate (paper §6.2 "Implicit Vector Masking" predication FIFO).
/// `Default` is the empty instance — the lane's buffer pool recycles
/// spent instances through it so steady-state stream delivery reuses
/// capacity instead of allocating.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VecVal {
    pub vals: Vec<f64>,
    pub pred: Vec<bool>,
}

impl VecVal {
    pub fn scalar(v: f64) -> Self {
        Self { vals: vec![v], pred: vec![true] }
    }

    pub fn full(vals: Vec<f64>) -> Self {
        let n = vals.len();
        Self { vals, pred: vec![true; n] }
    }

    pub fn masked(vals: Vec<f64>, pred: Vec<bool>) -> Self {
        assert_eq!(vals.len(), pred.len());
        Self { vals, pred }
    }

    pub fn width(&self) -> usize {
        self.vals.len()
    }
}

/// Cross-firing accumulator state: one f64 per node per lane.
pub type AccState = Vec<Vec<f64>>;

pub fn new_acc_state(dfg: &Dfg) -> AccState {
    vec![vec![0.0; dfg.width()]; dfg.nodes.len()]
}

/// Evaluate one firing. Returns, per out-binding, `Some(instance)` if the
/// binding's gate is open this firing (or ungated), else `None`.
pub fn exec_dfg<V: std::borrow::Borrow<VecVal>>(
    dfg: &Dfg,
    inputs: &[V],
    acc: &mut AccState,
) -> Vec<Option<VecVal>> {
    let w = dfg.width();
    assert_eq!(inputs.len(), dfg.in_ports.len(), "{}", dfg.name);
    // Per-lane input fetch without materializing broadcast copies
    // (this function runs once per simulated firing — keep it lean).
    let in_val = |p: usize, l: usize| -> f64 {
        let v: &VecVal = inputs[p].borrow();
        if v.width() == w {
            v.vals[l]
        } else if v.width() == 1 {
            v.vals[0]
        } else {
            panic!("width mismatch: instance {} vs dfg {}", v.width(), w)
        }
    };
    // Firing-level predicate: a lane is active iff all vector-width inputs
    // agree it is (scalar broadcasts don't narrow the mask).
    let mut pred = vec![true; w];
    for (inp, decl) in inputs.iter().zip(&dfg.in_ports) {
        let inp: &VecVal = inp.borrow();
        if decl.width > 1 || w == 1 {
            for l in 0..w {
                pred[l] &= if inp.width() == w { inp.pred[l] } else { inp.pred[0] };
            }
        }
    }

    let mut vals: Vec<Vec<f64>> = Vec::with_capacity(dfg.nodes.len());
    for (i, n) in dfg.nodes.iter().enumerate() {
        let get = |o: Operand, vals: &Vec<Vec<f64>>, l: usize| -> f64 {
            match o {
                Operand::Const(c) => c,
                Operand::Port(p) => in_val(p, l),
                Operand::Node(j) => vals[j][l],
            }
        };
        let mut out = vec![0.0; w];
        for l in 0..w {
            let av = get(n.a, &vals, l);
            let bv = n.b.map(|o| get(o, &vals, l)).unwrap_or(0.0);
            let cv = n.c.map(|o| get(o, &vals, l)).unwrap_or(0.0);
            out[l] = match n.op {
                Op::Add => av + bv,
                Op::Sub => av - bv,
                Op::Mul => av * bv,
                Op::Div => av / bv,
                Op::Sqrt => av.sqrt(),
                Op::Rsqrt => 1.0 / av.sqrt(),
                Op::Neg => -av,
                Op::Abs => av.abs(),
                Op::Max => av.max(bv),
                Op::Min => av.min(bv),
                Op::CmpGe => {
                    if av >= bv {
                        1.0
                    } else {
                        0.0
                    }
                }
                Op::Select => {
                    if av != 0.0 {
                        bv
                    } else {
                        cv
                    }
                }
                Op::Acc => {
                    if pred[l] {
                        acc[i][l] += av;
                    }
                    let v = acc[i][l];
                    if bv >= 0.5 && pred[l] {
                        acc[i][l] = 0.0;
                    }
                    v
                }
                Op::AccReduce => 0.0, // handled below (cross-lane)
                Op::Copy => av,
            };
        }
        if n.op == Op::AccReduce {
            let add: f64 = (0..w)
                .filter(|&l| pred[l])
                .map(|l| get(n.a, &vals, l))
                .sum();
            acc[i][0] += add;
            let v = acc[i][0];
            // Gate is scalar-ish: emit/reset decided by lane 0's gate value.
            let gate = n.b.map(|o| get(o, &vals, 0)).unwrap_or(0.0);
            if gate >= 0.5 {
                acc[i][0] = 0.0;
            }
            for l in 0..w {
                out[l] = v;
            }
        }
        vals.push(out);
    }

    dfg.outs
        .iter()
        .map(|ob| {
            let open = match ob.gate {
                None => true,
                Some(g) => in_val(g, 0) >= 0.5,
            };
            if !open {
                return None;
            }
            let v = &vals[ob.node];
            if ob.width == 1 {
                Some(VecVal::scalar(v[0]))
            } else {
                Some(VecVal::masked(v[..ob.width].to_vec(), pred[..ob.width].to_vec()))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Criticality, DfgBuilder};

    #[test]
    fn point_region_computes_sqrt_and_reciprocal() {
        let mut b = DfgBuilder::new("point", Criticality::NonCritical);
        let akk = b.in_port(0, 1);
        let d = b.node(Op::Sqrt, &[akk]);
        let inva = b.node(Op::Div, &[Operand::Const(1.0), d]);
        b.out(0, d, 1);
        b.out(1, inva, 1);
        let dfg = b.build();
        let mut acc = new_acc_state(&dfg);
        let outs = exec_dfg(&dfg, &[VecVal::scalar(16.0)], &mut acc);
        assert_eq!(outs[0].as_ref().unwrap().vals[0], 4.0);
        assert_eq!(outs[1].as_ref().unwrap().vals[0], 0.25);
    }

    #[test]
    fn vector_rank1_update_with_broadcast_and_mask() {
        // upd = a - col_i * col_j  (matrix region of Cholesky)
        let mut b = DfgBuilder::new("matrix", Criticality::Critical);
        let a = b.in_port(0, 4);
        let ci = b.in_port(1, 1); // scalar broadcast
        let cj = b.in_port(2, 4);
        let prod = b.node(Op::Mul, &[ci, cj]);
        let upd = b.node(Op::Sub, &[a, prod]);
        b.out(0, upd, 4);
        let dfg = b.build();
        let mut acc = new_acc_state(&dfg);
        let outs = exec_dfg(
            &dfg,
            &[
                VecVal::masked(vec![10.0, 20.0, 30.0, 0.0], vec![true, true, true, false]),
                VecVal::scalar(2.0),
                VecVal::masked(vec![1.0, 2.0, 3.0, 0.0], vec![true, true, true, false]),
            ],
            &mut acc,
        );
        let o = outs[0].as_ref().unwrap();
        assert_eq!(o.vals[..3], [8.0, 16.0, 24.0]);
        assert_eq!(o.pred, vec![true, true, true, false]);
    }

    #[test]
    fn acc_reduce_dot_product_with_emit_gate() {
        // Dot product over 2 firings of width 4, gate on second firing.
        let mut b = DfgBuilder::new("dot", Criticality::Critical);
        let x = b.in_port(0, 4);
        let y = b.in_port(1, 4);
        let g = b.in_port(2, 1);
        let prod = b.node(Op::Mul, &[x, y]);
        let acc_n = b.node(Op::AccReduce, &[prod, g]);
        b.out_gated(0, acc_n, 1, Some(g));
        let dfg = b.build();
        let mut st = new_acc_state(&dfg);
        let f1 = exec_dfg(
            &dfg,
            &[
                VecVal::full(vec![1.0, 2.0, 3.0, 4.0]),
                VecVal::full(vec![1.0, 1.0, 1.0, 1.0]),
                VecVal::scalar(0.0),
            ],
            &mut st,
        );
        assert!(f1[0].is_none(), "gated off");
        let f2 = exec_dfg(
            &dfg,
            &[
                VecVal::full(vec![5.0, 6.0, 7.0, 8.0]),
                VecVal::full(vec![1.0, 1.0, 1.0, 1.0]),
                VecVal::scalar(1.0),
            ],
            &mut st,
        );
        assert_eq!(f2[0].as_ref().unwrap().vals[0], 36.0);
        // State reset after emit.
        let f3 = exec_dfg(
            &dfg,
            &[
                VecVal::full(vec![1.0, 0.0, 0.0, 0.0]),
                VecVal::full(vec![1.0, 1.0, 1.0, 1.0]),
                VecVal::scalar(1.0),
            ],
            &mut st,
        );
        assert_eq!(f3[0].as_ref().unwrap().vals[0], 1.0);
    }

    #[test]
    fn masked_lanes_do_not_pollute_reduction() {
        let mut b = DfgBuilder::new("dot", Criticality::Critical);
        let x = b.in_port(0, 4);
        let g = b.in_port(1, 1);
        let acc_n = b.node(Op::AccReduce, &[x, g]);
        b.out_gated(0, acc_n, 1, Some(g));
        let dfg = b.build();
        let mut st = new_acc_state(&dfg);
        let out = exec_dfg(
            &dfg,
            &[
                VecVal::masked(vec![1.0, 2.0, 99.0, 99.0], vec![true, true, false, false]),
                VecVal::scalar(1.0),
            ],
            &mut st,
        );
        assert_eq!(out[0].as_ref().unwrap().vals[0], 3.0);
    }

    #[test]
    fn per_lane_acc_keeps_independent_state() {
        let mut b = DfgBuilder::new("acc", Criticality::Critical);
        let x = b.in_port(0, 2);
        let g = b.in_port(1, 1);
        let a = b.node(Op::Acc, &[x, g]);
        b.out_gated(0, a, 2, Some(g));
        let dfg = b.build();
        let mut st = new_acc_state(&dfg);
        exec_dfg(&dfg, &[VecVal::full(vec![1.0, 10.0]), VecVal::scalar(0.0)], &mut st);
        let out = exec_dfg(
            &dfg,
            &[VecVal::full(vec![2.0, 20.0]), VecVal::scalar(1.0)],
            &mut st,
        );
        assert_eq!(out[0].as_ref().unwrap().vals, vec![3.0, 30.0]);
    }
}
