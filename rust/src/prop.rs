//! Minimal property-based testing helper (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` deterministic
//! seeds. Seeds are opaque, so bisection-style shrinking over the seed space
//! would not be meaningful; on failure the helper instead reports the failing
//! seed, making the case exactly reproducible with [`check_one`].

use crate::util::Rng;

/// Run `f` for `cases` deterministic seeds; panic with the failing seed.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single seed (for debugging a reported failure).
pub fn check_one(seed: u64, mut f: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E37_79B9));
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_when_property_holds() {
        check("addition commutes", 16, |rng| {
            let a = rng.int(-100, 100);
            let b = rng.int(-100, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn check_reports_seed_on_failure() {
        check("always fails", 4, |_| panic!("boom"));
    }
}
