//! Typed vector-stream kernel builders.
//!
//! [`Kernel`] assembles a lane configuration out of named dataflow
//! scopes; every input/output port is created by the builder and handed
//! back as a typed handle ([`In`] / [`Out`]) that carries its global
//! port id, width, and the identity of the kernel that created it. The
//! [`ProgBuilder`] then consumes those handles to emit the control
//! program — so a port number can never be fabricated, double-bound, or
//! borrowed from another kernel: misuse panics at build time with a
//! named diagnostic instead of surfacing as a watchdog deadlock deep in
//! simulation.
//!
//! The lowering is exactly the raw-[`Cmd`] lowering the workloads used
//! to hand-roll (including the per-row decomposition of 2D patterns
//! when the inductive feature is ablated — paper Fig 11), which is what
//! the old-vs-new equivalence property tests in `tests/property.rs`
//! assert command by command.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::compiler::Configured;
use crate::dataflow::{Criticality, Dfg, DfgBuilder, LaneConfig, Op, Operand};
use crate::isa::{
    decompose_rows, Cmd, ConstPattern, LaneMask, Pattern2D, Program, Reuse,
    VsCommand, XferDst,
};
use crate::sim::lane::NUM_PORTS;
use crate::workloads::Features;

static NEXT_KERNEL_ID: AtomicU64 = AtomicU64::new(1);

/// Typed handle to a lane **input** port (scratchpad/const/XFER streams
/// deliver into it; a dataflow consumes it). Created only by
/// [`DfgScope::input`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct In {
    kid: u64,
    dfg: usize,
    local: usize,
    gid: usize,
    width: usize,
}

impl In {
    /// Global lane port id this handle names.
    pub fn id(&self) -> usize {
        self.gid
    }

    /// Vector width (words) the owning dataflow declared.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The port as a dataflow operand (valid inside its own scope).
    pub fn wire(&self) -> Operand {
        Operand::Port(self.local)
    }
}

impl From<In> for Operand {
    fn from(p: In) -> Operand {
        p.wire()
    }
}

/// Typed handle to a lane **output** port (a dataflow produces into it;
/// stores/XFERs drain it). Created only by [`DfgScope::output`] /
/// [`DfgScope::output_gated`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Out {
    kid: u64,
    gid: usize,
    width: usize,
}

impl Out {
    /// Global lane port id this handle names.
    pub fn id(&self) -> usize {
        self.gid
    }

    /// Vector width (words) of the produced instances.
    pub fn width(&self) -> usize {
        self.width
    }
}

/// Multi-dataflow kernel under construction.
pub struct Kernel {
    name: String,
    kid: u64,
    dfgs: Vec<Dfg>,
    next_in: usize,
    next_out: usize,
    open_scopes: usize,
}

impl Kernel {
    /// Start a new kernel. Port ids are assigned sequentially per
    /// direction as the dataflow scopes declare them.
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            kid: NEXT_KERNEL_ID.fetch_add(1, Ordering::Relaxed),
            dfgs: Vec::new(),
            next_in: 0,
            next_out: 0,
            open_scopes: 0,
        }
    }

    /// Open a dataflow scope. Call [`DfgScope::done`] to commit it.
    pub fn dfg(&mut self, name: &str, criticality: Criticality) -> DfgScope<'_> {
        self.open_scopes += 1;
        let dfg_idx = self.dfgs.len();
        DfgScope { b: DfgBuilder::new(name, criticality), k: self, dfg_idx }
    }

    /// Validate and freeze the kernel.
    pub fn build(self) -> Result<BuiltKernel, String> {
        if self.open_scopes != 0 {
            return Err(format!(
                "kernel {:?}: {} dataflow scope(s) never committed (call done())",
                self.name, self.open_scopes
            ));
        }
        if self.next_in > NUM_PORTS || self.next_out > NUM_PORTS {
            return Err(format!(
                "kernel {:?}: {} in / {} out ports exceed the lane's {NUM_PORTS}",
                self.name, self.next_in, self.next_out
            ));
        }
        let config = LaneConfig { name: self.name.clone(), dfgs: self.dfgs };
        config.validate()?;
        Ok(BuiltKernel { name: self.name, kid: self.kid, config })
    }
}

/// One dataflow graph under construction inside a [`Kernel`].
pub struct DfgScope<'k> {
    k: &'k mut Kernel,
    b: DfgBuilder,
    dfg_idx: usize,
}

impl DfgScope<'_> {
    /// Declare an input port of the given vector width; returns its
    /// typed handle (use [`In::wire`] to feed nodes).
    pub fn input(&mut self, width: usize) -> In {
        let gid = self.k.next_in;
        self.k.next_in += 1;
        let local = match self.b.in_port(gid, width) {
            Operand::Port(i) => i,
            _ => unreachable!("DfgBuilder::in_port returns a port operand"),
        };
        In { kid: self.k.kid, dfg: self.dfg_idx, local, gid, width }
    }

    /// Add a compute node (same contract as
    /// [`crate::dataflow::DfgBuilder::node`]).
    pub fn node(&mut self, op: Op, operands: &[Operand]) -> Operand {
        self.b.node(op, operands)
    }

    /// Bind `node` to a fresh output port of the given width.
    pub fn output(&mut self, node: Operand, width: usize) -> Out {
        let gid = self.k.next_out;
        self.k.next_out += 1;
        self.b.out(gid, node, width);
        Out { kid: self.k.kid, gid, width }
    }

    /// Bind `node` to a fresh *gated* output port: instances are pushed
    /// only on firings where `gate` (an input of this same dataflow)
    /// carries a 1 — the inductive production-rate mechanism behind the
    /// loop-carried forwards (paper Feature 3).
    pub fn output_gated(&mut self, node: Operand, width: usize, gate: In) -> Out {
        assert!(
            gate.kid == self.k.kid && gate.dfg == self.dfg_idx,
            "kernel {:?} dfg #{}: gate port belongs to another dataflow",
            self.k.name,
            self.dfg_idx
        );
        let gid = self.k.next_out;
        self.k.next_out += 1;
        self.b.out_gated(gid, node, width, Some(Operand::Port(gate.local)));
        Out { kid: self.k.kid, gid, width }
    }

    /// Commit this dataflow into the kernel.
    pub fn done(self) {
        self.k.dfgs.push(self.b.build());
        self.k.open_scopes -= 1;
    }
}

/// A frozen kernel: the lane configuration plus the identity that makes
/// its port handles unforgeable.
pub struct BuiltKernel {
    name: String,
    kid: u64,
    /// The lane configuration to compile (e.g. via
    /// `workloads::cached_config`).
    pub config: LaneConfig,
}

impl BuiltKernel {
    /// Kernel name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Start the control program for this kernel: pushes the
    /// `Configure` broadcast and returns the typed command builder.
    pub fn program(
        &self,
        cfg: Arc<Configured>,
        feats: Features,
        mask: LaneMask,
    ) -> ProgBuilder {
        assert_eq!(
            cfg.config.name, self.name,
            "vsc: configuring kernel {:?} with {:?}'s compiled config",
            self.name, cfg.config.name
        );
        ProgBuilder {
            kernel: self.name.clone(),
            kid: self.kid,
            prog: vec![VsCommand::new(Cmd::Configure(cfg), mask)],
            mask,
            feats,
        }
    }
}

/// Typed control-program builder. Lowers to the exact [`Cmd`] stream the
/// workloads used to hand-write: loads/stores decompose into per-row 1D
/// commands when the inductive feature is off, masking follows the
/// feature switch, and every command carries the builder's lane mask.
pub struct ProgBuilder {
    kernel: String,
    kid: u64,
    prog: Program,
    mask: LaneMask,
    feats: Features,
}

impl ProgBuilder {
    fn ck_in(&self, p: In) {
        assert!(
            p.kid == self.kid,
            "vsc: input port #{} belongs to another kernel (program of {:?})",
            p.gid,
            self.kernel
        );
    }

    fn ck_out(&self, p: Out) {
        assert!(
            p.kid == self.kid,
            "vsc: output port #{} belongs to another kernel (program of {:?})",
            p.gid,
            self.kernel
        );
    }

    fn push(&mut self, cmd: Cmd) {
        self.prog.push(VsCommand::new(cmd, self.mask));
    }

    /// Feature switches this program is built under.
    pub fn feats(&self) -> Features {
        self.feats
    }

    /// Lane mask every command is broadcast to.
    pub fn mask(&self) -> LaneMask {
        self.mask
    }

    /// Scratchpad load stream into `p` (no reuse, no RMW pairing).
    pub fn ld(&mut self, pat: Pattern2D, p: In) {
        self.ld_opts(pat, p, None, None);
    }

    /// Load with a port-side reuse config (paper Feature 2).
    pub fn ld_reuse(&mut self, pat: Pattern2D, p: In, reuse: Reuse) {
        self.ld_opts(pat, p, Some(reuse), None);
    }

    /// Load that is the RMW partner of an in-place store over the same
    /// range (issue the store first; see [`Cmd::LocalLd`]).
    pub fn ld_rmw(&mut self, pat: Pattern2D, p: In, lag: u8) {
        self.ld_opts(pat, p, None, Some(lag));
    }

    /// General load: reuse and RMW pairing both optional. 2D patterns
    /// decompose into per-row commands when the inductive feature is
    /// off (Fig 11's O(n) expansion).
    pub fn ld_opts(
        &mut self,
        pat: Pattern2D,
        p: In,
        reuse: Option<Reuse>,
        rmw: Option<u8>,
    ) {
        self.ck_in(p);
        let masked = self.feats.masking;
        if self.feats.inductive || pat.n_j <= 1 {
            self.push(Cmd::LocalLd { pat, port: p.gid, reuse, masked, rmw });
        } else {
            for row in decompose_rows(&pat) {
                self.push(Cmd::LocalLd { pat: row, port: p.gid, reuse, masked, rmw });
            }
        }
    }

    /// Rectangular-native load: issued as a single command even when
    /// the inductive feature is ablated. Rectangular 2D streams are
    /// native to every capability >= RR (paper Fig 21), so the non-FGOP
    /// kernels (FFT, GEMM) do not decompose under the ablation.
    pub fn ld_rect(&mut self, pat: Pattern2D, p: In, rmw: Option<u8>) {
        self.ck_in(p);
        let masked = self.feats.masking;
        self.push(Cmd::LocalLd { pat, port: p.gid, reuse: None, masked, rmw });
    }

    /// Store-side counterpart of [`ProgBuilder::ld_rect`].
    pub fn st_rect(&mut self, pat: Pattern2D, p: Out, rmw: bool) {
        self.ck_out(p);
        self.push(Cmd::LocalSt { pat, port: p.gid, rmw });
    }

    /// Load with a per-lane address stride (vector-stream control:
    /// one command, per-lane offsets). Never decomposed.
    pub fn ld_strided_lanes(&mut self, pat: Pattern2D, p: In, lane_stride: i64) {
        self.ck_in(p);
        let masked = self.feats.masking;
        self.prog.push(VsCommand::with_stride(
            Cmd::LocalLd { pat, port: p.gid, reuse: None, masked, rmw: None },
            self.mask,
            lane_stride,
        ));
    }

    /// Output-port store stream to the scratchpad.
    pub fn st(&mut self, pat: Pattern2D, p: Out) {
        self.st_opts(pat, p, false);
    }

    /// In-place RMW store: element-ordered against its paired load
    /// instead of issue-blocked (see [`Cmd::LocalSt`]).
    pub fn st_rmw(&mut self, pat: Pattern2D, p: Out) {
        self.st_opts(pat, p, true);
    }

    /// General store; decomposes like [`ProgBuilder::ld_opts`].
    pub fn st_opts(&mut self, pat: Pattern2D, p: Out, rmw: bool) {
        self.ck_out(p);
        if self.feats.inductive || pat.n_j <= 1 {
            self.push(Cmd::LocalSt { pat, port: p.gid, rmw });
        } else {
            for row in decompose_rows(&pat) {
                self.push(Cmd::LocalSt { pat: row, port: p.gid, rmw });
            }
        }
    }

    /// Store with a per-lane address stride. Never decomposed.
    pub fn st_strided_lanes(&mut self, pat: Pattern2D, p: Out, lane_stride: i64) {
        self.ck_out(p);
        self.prog.push(VsCommand::with_stride(
            Cmd::LocalSt { pat, port: p.gid, rmw: false },
            self.mask,
            lane_stride,
        ));
    }

    /// Constant-pattern stream into `p` (inductive control flow).
    pub fn const_st(&mut self, pat: ConstPattern, p: In) {
        self.ck_in(p);
        self.push(Cmd::ConstSt { pat, port: p.gid });
    }

    /// Gate idiom: a run of `n` copies of `val` (e.g. all-ones over a
    /// forwarded column, all-zeros after).
    pub fn gate_run(&mut self, p: In, val: f64, n: i64) {
        self.const_st(ConstPattern::scalar(val, n), p);
    }

    /// Gate idiom: per row j one `val1` then `len(j)-1` `val2`s —
    /// "first element of each row" (loop-carried scalar taps).
    pub fn gate_first_of_row(
        &mut self,
        p: In,
        val1: f64,
        val2: f64,
        n_i: f64,
        n_j: i64,
        s: f64,
    ) {
        self.const_st(ConstPattern::first_of_row(val1, val2, n_i, n_j, s), p);
    }

    /// Gate idiom: `len(j)-1` `val2`s then one `val1` — "last element of
    /// each row" (accumulator emit pacing).
    pub fn gate_last_of_row(
        &mut self,
        p: In,
        val1: f64,
        val2: f64,
        n_i: f64,
        n_j: i64,
        s: f64,
    ) {
        self.const_st(ConstPattern::last_of_row(val1, val2, n_i, n_j, s), p);
    }

    /// Same-lane fine-grain ordered dependence: `n` instances from
    /// `src` to `dst` (no reuse).
    pub fn xfer(&mut self, src: Out, dst: In, n: i64) {
        self.xfer_opts(src, dst, XferDst::Local, n, None);
    }

    /// Same-lane XFER with destination-side reuse (the `inva`/`w_j`
    /// scalar-tap idiom).
    pub fn xfer_reuse(&mut self, src: Out, dst: In, n: i64, reuse: Reuse) {
        self.xfer_opts(src, dst, XferDst::Local, n, Some(reuse));
    }

    /// Neighbor-lane XFER at `+off` (mod lanes).
    pub fn xfer_lane(&mut self, src: Out, dst: In, off: i8, n: i64, reuse: Option<Reuse>) {
        self.xfer_opts(src, dst, XferDst::Lane(off), n, reuse);
    }

    /// Pivot broadcast: replicate each instance to `lanes`' input ports
    /// (bus-serialized — the latency-optimized factorization idiom).
    pub fn bcast(&mut self, src: Out, dst: In, lanes: LaneMask, n: i64, reuse: Option<Reuse>) {
        self.xfer_opts(src, dst, XferDst::Bcast(lanes), n, reuse);
    }

    /// General XFER.
    pub fn xfer_opts(
        &mut self,
        src: Out,
        dst: In,
        to: XferDst,
        n: i64,
        reuse: Option<Reuse>,
    ) {
        self.ck_out(src);
        self.ck_in(dst);
        self.push(Cmd::Xfer { src_port: src.gid, dst_port: dst.gid, dst: to, n, reuse });
    }

    /// Shared-scratchpad load (shared -> local), with per-lane stride
    /// applied to the shared address.
    pub fn shared_ld(
        &mut self,
        pat: Pattern2D,
        shared_addr: i64,
        local_addr: i64,
        lane_stride: i64,
    ) {
        self.prog.push(VsCommand::with_stride(
            Cmd::SharedLd { pat, shared_addr, local_addr },
            self.mask,
            lane_stride,
        ));
    }

    /// Shared-scratchpad store (local -> shared).
    pub fn shared_st(
        &mut self,
        pat: Pattern2D,
        local_addr: i64,
        shared_addr: i64,
        lane_stride: i64,
    ) {
        self.prog.push(VsCommand::with_stride(
            Cmd::SharedSt { pat, local_addr, shared_addr },
            self.mask,
            lane_stride,
        ));
    }

    /// Scratchpad barrier (local + shared streams drain; XFER streams
    /// are unaffected, which is what lets fine-grain dependences overlap
    /// across it).
    pub fn barrier(&mut self) {
        self.push(Cmd::Barrier);
    }

    /// Append `Wait` (control core blocks until the masked lanes go
    /// idle) and return the finished program.
    pub fn finish(mut self) -> Program {
        self.push(Cmd::Wait);
        self.prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_kernel() -> (BuiltKernel, In, In, Out) {
        let mut k = Kernel::new("tiny");
        let mut d = k.dfg("scale", Criticality::Critical);
        let x = d.input(4);
        let s = d.input(1);
        let y = d.node(Op::Mul, &[x.wire(), s.wire()]);
        let out = d.output(y, 4);
        d.done();
        (k.build().unwrap(), x, s, out)
    }

    fn compiled(b: &BuiltKernel) -> Arc<Configured> {
        Configured::new(
            b.config.clone(),
            &crate::compiler::FabricSpec::default_revel(),
            &crate::compiler::CompileOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn ports_are_assigned_sequentially_and_typed() {
        let (built, x, s, out) = tiny_kernel();
        assert_eq!((x.id(), s.id(), out.id()), (0, 1, 0));
        assert_eq!((x.width(), s.width(), out.width()), (4, 1, 4));
        assert_eq!(built.config.dfgs.len(), 1);
        assert_eq!(built.config.find_in_port(1), Some((0, 1)));
    }

    #[test]
    fn program_lowers_to_raw_commands() {
        let (built, x, s, out) = tiny_kernel();
        let cfg = compiled(&built);
        let mask = LaneMask::one(0);
        let mut p = built.program(cfg, Features::ALL, mask);
        p.ld(Pattern2D::lin(0, 8), x);
        p.gate_run(s, 2.0, 2);
        p.st(Pattern2D::lin(16, 8), out);
        let prog = p.finish();
        assert_eq!(prog.len(), 5, "configure + 3 streams + wait");
        assert!(matches!(prog[0].cmd, Cmd::Configure(_)));
        assert!(
            matches!(prog[1].cmd, Cmd::LocalLd { port: 0, masked: true, .. })
        );
        assert!(matches!(prog[4].cmd, Cmd::Wait));
    }

    #[test]
    fn non_inductive_programs_decompose_2d_patterns() {
        let (built, x, _, out) = tiny_kernel();
        let cfg = compiled(&built);
        let no_ind = Features { inductive: false, ..Features::ALL };
        let mut p = built.program(cfg, no_ind, LaneMask::one(0));
        let pat = Pattern2D::inductive(0, 1, 4.0, 5, 4, -1.0);
        p.ld(pat.clone(), x);
        p.st(Pattern2D::lin(32, 4), out);
        let prog = p.finish();
        // Configure + 4 decomposed rows + store + wait.
        assert_eq!(prog.len(), 1 + decompose_rows(&pat).len() + 1 + 1);
        assert!(prog[1..5]
            .iter()
            .all(|c| matches!(c.cmd, Cmd::LocalLd { port: 0, .. })));
    }

    #[test]
    #[should_panic(expected = "belongs to another kernel")]
    fn foreign_port_is_rejected_at_build_time() {
        let (built_a, _, _, _) = tiny_kernel();
        let (_, x_b, _, _) = tiny_kernel();
        let cfg = compiled(&built_a);
        let mut p = built_a.program(cfg, Features::ALL, LaneMask::one(0));
        p.ld(Pattern2D::lin(0, 4), x_b); // port from the other kernel
    }

    #[test]
    fn uncommitted_scope_is_a_build_error() {
        let mut k = Kernel::new("leaky");
        let mut d = k.dfg("d", Criticality::Critical);
        let x = d.input(1);
        let y = d.node(Op::Copy, &[x.wire()]);
        let _ = d.output(y, 1);
        std::mem::drop(d); // forgot done()
        let err = k.build().unwrap_err();
        assert!(err.contains("never committed"), "{err}");
    }

    #[test]
    fn gated_output_requires_same_dfg_gate() {
        let mut k = Kernel::new("g");
        let mut a = k.dfg("a", Criticality::Critical);
        let ax = a.input(4);
        let g = a.input(4);
        let n = a.node(Op::Copy, &[ax.wire()]);
        let _ = a.output_gated(n, 4, g);
        a.done();
        assert!(k.build().is_ok());
    }
}
