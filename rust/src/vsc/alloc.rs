//! Scratchpad **region allocator**: named, non-overlapping address
//! ranges checked against the machine's capacity ([`SimConfig`]).
//!
//! Workloads used to hard-code base addresses (`A_BASE = 0`,
//! `TMP_BASE = 1500`, ...) and every new kernel had to re-derive another
//! module's magic numbers to avoid clobbering them. The allocator packs
//! regions sequentially, aligns each base to a scratchpad line
//! ([`LINE_WORDS`] words — base alignment affects the per-cycle gather
//! width, so line-aligned regions never pay avoidable line-crossing
//! stalls), and rejects over-capacity layouts at build time with a
//! readable diagnostic instead of a simulator out-of-bounds panic.
//!
//! [`Region`] doubles as a checked [`Pattern2D`] factory: patterns built
//! through a region assert containment, so a stream can never silently
//! walk into a neighbouring array.

use crate::isa::Pattern2D;
use crate::sim::{SimConfig, LINE_WORDS};

/// A named, allocated address range in a scratchpad.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    name: &'static str,
    base: i64,
    words: i64,
}

impl Region {
    /// First word address of the region.
    pub fn base(&self) -> i64 {
        self.base
    }

    /// Capacity in 32-bit words.
    pub fn words(&self) -> i64 {
        self.words
    }

    /// One past the last word address.
    pub fn end(&self) -> i64 {
        self.base + self.words
    }

    /// Region name (diagnostics).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Absolute address of `off` within the region (bounds-checked).
    pub fn addr(&self, off: i64) -> i64 {
        assert!(
            (0..self.words).contains(&off),
            "region {:?}: offset {off} outside 0..{}",
            self.name,
            self.words
        );
        self.base + off
    }

    /// Assert a pattern stays inside the region and return it.
    fn checked(&self, pat: Pattern2D) -> Pattern2D {
        if let Some((lo, hi)) = pat.bounds() {
            assert!(
                lo >= self.base && hi < self.end(),
                "region {:?} [{}, {}): pattern spans [{lo}, {hi}]",
                self.name,
                self.base,
                self.end()
            );
        }
        pat
    }

    /// Contiguous pattern of `n` words starting at `off`.
    pub fn lin(&self, off: i64, n: i64) -> Pattern2D {
        self.checked(Pattern2D::lin(self.base + off, n))
    }

    /// 1D strided pattern starting at `off`.
    pub fn strided(&self, off: i64, c_i: i64, n: i64) -> Pattern2D {
        self.checked(Pattern2D::strided(self.base + off, c_i, n))
    }

    /// 2D rectangular pattern starting at `off`.
    pub fn rect(&self, off: i64, c_i: i64, n_i: i64, c_j: i64, n_j: i64) -> Pattern2D {
        self.checked(Pattern2D::rect(self.base + off, c_i, n_i, c_j, n_j))
    }

    /// 2D inductive (stretched) pattern starting at `off` — the RI
    /// stream of paper Fig 10b, bounds-checked against the region.
    pub fn inductive(
        &self,
        off: i64,
        c_i: i64,
        n_i: f64,
        c_j: i64,
        n_j: i64,
        s_ji: f64,
    ) -> Pattern2D {
        self.checked(Pattern2D::inductive(self.base + off, c_i, n_i, c_j, n_j, s_ji))
    }
}

/// Allocation failure (rendered with the full layout so far).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// The request does not fit in the remaining capacity.
    Capacity {
        /// Region that failed to allocate.
        name: &'static str,
        /// Requested size in words.
        words: i64,
        /// Words already allocated (aligned).
        used: i64,
        /// Total scratchpad capacity in words.
        cap: i64,
    },
    /// A region with this name already exists in the allocator.
    Duplicate(&'static str),
    /// Zero- or negative-sized request.
    Empty(&'static str),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Capacity { name, words, used, cap } => write!(
                f,
                "spad region {name:?}: {words} words do not fit \
                 ({used}/{cap} words already allocated)"
            ),
            AllocError::Duplicate(name) => {
                write!(f, "spad region {name:?} allocated twice")
            }
            AllocError::Empty(name) => {
                write!(f, "spad region {name:?} requested with no words")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// Sequential, line-aligned scratchpad region allocator.
#[derive(Clone, Debug)]
pub struct SpadAlloc {
    cap: i64,
    cursor: i64,
    regions: Vec<Region>,
}

impl SpadAlloc {
    /// Allocator over an explicit capacity in words.
    pub fn with_capacity(words: usize) -> Self {
        Self { cap: words as i64, cursor: 0, regions: Vec::new() }
    }

    /// Allocator over a lane's local scratchpad.
    pub fn lane(cfg: &SimConfig) -> Self {
        Self::with_capacity(cfg.lane_spad_words)
    }

    /// Allocator over the shared scratchpad.
    pub fn shared(cfg: &SimConfig) -> Self {
        Self::with_capacity(cfg.shared_words)
    }

    /// Allocate `words` words as a new named region. Bases are aligned
    /// to a scratchpad line; regions never overlap by construction.
    pub fn region(&mut self, name: &'static str, words: i64) -> Result<Region, AllocError> {
        if words <= 0 {
            return Err(AllocError::Empty(name));
        }
        if self.regions.iter().any(|r| r.name == name) {
            return Err(AllocError::Duplicate(name));
        }
        let line = LINE_WORDS as i64;
        let base = (self.cursor + line - 1) / line * line;
        if base + words > self.cap {
            return Err(AllocError::Capacity { name, words, used: base, cap: self.cap });
        }
        let r = Region { name, base, words };
        self.cursor = base + words;
        self.regions.push(r);
        Ok(r)
    }

    /// Words still available (from the aligned cursor).
    pub fn remaining(&self) -> i64 {
        let line = LINE_WORDS as i64;
        self.cap - (self.cursor + line - 1) / line * line
    }

    /// Allocated regions, in allocation order.
    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    /// Render the layout (diagnostics / docs).
    pub fn describe(&self) -> String {
        let mut s = format!("spad layout ({} words):\n", self.cap);
        for r in &self.regions {
            s.push_str(&format!(
                "  [{:>6}, {:>6})  {:>6} words  {}\n",
                r.base,
                r.end(),
                r.words,
                r.name
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_pack_line_aligned_and_disjoint() {
        let mut al = SpadAlloc::with_capacity(256);
        let a = al.region("a", 20).unwrap();
        let b = al.region("b", 7).unwrap();
        let c = al.region("c", 16).unwrap();
        assert_eq!(a.base(), 0);
        assert_eq!(b.base(), 32, "20 rounds up to the next line");
        assert_eq!(c.base(), 48);
        for (x, y) in [(a, b), (b, c), (a, c)] {
            assert!(x.end() <= y.base(), "{x:?} overlaps {y:?}");
        }
        assert!(al.remaining() >= 256 - 64 - 16);
    }

    #[test]
    fn capacity_overflow_is_a_readable_error() {
        let mut al = SpadAlloc::with_capacity(64);
        al.region("a", 40).unwrap();
        let err = al.region("b", 32).unwrap_err();
        assert!(matches!(err, AllocError::Capacity { name: "b", .. }));
        let msg = err.to_string();
        assert!(msg.contains("\"b\"") && msg.contains("64"), "{msg}");
    }

    #[test]
    fn duplicate_and_empty_requests_rejected() {
        let mut al = SpadAlloc::with_capacity(64);
        al.region("a", 8).unwrap();
        assert_eq!(al.region("a", 8).unwrap_err(), AllocError::Duplicate("a"));
        assert_eq!(al.region("z", 0).unwrap_err(), AllocError::Empty("z"));
    }

    #[test]
    fn region_patterns_are_containment_checked() {
        let mut al = SpadAlloc::with_capacity(128);
        let a = al.region("a", 32).unwrap();
        assert_eq!(a.lin(4, 8).start, 4);
        assert_eq!(a.addr(31), 31);
        let tri = a.inductive(0, 1, 4.0, 5, 4, -1.0);
        assert_eq!(tri.total_len(), 10);
    }

    #[test]
    #[should_panic(expected = "pattern spans")]
    fn out_of_region_pattern_panics_at_build_time() {
        let mut al = SpadAlloc::with_capacity(128);
        let a = al.region("a", 32).unwrap();
        let _ = a.lin(16, 32); // runs to word 47 > region end 32
    }

    #[test]
    fn describe_lists_every_region() {
        let mut al = SpadAlloc::with_capacity(128);
        al.region("mat", 64).unwrap();
        al.region("tmp", 8).unwrap();
        let d = al.describe();
        assert!(d.contains("mat") && d.contains("tmp"), "{d}");
    }
}
