//! Scratchpad **region allocator**: named, non-overlapping address
//! ranges checked against the machine's capacity ([`SimConfig`]).
//!
//! Workloads used to hard-code base addresses (`A_BASE = 0`,
//! `TMP_BASE = 1500`, ...) and every new kernel had to re-derive another
//! module's magic numbers to avoid clobbering them. The allocator packs
//! regions sequentially, aligns each base to a scratchpad line
//! ([`LINE_WORDS`] words — base alignment affects the per-cycle gather
//! width, so line-aligned regions never pay avoidable line-crossing
//! stalls), and rejects over-capacity layouts at build time with a
//! readable diagnostic instead of a simulator out-of-bounds panic.
//!
//! [`Region`] doubles as a checked [`Pattern2D`] factory: patterns built
//! through a region assert containment, so a stream can never silently
//! walk into a neighbouring array.
//!
//! # Region lifetimes (eras)
//!
//! The tiled task-graph executor ([`crate::taskgraph`]) keeps one
//! allocator alive per persistent unit across many tile tasks, so the
//! allocator also tracks **eras**: [`SpadAlloc::advance_era`] opens a
//! new stage and frees every live region from earlier eras that was not
//! pinned with [`SpadAlloc::retain`]; [`SpadAlloc::free`] releases one
//! region explicitly (slot eviction). Freed ranges land on an exact-fit
//! free list and are reused deterministically (lowest base first), and
//! a new allocation can never overlap a still-live region — the
//! invariant `tests/taskgraph_alias.rs` checks on the real tile plans.
//! Duplicate-name rejection applies to *live* regions only, so a fixed
//! static name can be re-allocated era after era.

use crate::isa::Pattern2D;
use crate::sim::{SimConfig, LINE_WORDS};

/// A named, allocated address range in a scratchpad.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    name: &'static str,
    base: i64,
    words: i64,
}

impl Region {
    /// First word address of the region.
    pub fn base(&self) -> i64 {
        self.base
    }

    /// Capacity in 32-bit words.
    pub fn words(&self) -> i64 {
        self.words
    }

    /// One past the last word address.
    pub fn end(&self) -> i64 {
        self.base + self.words
    }

    /// Scratchpad lines the region spans (bases are line-aligned, so
    /// this is the region's line-traffic footprint: a full reload of
    /// the region fetches exactly this many lines).
    pub fn lines(&self) -> i64 {
        let line = LINE_WORDS as i64;
        (self.words + line - 1) / line
    }

    /// Region name (diagnostics).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Absolute address of `off` within the region (bounds-checked).
    pub fn addr(&self, off: i64) -> i64 {
        assert!(
            (0..self.words).contains(&off),
            "region {:?}: offset {off} outside 0..{}",
            self.name,
            self.words
        );
        self.base + off
    }

    /// Assert a pattern stays inside the region and return it.
    fn checked(&self, pat: Pattern2D) -> Pattern2D {
        if let Some((lo, hi)) = pat.bounds() {
            assert!(
                lo >= self.base && hi < self.end(),
                "region {:?} [{}, {}): pattern spans [{lo}, {hi}]",
                self.name,
                self.base,
                self.end()
            );
        }
        pat
    }

    /// Contiguous pattern of `n` words starting at `off`.
    pub fn lin(&self, off: i64, n: i64) -> Pattern2D {
        self.checked(Pattern2D::lin(self.base + off, n))
    }

    /// 1D strided pattern starting at `off`.
    pub fn strided(&self, off: i64, c_i: i64, n: i64) -> Pattern2D {
        self.checked(Pattern2D::strided(self.base + off, c_i, n))
    }

    /// 2D rectangular pattern starting at `off`.
    pub fn rect(&self, off: i64, c_i: i64, n_i: i64, c_j: i64, n_j: i64) -> Pattern2D {
        self.checked(Pattern2D::rect(self.base + off, c_i, n_i, c_j, n_j))
    }

    /// 2D inductive (stretched) pattern starting at `off` — the RI
    /// stream of paper Fig 10b, bounds-checked against the region.
    pub fn inductive(
        &self,
        off: i64,
        c_i: i64,
        n_i: f64,
        c_j: i64,
        n_j: i64,
        s_ji: f64,
    ) -> Pattern2D {
        self.checked(Pattern2D::inductive(self.base + off, c_i, n_i, c_j, n_j, s_ji))
    }
}

/// Allocation failure (rendered with the full layout so far).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// The request does not fit in the remaining capacity.
    Capacity {
        /// Region that failed to allocate.
        name: &'static str,
        /// Requested size in words.
        words: i64,
        /// Words already allocated (aligned).
        used: i64,
        /// Total scratchpad capacity in words.
        cap: i64,
    },
    /// A region with this name already exists in the allocator.
    Duplicate(&'static str),
    /// Zero- or negative-sized request.
    Empty(&'static str),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Capacity { name, words, used, cap } => write!(
                f,
                "spad region {name:?}: {words} words do not fit \
                 ({used}/{cap} words already allocated)"
            ),
            AllocError::Duplicate(name) => {
                write!(f, "spad region {name:?} allocated twice")
            }
            AllocError::Empty(name) => {
                write!(f, "spad region {name:?} requested with no words")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// Per-region lifetime bookkeeping, index-aligned with
/// `SpadAlloc::regions` (kept outside [`Region`] so regions stay `Copy`
/// and value-comparable).
#[derive(Clone, Copy, Debug)]
struct RegionMeta {
    /// Era the region was allocated in.
    era: u32,
    /// Retained regions survive [`SpadAlloc::advance_era`].
    retained: bool,
}

/// Sequential, line-aligned scratchpad region allocator.
#[derive(Clone, Debug)]
pub struct SpadAlloc {
    cap: i64,
    cursor: i64,
    regions: Vec<Region>,
    /// Lifetime metadata for each live region (index-aligned).
    meta: Vec<RegionMeta>,
    /// Current era (starts at 0; bumped by [`SpadAlloc::advance_era`]).
    era: u32,
    /// Freed `(base, words)` ranges, reusable by exact-fit allocation.
    free_list: Vec<(i64, i64)>,
}

impl SpadAlloc {
    /// Allocator over an explicit capacity in words.
    pub fn with_capacity(words: usize) -> Self {
        Self {
            cap: words as i64,
            cursor: 0,
            regions: Vec::new(),
            meta: Vec::new(),
            era: 0,
            free_list: Vec::new(),
        }
    }

    /// Allocator over a lane's local scratchpad.
    pub fn lane(cfg: &SimConfig) -> Self {
        Self::with_capacity(cfg.lane_spad_words)
    }

    /// Allocator over the shared scratchpad.
    pub fn shared(cfg: &SimConfig) -> Self {
        Self::with_capacity(cfg.shared_words)
    }

    /// Allocate `words` words as a new named region. Bases are aligned
    /// to a scratchpad line; live regions never overlap by construction.
    /// An exact-fit freed range (lowest base first) is reused before the
    /// bump cursor grows, so slot-sized churn is address-stable.
    pub fn region(&mut self, name: &'static str, words: i64) -> Result<Region, AllocError> {
        if words <= 0 {
            return Err(AllocError::Empty(name));
        }
        if self.regions.iter().any(|r| r.name == name) {
            return Err(AllocError::Duplicate(name));
        }
        let base = match self
            .free_list
            .iter()
            .enumerate()
            .filter(|(_, &(_, w))| w == words)
            .min_by_key(|(_, &(b, _))| b)
            .map(|(i, _)| i)
        {
            Some(i) => self.free_list.swap_remove(i).0,
            None => {
                let line = LINE_WORDS as i64;
                let base = (self.cursor + line - 1) / line * line;
                if base + words > self.cap {
                    return Err(AllocError::Capacity {
                        name,
                        words,
                        used: base,
                        cap: self.cap,
                    });
                }
                self.cursor = base + words;
                base
            }
        };
        let r = Region { name, base, words };
        self.regions.push(r);
        self.meta.push(RegionMeta { era: self.era, retained: false });
        Ok(r)
    }

    /// Open a new era: every live region from an earlier era that was
    /// not pinned with [`SpadAlloc::retain`] is freed (its range joins
    /// the exact-fit free list, its name becomes reusable). Returns the
    /// new era number.
    pub fn advance_era(&mut self) -> u32 {
        self.era += 1;
        let era = self.era;
        let mut i = 0;
        while i < self.regions.len() {
            if self.meta[i].era < era && !self.meta[i].retained {
                let r = self.regions.remove(i);
                self.meta.remove(i);
                self.free_list.push((r.base, r.words));
            } else {
                i += 1;
            }
        }
        era
    }

    /// Current era number.
    pub fn era(&self) -> u32 {
        self.era
    }

    /// Pin a live region so it survives [`SpadAlloc::advance_era`]
    /// (persistent tile slots, round-trip scratch). Panics if the
    /// region is not live in this allocator.
    pub fn retain(&mut self, r: &Region) {
        let i = self.index_of(r);
        self.meta[i].retained = true;
    }

    /// Explicitly free a live region (tile-slot eviction): its range
    /// joins the exact-fit free list and its name becomes reusable.
    /// Panics if the region is not live in this allocator.
    pub fn free(&mut self, r: &Region) {
        let i = self.index_of(r);
        self.regions.remove(i);
        self.meta.remove(i);
        self.free_list.push((r.base, r.words));
    }

    fn index_of(&self, r: &Region) -> usize {
        self.regions
            .iter()
            .position(|x| x == r)
            .unwrap_or_else(|| panic!("region {:?} is not live in this allocator", r.name))
    }

    /// Words still available (from the aligned cursor).
    pub fn remaining(&self) -> i64 {
        let line = LINE_WORDS as i64;
        self.cap - (self.cursor + line - 1) / line * line
    }

    /// Allocated regions, in allocation order.
    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    /// Render the layout (diagnostics / docs).
    pub fn describe(&self) -> String {
        let mut s = format!("spad layout ({} words):\n", self.cap);
        for r in &self.regions {
            s.push_str(&format!(
                "  [{:>6}, {:>6})  {:>6} words  {}\n",
                r.base,
                r.end(),
                r.words,
                r.name
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_pack_line_aligned_and_disjoint() {
        let mut al = SpadAlloc::with_capacity(256);
        let a = al.region("a", 20).unwrap();
        let b = al.region("b", 7).unwrap();
        let c = al.region("c", 16).unwrap();
        assert_eq!(a.base(), 0);
        assert_eq!(b.base(), 32, "20 rounds up to the next line");
        assert_eq!(c.base(), 48);
        for (x, y) in [(a, b), (b, c), (a, c)] {
            assert!(x.end() <= y.base(), "{x:?} overlaps {y:?}");
        }
        assert!(al.remaining() >= 256 - 64 - 16);
    }

    #[test]
    fn capacity_overflow_is_a_readable_error() {
        let mut al = SpadAlloc::with_capacity(64);
        al.region("a", 40).unwrap();
        let err = al.region("b", 32).unwrap_err();
        assert!(matches!(err, AllocError::Capacity { name: "b", .. }));
        let msg = err.to_string();
        assert!(msg.contains("\"b\"") && msg.contains("64"), "{msg}");
    }

    #[test]
    fn duplicate_and_empty_requests_rejected() {
        let mut al = SpadAlloc::with_capacity(64);
        al.region("a", 8).unwrap();
        assert_eq!(al.region("a", 8).unwrap_err(), AllocError::Duplicate("a"));
        assert_eq!(al.region("z", 0).unwrap_err(), AllocError::Empty("z"));
    }

    #[test]
    fn region_patterns_are_containment_checked() {
        let mut al = SpadAlloc::with_capacity(128);
        let a = al.region("a", 32).unwrap();
        assert_eq!(a.lin(4, 8).start, 4);
        assert_eq!(a.addr(31), 31);
        assert_eq!(a.lines(), 2, "32 words = 2 full lines");
        assert_eq!(al.region("odd", 17).unwrap().lines(), 2, "17 words round up");
        let tri = a.inductive(0, 1, 4.0, 5, 4, -1.0);
        assert_eq!(tri.total_len(), 10);
    }

    #[test]
    #[should_panic(expected = "pattern spans")]
    fn out_of_region_pattern_panics_at_build_time() {
        let mut al = SpadAlloc::with_capacity(128);
        let a = al.region("a", 32).unwrap();
        let _ = a.lin(16, 32); // runs to word 47 > region end 32
    }

    #[test]
    fn era_frees_unretained_regions_and_reuses_names() {
        let mut al = SpadAlloc::with_capacity(256);
        let keep = al.region("keep", 32).unwrap();
        al.retain(&keep);
        let tmp = al.region("tmp", 16).unwrap();
        assert_eq!(al.era(), 0);
        assert_eq!(al.advance_era(), 1);
        // `keep` survives, `tmp` is gone and its name is reusable.
        assert_eq!(al.regions(), &[keep]);
        let tmp2 = al.region("tmp", 16).unwrap();
        assert_eq!(tmp2.base(), tmp.base(), "exact-fit reuse is address-stable");
        // But a still-live name is still a duplicate.
        assert_eq!(al.region("keep", 32).unwrap_err(), AllocError::Duplicate("keep"));
    }

    #[test]
    fn free_then_realloc_prefers_lowest_exact_fit() {
        let mut al = SpadAlloc::with_capacity(512);
        let a = al.region("a", 64).unwrap();
        let b = al.region("b", 64).unwrap();
        let c = al.region("c", 64).unwrap();
        al.free(&b);
        al.free(&a);
        // Both freed slots fit; the lower base wins deterministically.
        let d = al.region("d", 64).unwrap();
        assert_eq!(d.base(), a.base());
        let e = al.region("e", 64).unwrap();
        assert_eq!(e.base(), b.base());
        // No exact fit (different size) -> bump allocation past c.
        let f = al.region("f", 32).unwrap();
        assert!(f.base() >= c.end());
        // Live regions stay pairwise disjoint through the churn.
        let live = al.regions().to_vec();
        for (i, x) in live.iter().enumerate() {
            for y in &live[i + 1..] {
                assert!(
                    x.end() <= y.base() || y.end() <= x.base(),
                    "{x:?} overlaps {y:?}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "not live")]
    fn double_free_panics() {
        let mut al = SpadAlloc::with_capacity(128);
        let a = al.region("a", 16).unwrap();
        al.free(&a);
        al.free(&a);
    }

    #[test]
    fn describe_lists_every_region() {
        let mut al = SpadAlloc::with_capacity(128);
        al.region("mat", 64).unwrap();
        al.region("tmp", 8).unwrap();
        let d = al.describe();
        assert!(d.contains("mat") && d.contains("tmp"), "{d}");
    }
}
