//! `vsc` — the typed **vector-stream control** kernel-builder API
//! (paper §5, Table 1), the programming layer every workload is
//! authored against.
//!
//! The raw [`crate::isa`] layer is deliberately machine-shaped: port
//! numbers are bare `usize`s, scratchpad addresses are bare `i64`s, and
//! nothing stops a program from streaming into a port no dataflow
//! consumes — bugs that surface as watchdog deadlocks hundreds of
//! thousands of cycles into a simulation. This module closes that gap
//! with three pieces:
//!
//! * [`builder`] — [`Kernel`]/[`DfgScope`] assemble the lane's dataflow
//!   graphs and hand back typed, unforgeable port handles ([`In`],
//!   [`Out`]); [`ProgBuilder`] consumes the handles to emit the control
//!   program, including the ablation-aware lowering (per-row
//!   decomposition when inductive streams are off, implicit-mask
//!   flags) and constructors for the recurring idioms: gated forwards,
//!   pivot broadcasts over [`crate::isa::XferDst::Bcast`], inductive
//!   gate streams.
//! * [`alloc`] — [`SpadAlloc`]/[`Region`]: a named scratchpad region
//!   allocator with line-aligned bases, capacity checking against
//!   [`crate::sim::SimConfig`], and containment-checked pattern
//!   construction. No workload hard-codes a base address anymore.
//! * [`check`] — [`check_program`] validates a finished program
//!   (every fed dataflow can fire, every produced output is drained,
//!   patterns stay in bounds, instance totals balance), runs the
//!   LRU reuse-budget accounting model (predicted line traffic per
//!   configuration era, [`DiagKind::MissedReuse`] warnings for
//!   avoidable re-fetches), and renders readable diagnostics;
//!   [`programs_equal`] is the structural comparator behind the
//!   old-vs-new port equivalence tests.

#![deny(missing_docs)]

pub mod alloc;
pub mod builder;
pub mod check;

pub use alloc::{AllocError, Region, SpadAlloc};
pub use builder::{BuiltKernel, DfgScope, In, Kernel, Out, ProgBuilder};
pub use check::{
    check_program, programs_equal, CheckReport, Diag, DiagKind, Severity,
    TrafficReport, REUSE_LINES,
};
